"""Frozen plan artifacts: round-trip fidelity, immutability, the
content-addressed store (corruption tolerance, cross-process reload),
plan-driven serving, and checkpoint plan-hash warm starts."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import ShapeConfig, all_archs, get_arch
from repro.core import (FrozenPlan, MemoryPlan, PlanStore, diff_decision_logs,
                        specialize)
from repro.core import planstore

SRC = str(Path(__file__).resolve().parents[1] / "src")

SMOKE = ShapeConfig("smoke", "train", 64, 4)
DEC = ShapeConfig("smoke_dec", "decode", 48, 2)


# ---------------- round-trip fidelity ----------------

@pytest.mark.parametrize("arch", all_archs())
def test_roundtrip_every_arch_train_and_decode(arch):
    a = get_arch(arch)
    shapes = ["train_4k", "prefill_32k" if a.is_encoder else "decode_32k"]
    for s in shapes:
        plan = specialize(arch, s)
        rt = FrozenPlan.from_json(plan.to_json())
        assert rt == plan, (arch, s)
        assert rt.content_hash() == plan.content_hash(), (arch, s)
        # and through the mutable builder (thaw -> refreeze is lossless)
        assert plan.thaw().freeze().content_hash() == plan.content_hash()


def test_content_hash_is_insertion_order_independent():
    plan = specialize("qwen3-8b", "train_4k")
    d = json.loads(plan.to_json())
    reordered = {k: d[k] for k in reversed(list(d))}
    rt = MemoryPlan.from_dict(reordered).freeze()
    assert rt.content_hash() == plan.content_hash()


def test_shape_dims_carried_in_artifact():
    plan = specialize("qwen3-8b", DEC, mesh_shape=(1, 1))
    assert (plan.shape_kind, plan.seq_len, plan.global_batch) \
        == ("decode", 48, 2)
    rt = FrozenPlan.from_json(plan.to_json())
    assert rt.seq_len == 48 and rt.global_batch == 2


# ---------------- immutability ----------------

def test_frozen_plan_mutation_raises():
    plan = specialize("qwen3-8b", "train_4k")
    assert isinstance(plan, FrozenPlan)
    with pytest.raises(Exception):      # FrozenInstanceError
        plan.use_pallas = "on"
    with pytest.raises(TypeError):
        plan.estimates["x"] = 1.0
    with pytest.raises(TypeError):
        plan.axis_rules["batch"] = "model"
    with pytest.raises(TypeError):
        plan.placements["new"] = None
    with pytest.raises(Exception):
        plan.comm.compress_grads = True
    with pytest.raises(TypeError):
        plan.partitions["flash_attention"].blocks["block_q"] = 1
    with pytest.raises(AttributeError):
        plan.log.append(("x", "y", "z", "w"))
    # builder-only APIs are not on the artifact
    assert not hasattr(plan, "record")
    assert not hasattr(plan, "placement")
    # but it is hashable (usable as a dict key / memo key)
    assert {plan: 1}[plan] == 1


def test_builder_still_mutable_and_freezes():
    b = MemoryPlan(arch="a", shape="s", mesh_axes=("data",), mesh_shape=(2,))
    b.record("p", "subj", "dec", "why")
    b.placement("t").spec = ("data", None)
    f = b.freeze()
    assert f.log == (("p", "subj", "dec", "why"),)
    assert f.placements["t"].spec == ("data", None)


# ---------------- disk store ----------------

def test_store_corruption_tolerance(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_DIR", str(tmp_path))
    plan = specialize("qwen3-8b", "train_4k")
    h = plan.content_hash()
    entry = tmp_path / f"{h}.json"
    assert entry.exists()
    # truncate the artifact mid-file: reload must miss, not crash
    entry.write_text(entry.read_text()[: entry.stat().st_size // 2])
    store = planstore.get_store()
    store.clear()                       # drop the memory tier
    assert store.load(h) is None
    p2 = specialize("qwen3-8b", "train_4k")      # recompiles cleanly
    assert p2.content_hash() == h
    assert store.stats()["corrupt"] >= 1


def test_store_rejects_wrong_schema_and_tampered_payload(tmp_path):
    store = PlanStore(tmp_path)
    plan = specialize("qwen3-8b", "decode_32k")
    h = store.save(plan)
    # stale schema version -> miss
    entry = json.loads((tmp_path / f"{h}.json").read_text())
    entry["schema"] = -1
    (tmp_path / f"{h}.json").write_text(json.dumps(entry))
    assert store.load(h) is None
    # tampered payload (hash no longer matches the content) -> miss
    entry["schema"] = 1
    entry["plan"]["use_pallas"] = "tampered"
    (tmp_path / f"{h}.json").write_text(json.dumps(entry))
    assert store.load(h) is None


def test_store_save_load_evict(tmp_path):
    store = PlanStore(tmp_path)
    plan = specialize("mamba2-2.7b", "train_4k")
    h = store.save(plan)
    assert store.load(h) == plan
    key = "somekey"
    store.put(key, plan)
    assert store.get(key) is plan       # memory tier: same object
    assert store.evict(key)
    fresh = PlanStore(tmp_path)         # fresh process simulation
    assert fresh.get(key) is None       # both tiers evicted
    assert fresh.stats()["misses"] == 1


def test_store_gc_size_cap_and_stale_schema(tmp_path):
    """The content-addressed tier is capped: oldest-mtime entries beyond
    the cap are evicted (with their by_key refs), stale-schema leftovers
    go first, and stats()["disk_size"] reflects the shrink."""
    import time

    store = PlanStore(tmp_path, max_disk_entries=2)
    # a leftover from a previous schema version must be collected
    stale = tmp_path / ("0" * 64 + ".json")
    stale.write_text(json.dumps({"schema": -1, "content_hash": "0" * 64,
                                 "plan": {}}))
    plans = [specialize("qwen3-8b", ShapeConfig(f"gc{i}", "train", 64, 4),
                        cache=False) for i in range(4)]
    for i, p in enumerate(plans):
        store.put(f"key{i}", p)
        time.sleep(0.01)             # distinct mtimes for LRU ordering
    st = store.stats()
    assert not stale.exists(), "stale-schema entry survived gc"
    assert st["disk_size"] <= 2, st
    assert st["gc_evictions"] >= 3, st          # stale + >=2 over-cap
    assert st["disk_bytes"] > 0
    # the newest entry survived; its by_key ref still resolves on disk
    fresh = PlanStore(tmp_path, max_disk_entries=2)
    assert fresh.get("key3") == plans[-1]
    # evicted entries took their by_key refs with them -> clean miss
    assert fresh.get("key0") is None
    # explicit gc below the cap is a no-op
    assert store.gc() == 0


def test_store_gc_collects_by_key_refs(tmp_path):
    """Refs to live entries (minted by flow-fingerprint changes) are
    LRU-capped at 4x the entry cap, and dangling refs are dropped."""
    store = PlanStore(tmp_path, max_disk_entries=1)
    plan = specialize("qwen3-8b", ShapeConfig("refs", "train", 64, 4),
                      cache=False)
    for i in range(7):                  # 7 request keys, 1 content entry
        store.put(f"fingerprint{i}", plan)
    # ref churn alone (no entry churn) already triggered the trim
    refs = list((tmp_path / "by_key").iterdir())
    assert len(refs) <= 5, refs         # 4x cap (+1 just-written)
    dangling = tmp_path / "by_key" / "deadkey"
    dangling.write_text("f" * 64)
    # a stray non-dict payload must be treated as stale, not crash gc
    junk = tmp_path / ("e" * 64 + ".json")
    junk.write_text("[1, 2, 3]")
    store.gc()
    refs = list((tmp_path / "by_key").iterdir())
    assert not dangling.exists(), "dangling by_key ref survived gc"
    assert not junk.exists(), "non-dict payload survived gc"
    assert len(refs) <= 4, refs         # LRU-trimmed to 4x cap
    assert store.stats()["disk_size"] == 1


def test_store_gc_uncapped_when_disabled(tmp_path):
    store = PlanStore(tmp_path, max_disk_entries=0)
    for i in range(4):
        store.put(f"key{i}", specialize(
            "qwen3-8b", ShapeConfig(f"nogc{i}", "train", 64, 4),
            cache=False))
    assert store.stats()["disk_size"] == 4
    assert store.stats()["gc_evictions"] == 0


def test_second_process_reloads_identical_hash(tmp_path):
    plan = specialize("qwen3-8b", "train_4k", plan_dir=tmp_path)
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core import specialize, plan_cache_stats\n"
         "p = specialize('qwen3-8b', 'train_4k')\n"
         "s = plan_cache_stats()\n"
         "assert s['disk_hits'] == 1 and s['misses'] == 0, s\n"
         "print(p.content_hash())"],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": SRC,
             "REPRO_PLAN_DIR": str(tmp_path)})
    assert out.returncode == 0, out.stderr[-3000:]
    assert out.stdout.strip().splitlines()[-1] == plan.content_hash()


# ---------------- plan-driven serving ----------------

def test_from_plan_matches_kwargs_engine():
    from repro.models import init_params
    from repro.models.lm import RunCfg
    from repro.serve import ServeEngine

    arch = get_arch("qwen3-8b").reduced()
    plan = specialize(arch, DEC, mesh_axes=("data", "model"),
                      mesh_shape=(1, 1))
    # reduced config on a 1x1 mesh: no padding, so a hand-written RunCfg
    # is expressible (assert the assumption so drift is visible)
    pads = plan.padded_sizes()
    assert pads == (0, 0, 0, 0) or pads == (arch.vocab_size, arch.n_heads,
                                            0, arch.n_kv_heads), pads
    params = init_params(arch, jax.random.PRNGKey(0), *pads)

    eng_plan = ServeEngine.from_plan(plan, params, arch=arch)
    assert eng_plan.max_len == DEC.seq_len            # limits from the plan
    assert eng_plan.max_batch == DEC.global_batch
    assert eng_plan.plan is plan
    eng_kw = ServeEngine(arch, params,
                         RunCfg(vocab_padded=pads[0], heads_padded=pads[1],
                                ssm_heads_padded=pads[2],
                                kv_heads_padded=pads[3], block_q=16),
                         max_batch=2, max_len=48)

    prompt = np.arange(9, dtype=np.int32) % arch.vocab_size
    for eng in (eng_plan, eng_kw):
        eng.submit(prompt, max_new_tokens=5)
        eng.run_until_idle(max_ticks=16)
    toks_plan = eng_plan.finished[0].out_tokens
    toks_kw = eng_kw.finished[0].out_tokens
    assert toks_plan == toks_kw, (toks_plan, toks_kw)


# ---------------- checkpoint plan-hash flow ----------------

def test_trainer_stamps_hash_and_warm_starts(tmp_path, capsys):
    from repro.launch.mesh import make_host_mesh
    from repro.optim import OptConfig
    from repro.train.trainer import Trainer, TrainerConfig

    mesh = make_host_mesh()
    arch = get_arch("qwen3-8b").reduced()
    mesh_kw = dict(mesh_axes=tuple(mesh.axis_names),
                   mesh_shape=tuple(mesh.devices.shape))
    plan = specialize(arch, SMOKE, **mesh_kw)
    cfg = TrainerConfig(n_steps=2, ckpt_every=2, ckpt_dir=str(tmp_path),
                        log_every=100)
    tr = Trainer(plan, mesh, cfg, opt_cfg=OptConfig(total_steps=2),
                 arch=arch, shape=SMOKE)
    tr.fit()
    step = tr.ckpt.latest_step()
    assert step == 2
    # the manifest is stamped with the plan hash...
    assert tr.ckpt.plan_hash(step) == plan.content_hash()
    # ...and the artifact itself ships next to the checkpoints
    reloaded = PlanStore(tmp_path / "plans").load(plan.content_hash())
    assert reloaded == plan

    # a restarted job warm-starts from the stored artifact
    tr2 = Trainer.warm_start(tmp_path, mesh, opt_cfg=OptConfig(total_steps=2),
                             arch=arch, shape=SMOKE)
    assert tr2.plan_hash == plan.content_hash()
    state, at = tr2.resume()
    assert at == 2

    # artifact gone -> the fallback recompiles with the CALLER's reduced
    # arch and ad-hoc shape (manifest names would hit the full registry
    # config / an unknown shape)
    import shutil
    shutil.rmtree(tmp_path / "plans")
    planstore._STORES.pop(tmp_path / "plans", None)
    tr4 = Trainer.warm_start(tmp_path, mesh, opt_cfg=OptConfig(total_steps=2),
                             arch=arch, shape=SMOKE)
    assert tr4.plan.arch == plan.arch and tr4.plan.seq_len == SMOKE.seq_len

    # hash mismatch (recompiled under different decisions) -> logged
    # decision diff, restore still succeeds
    plan_b = specialize(arch, SMOKE, use_pallas="off", **mesh_kw)
    assert plan_b.content_hash() != plan.content_hash()
    tr3 = Trainer(plan_b, mesh, cfg, opt_cfg=OptConfig(total_steps=2),
                  arch=arch, shape=SMOKE)
    capsys.readouterr()
    state, at = tr3.resume()
    assert at == 2
    assert "plan hash changed" in capsys.readouterr().out


def test_comm_plan_wire_fields_roundtrip_and_render(capsys, tmp_path):
    """The two new CommPlan fields (``compress_lowered``,
    ``combine_topology``) survive freeze -> json -> thaw -> refreeze
    losslessly, `plan show` renders them, and `plan diff` narrates a
    topology flip between two otherwise-identical decode artifacts."""
    from repro.launch.plan import main

    d = str(tmp_path / "plans")
    dec = ShapeConfig("wire_dec", "decode", 256, 8)
    flat = specialize("qwen3-8b", dec, mesh_axes=("data", "model"),
                      mesh_shape=(1, 8), plan_dir=d)
    ring = specialize("qwen3-8b", dec, mesh_axes=("data", "model"),
                      mesh_shape=(1, 8), plan_dir=d,
                      combine_topology="ring")
    assert flat.comm.combine_topology == "flat"
    assert ring.comm.combine_topology == "ring"
    assert flat.content_hash() != ring.content_hash()
    for p in (flat, ring):
        rt = FrozenPlan.from_json(p.to_json())
        assert rt == p and rt.content_hash() == p.content_hash()
        assert rt.thaw().freeze().comm.combine_topology \
            == p.comm.combine_topology

    assert main(["--plan-dir", d, "show", ring.content_hash()[:10]]) == 0
    out = capsys.readouterr().out
    assert '"combine_topology": "ring"' in out
    rc = main(["--plan-dir", d, "diff", flat.content_hash()[:10],
               ring.content_hash()[:10]])
    out = capsys.readouterr().out
    assert rc == 1 and "combine_topology" in out

    # the lowered-compression flag rides the same round-trip
    low = specialize("qwen3-8b", ShapeConfig("wire_tr", "train", 128, 8),
                     mesh_axes=("data", "model"), mesh_shape=(8, 2),
                     plan_dir=d)
    assert low.comm.compress_lowered
    assert FrozenPlan.from_json(low.to_json()).comm.compress_lowered
    assert main(["--plan-dir", d, "show", low.content_hash()[:10]]) == 0
    out = capsys.readouterr().out
    assert '"grad_compress_lowered": 8.0' in out and "+int8_ef" in out


def test_plan_cli_renders_wire_decisions_schema_tolerant():
    """Artifacts stored before the topology/lowering split still
    render: a shard_map decode artifact without ``combine_topology``
    displays the flat combine it actually ran, a compressed artifact
    without ``grad_compress_lowered`` displays the post-reduce EF it
    actually ran, and plans with neither key synthesize nothing."""
    from types import SimpleNamespace

    from repro.launch.plan import _DECISION_KEYS, _decisions

    assert "combine_topology" in _DECISION_KEYS
    assert "grad_compress_lowered" in _DECISION_KEYS
    old_dec = _decisions(SimpleNamespace(
        estimates={"decode_impl": "shard_map_flash",
                   "kv_residency": "dense"}))
    assert old_dec["combine_topology"] == "flat"
    old_cmp = _decisions(SimpleNamespace(estimates={"grad_compress": 1.0}))
    assert old_cmp["grad_compress_lowered"] == "post-reduce"
    # xla-decode and uncompressed artifacts synthesize neither field
    plain = _decisions(SimpleNamespace(
        estimates={"decode_impl": "xla", "grad_compress": 0.0}))
    assert "combine_topology" not in plain
    assert "grad_compress_lowered" not in plain
    # present keys always win over the fallbacks
    new = _decisions(SimpleNamespace(
        estimates={"decode_impl": "shard_map_flash",
                   "combine_topology": "bidir",
                   "grad_compress": 1.0,
                   "grad_compress_lowered": 8.0}))
    assert new["combine_topology"] == "bidir"
    assert new["grad_compress_lowered"] == 8.0


def test_diff_decision_logs():
    old = [("layout", "vocab", "pad_512", "mxu"),
           ("comm", "grads", "reduce_scatter", "bw")]
    new = [("layout", "vocab", "pad_1024", "mxu"),
           ("part", "fa", "512x512", "vmem")]
    lines = diff_decision_logs(old, new)
    assert any(line.startswith("~ layout/vocab") for line in lines)
    assert any(line.startswith("- comm/grads") for line in lines)
    assert any(line.startswith("+ part/fa") for line in lines)
    assert diff_decision_logs(new, new) == []
