"""The repro.dist layer + the perf work that rides on it.

Covers what the seed tests do not: int8 round-trips on non-128-multiple
shapes, compressed_psum over a >1-size axis, resolve_pspec divisibility
repair on awkward dims, the plan cache, the CommunicationPass
compressed-schedule decision, and the causal flash-attention grid
pruning (grid math + bit-identical output).
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig
from repro.core.costmodel import compressed_ratio
from repro.core.pipeline import (clear_plan_cache, plan_cache_stats,
                                 specialize)
from repro.dist.collectives import dequantize_int8, ef_compress, quantize_int8
from repro.dist.sharding import cache_pspecs, mesh_sizes, resolve_pspec
from repro.kernels.flash_attention import flash_attention, kv_grid_steps

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------- int8 quantization on awkward shapes ----------------

@pytest.mark.parametrize("shape", [(1,), (7,), (127,), (129,), (3, 5),
                                   (257,), (2, 130)])
def test_int8_roundtrip_non_multiple_shapes(shape):
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal(shape) * 5, jnp.float32)
    q, scales, pad = quantize_int8(x)
    assert q.dtype == jnp.int8
    assert (int(np.prod(shape)) + pad) % 128 == 0
    xr = dequantize_int8(q, scales, pad, x.shape)
    assert xr.shape == x.shape
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(xr - x).max()) <= amax / 254 * 1.001 + 1e-6


def test_int8_roundtrip_zeros_and_tiny():
    for x in (jnp.zeros((5,)), jnp.full((300,), 1e-7)):
        q, s, pad = quantize_int8(x)
        xr = dequantize_int8(q, s, pad, x.shape)
        assert float(jnp.abs(xr - x).max()) <= 1e-6


def test_ef_compress_keeps_residual_dtype():
    g = jnp.linspace(-1, 1, 300)
    gh, err = ef_compress(g, None)
    assert err.dtype == jnp.float32 and gh.dtype == g.dtype
    gh, err2 = ef_compress(g, jnp.zeros_like(g, jnp.bfloat16))
    assert err2.dtype == jnp.bfloat16


# ---------------- compressed_psum over a real >1 axis ----------------

def test_compressed_psum_axis_size_two_awkward_shape():
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.dist.collectives import compressed_psum
            mesh = jax.make_mesh((2,), ("data",))
            x = jnp.arange(2 * 37, dtype=jnp.float32).reshape(2, 37) / 5.0
            def f(xs):
                y, err = compressed_psum(xs[0], "data")
                return y[None], err[None]
            y, err = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P("data", None),
                out_specs=(P("data", None), P("data", None))))(x)
            want = jnp.mean(x, axis=0)
            rel = float(jnp.abs(y[0] - want).max() / jnp.abs(want).max())
            assert rel < 0.02, rel
            # the residual is exactly what dequantization dropped
            assert float(jnp.abs(err).max()) <= float(jnp.abs(x).max()) / 254 * 1.01
            print("OK")
        """)],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": SRC,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=2"})
    assert out.returncode == 0, out.stderr[-3000:]


# ---------------- resolve_pspec repair ----------------

def test_resolve_pspec_divisibility_repair_awkward_dims():
    sizes = {"pod": 2, "data": 4, "model": 8}
    rules = {"batch": ("pod", "data"), "embed": ("data", "model"),
             "heads": "model", "ff": "model"}
    # 6 % (2*4) != 0 -> batch dim repaired to unsharded
    spec = resolve_pspec(rules, (6, 64), ("batch", "embed"), sizes)
    assert tuple(spec) == (None, ("data", "model"))
    # 96 % 32 == 0 -> keeps the full tuple
    spec = resolve_pspec(rules, (96, 30), ("embed", "heads"), sizes)
    assert spec[0] == ("data", "model")
    assert spec[1] is None              # model already used AND 30 % 8 != 0
    # uniqueness: first dim wins the contested axis
    spec = resolve_pspec(rules, (16, 16), ("heads", "ff"), sizes)
    assert tuple(spec) == ("model", None)
    # rules naming axes absent from this mesh are dropped, not crashed
    spec = resolve_pspec({"batch": ("pod", "data")}, (8,), ("batch",),
                         {"data": 4})
    assert tuple(spec) == ("data",)


def test_cache_pspecs_follows_seq_spill():
    plan = specialize("qwen2-vl-72b", "decode_32k")
    assert plan.estimates["decode_impl"] == "shard_map_flash"
    cache_shapes = {
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "k": jax.ShapeDtypeStruct((80, 128, 32768, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((80, 128, 32768, 8, 128), jnp.bfloat16),
    }
    sizes = {"data": 16, "model": 16}
    specs = cache_pspecs(plan, None, cache_shapes, sizes)
    assert tuple(specs["k"])[2] == "model"      # seq dim carries the TP axis
    assert tuple(specs["pos"]) == ()


def test_mesh_sizes_accepts_all_mesh_flavors():
    from repro.core.costmodel import MeshModel
    mm = MeshModel(axes=("data", "model"), shape=(4, 2))
    assert mesh_sizes(mm) == {"data": 4, "model": 2}
    assert mesh_sizes({"data": 4}) == {"data": 4}
    m = jax.make_mesh((1,), ("data",))
    assert mesh_sizes(m) == {"data": 1}


# ---------------- plan cache ----------------

def test_plan_cache_hit_miss_and_isolation(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_DIR", str(tmp_path))
    clear_plan_cache()
    p1 = specialize("qwen3-8b", "train_4k")
    p2 = specialize("qwen3-8b", "train_4k")
    stats = plan_cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    # warm hits are zero-copy: the SAME immutable artifact comes back
    assert p1 is p2 and p1.to_json() == p2.to_json()
    # different key -> miss
    specialize("qwen3-8b", "decode_32k")
    assert plan_cache_stats()["misses"] == 2
    # cache=False bypasses lookup and insertion entirely
    specialize("qwen3-8b", "train_4k", cache=False)
    stats = plan_cache_stats()
    assert stats["hits"] == 1 and stats["size"] == 2
    # the frozen artifact cannot be poisoned: mutation raises instead
    with pytest.raises(TypeError):
        p2.estimates["poison"] = 1.0
    p3 = specialize("qwen3-8b", "train_4k")
    assert "poison" not in p3.estimates
    # dropping the memory tier falls back to the on-disk artifact —
    # bit-identical content, same hash
    from repro.core import planstore
    store = planstore.get_store()
    store.clear()
    p4 = specialize("qwen3-8b", "train_4k")
    assert plan_cache_stats()["disk_hits"] == 1
    assert p4 == p1 and p4.content_hash() == p1.content_hash()


# ---------------- compressed-schedule decision ----------------

def test_communication_pass_compresses_when_collective_bound():
    """Small-batch TP fine-tuning: DP grad allreduce dominates -> int8+EF."""
    shape = ShapeConfig("cb", "train", 128, 8)
    plan = specialize("qwen3-8b", shape, mesh_axes=("data", "model"),
                      mesh_shape=(8, 2))
    assert plan.comm.compress_grads
    assert plan.comm.compresses_gradients
    raw = plan.estimates["est_collective_s_raw"]
    comp = plan.estimates["est_collective_s_int8"]
    assert raw > 0 and comp == pytest.approx(raw * compressed_ratio(8))
    assert comp < 0.6 * raw                     # the modeled volume cut
    assert plan.estimates["est_collective_s"] == pytest.approx(comp)
    assert any(e[1] == "grad_compression" and "int8" in e[2]
               for e in plan.log)
    # compute-bound big-batch training keeps the raw reduction
    plan2 = specialize("qwen3-8b", "train_4k")
    assert not plan2.comm.compress_grads
    assert any(e[1] == "grad_compression" and e[2] == "off"
               for e in plan2.log)


def test_communication_pass_records_lowered_wire():
    """When compression is on AND the wire gate admits the step, the
    plan records that the cut is lowered (int16 code sums on the wire),
    not merely modeled — with the DP degree in the estimates, a
    narrative decision-log entry, and the flag surviving the frozen
    round-trip.  A compressed plan the gate rejects records the honest
    post-reduce fallback instead."""
    from repro.core.plan import FrozenPlan

    shape = ShapeConfig("cb_low", "train", 128, 8)
    plan = specialize("qwen3-8b", shape, mesh_axes=("data", "model"),
                      mesh_shape=(8, 2))
    assert plan.comm.compress_grads and plan.comm.compress_lowered
    assert plan.estimates["grad_compress_lowered"] == 8.0   # the DP degree
    recs = [(d, w) for _, s, d, w in plan.log
            if s == "grad_compress_lowering"]
    assert recs and "int16" in recs[-1][0] and "dp=8" in recs[-1][0]
    assert "int16" in recs[-1][1]           # headroom narrative
    rt = FrozenPlan.from_json(plan.to_json())
    assert rt.comm.compress_lowered and rt == plan

    # forced compression on a 1-wide data axis: nothing to reduce
    # across, so the gate refuses and the record says post-reduce
    plan2 = specialize("qwen3-8b", ShapeConfig("cb_pr", "train", 128, 8),
                       mesh_axes=("data", "model"), mesh_shape=(1, 2),
                       grad_compression="on")
    assert plan2.comm.compress_grads and not plan2.comm.compress_lowered
    assert "grad_compress_lowered" not in plan2.estimates
    recs2 = [d for _, s, d, _ in plan2.log if s == "grad_compress_lowering"]
    assert recs2 and recs2[-1] == "post-reduce EF"


def test_communication_pass_chooses_and_records_combine_topology():
    """Decode plans choose a model-axis combine topology per mesh
    geometry (calibrated thresholds: flat <= 8 < ring <= 16 < bidir),
    record it with its hop count and a hop-comparison narrative, honor
    the specialize() override, and carry it through the frozen
    artifact — the same choose-and-record shape as kv_residency."""
    from repro.core.plan import FrozenPlan

    dec = ShapeConfig("ct_dec", "decode", 256, 8)
    plan = specialize("qwen3-8b", dec, mesh_axes=("data", "model"),
                      mesh_shape=(1, 8))
    assert plan.comm.combine_topology == "flat"
    assert plan.estimates["combine_topology"] == "flat"
    assert plan.estimates["combine_hops"] == 42.0     # 6 * (8 - 1)
    recs = [(d, w) for _, s, d, w in plan.log if s == "combine_topology"]
    assert recs and recs[-1][0] == "flat"
    rt = FrozenPlan.from_json(plan.to_json())
    assert rt.comm.combine_topology == "flat" and rt == plan

    # wider modeled meshes cross the thresholds (no host devices
    # needed: the pass works on the modeled mesh geometry)
    ring = specialize("qwen3-8b", dec, mesh_axes=("data", "model"),
                      mesh_shape=(1, 16))
    assert ring.estimates["combine_topology"] == "ring"
    assert ring.estimates["combine_hops"] == 15.0
    why = [w for _, s, _, w in ring.log if s == "combine_topology"][-1]
    assert "hop" in why
    bidir = specialize("qwen3-8b", dec, mesh_axes=("data", "model"),
                       mesh_shape=(1, 32))
    assert bidir.estimates["combine_topology"] == "bidir"
    assert bidir.estimates["combine_hops"] == 16.0    # ceil(31 / 2)

    # the override is the ops escape hatch, recorded as forced
    forced = specialize("qwen3-8b", dec, mesh_axes=("data", "model"),
                        mesh_shape=(1, 8), combine_topology="ring")
    assert forced.comm.combine_topology == "ring"
    whyf = [w for _, s, _, w in forced.log if s == "combine_topology"][-1]
    assert "forced by options" in whyf

    # a degenerate model axis records flat: no cross-shard combine
    one = specialize("qwen3-8b", ShapeConfig("ct_one", "decode", 256, 8),
                     mesh_axes=("data", "model"), mesh_shape=(1, 1))
    assert one.estimates["combine_topology"] == "flat"
    assert one.estimates["combine_hops"] == 0.0
    # train plans have no decode combine to choose
    assert "combine_topology" not in \
        specialize("qwen3-8b", "train_4k").estimates


# ---------------- causal grid pruning ----------------

def test_causal_grid_steps_halved_at_4k():
    full = kv_grid_steps(4096, 512, 512, causal=True, prune=False)
    pruned = kv_grid_steps(4096, 512, 512, causal=True, prune=True)
    assert full == 64 and pruned == 36          # (n/2)*(n+1) vs n^2, n=8
    assert pruned / full == (8 + 1) / (2 * 8)   # -> 1/2 for large n
    # large-n ratio approaches exactly half
    n = 4096 // 64
    assert kv_grid_steps(4096, 64, 64) / kv_grid_steps(
        4096, 64, 64, prune=False) == (n + 1) / (2 * n)
    # rectangular tiles keep the full grid (packing needs square tiles)
    assert kv_grid_steps(4096, 512, 1024, causal=True) == 8 * 4
    assert kv_grid_steps(4096, 512, 1024, causal=False) == 8 * 4


def test_partitioning_emits_square_tiles_for_causal():
    """The plan's own tile choice must engage the packed-causal grid."""
    plan = specialize("qwen3-8b", "train_4k")
    bp = plan.partitions["flash_attention"].blocks
    assert bp["block_q"] == bp["block_kv"]
    pruned = kv_grid_steps(4096, bp["block_q"], bp["block_kv"])
    full = kv_grid_steps(4096, bp["block_q"], bp["block_kv"], prune=False)
    assert pruned / full <= 0.6                 # ~half at S=4k
    # non-causal archs keep the wide-kv rectangular tiles
    plan2 = specialize("hubert-xlarge", "train_4k")
    bp2 = plan2.partitions["flash_attention"].blocks
    assert bp2["block_kv"] >= bp2["block_q"]


@pytest.mark.parametrize("S,block", [(256, 64), (192, 64)])  # even + odd n
def test_causal_pruned_bit_identical(S, block):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 32))
    k = jax.random.normal(ks[1], (1, S, 2, 32))
    v = jax.random.normal(ks[2], (1, S, 2, 32))
    o_pruned = flash_attention(q, k, v, block_q=block, block_kv=block,
                               interpret=True, prune=True)
    o_full = flash_attention(q, k, v, block_q=block, block_kv=block,
                             interpret=True, prune=False)
    assert np.array_equal(np.asarray(o_pruned), np.asarray(o_full))


def test_causal_pruned_windowed_matches_oracle():
    from repro.kernels import ref
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    o = flash_attention(q, k, v, causal=True, window=48, block_q=64,
                        block_kv=64, interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=True, window=48)
    assert float(jnp.abs(o - r).max()) < 1e-5
