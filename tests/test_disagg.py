"""Disaggregated prefill/decode: chunked block-native prefill identity,
plan-hash handshake, worker-kill journal resume, degraded fallback.

The load-bearing invariant everything here leans on: iterating
``lm.prefill_tail`` over block-sized slices (``lm.prefill_chunked``)
produces KV rows and last-token logits **bitwise identical** to the
dense one-shot ``lm.prefill``.  That identity is what makes the chunk
journal idempotent (a re-sent chunk overwrites equal bytes), the
resume token-exact (journaled rows ARE the prefix KV), and the
degraded inline fallback divergence-free.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch
from repro.core.pipeline import specialize
from repro.models import lm
from repro.models.lm import RunCfg
from repro.serve import PlanHandshakeError, PrefillFleet, ServeEngine


@pytest.fixture(scope="module")
def setup():
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("serve_disagg_t", "decode", 64, 2)
    plan = specialize(arch, shape, mesh_axes=("data", "model"),
                      mesh_shape=(1, 1))
    assert plan.estimates.get("kv_residency") == "paged"
    params = lm.init_params(arch, jax.random.PRNGKey(0),
                            *plan.padded_sizes())
    return arch, plan, params


OPTS = {"heartbeat_s": 0.2, "backoff_base_s": 0.05,
        "backoff_cap_s": 0.2, "chunk_delay_s": 0.05}


def test_chunked_prefill_bitwise_identical_to_dense():
    """Chunked == dense, bitwise, across block-aligned and ragged
    prompt lengths — the cornerstone the disagg path stands on."""
    arch = get_arch("qwen3-8b").reduced()
    cfg = RunCfg(block_q=16, ssd_chunk=16)
    params = lm.init_params(arch, jax.random.PRNGKey(5))
    bl = 16
    for plen in (17, 48, 49):
        p = (np.arange(plen, dtype=np.int32) * 7 + 3) % arch.vocab_size
        lg_full, cache = lm.prefill(
            arch, params, {"tokens": jnp.asarray(p[None])}, cfg,
            max_len=64)
        chunks = []
        lg_c, ks, vs = lm.prefill_chunked(
            arch, params, p, bl, cfg, kv_heads=cache["k"].shape[3],
            on_chunk=lambda i, k, v: chunks.append(i))
        k_c = np.asarray(jnp.concatenate(ks, axis=1))
        v_c = np.asarray(jnp.concatenate(vs, axis=1))
        assert chunks == list(range(-(-plen // bl)))
        assert (np.asarray(cache["k"][:, 0, :plen]) == k_c).all()
        assert (np.asarray(cache["v"][:, 0, :plen]) == v_c).all()
        assert (np.asarray(lg_full[0]) == np.asarray(lg_c)).all()


def test_chunked_prefill_rejects_bad_inputs():
    arch = get_arch("qwen3-8b").reduced()
    cfg = RunCfg(block_q=16, ssd_chunk=16)
    params = lm.init_params(arch, jax.random.PRNGKey(5))
    tok = np.arange(8, dtype=np.int32)
    with pytest.raises(ValueError):
        lm.prefill_chunked(arch, params, tok, 0, cfg)
    with pytest.raises(ValueError):
        lm.prefill_chunked(arch, params, np.zeros((0,), np.int32), 16, cfg)
    ssm = get_arch("mamba2-2.7b").reduced()
    with pytest.raises(ValueError):
        lm.prefill_chunked(ssm, lm.init_params(ssm, jax.random.PRNGKey(0)),
                           tok, 16, cfg)


def test_handshake_rejects_mismatched_plan_hash(setup):
    """A worker whose rebuilt plan hashes differently must be refused
    before any KV crosses the wire — typed, not a crash or a silent
    geometry mismatch."""
    arch, plan, params = setup
    with pytest.raises(PlanHandshakeError, match="content hash"):
        PrefillFleet(plan, arch, params, 1, block_len=16,
                     _expect_hash="0" * 64, **OPTS)


def test_kill_mid_prefill_resumes_from_journal(setup):
    """SIGKILL the worker with a prefill half-journaled: the engine
    must re-dispatch from the last acked block boundary and finish
    token-identical to the inline oracle, with the pool whole."""
    import time
    arch, plan, params = setup
    p = (np.arange(49, dtype=np.int32) * 7 + 3) % arch.vocab_size

    ref = ServeEngine.from_plan(plan, params, arch=arch, max_batch=1)
    ref.submit(p, max_new_tokens=6)
    want = list(ref.run_until_idle(256)[0].out_tokens)

    eng = ServeEngine.from_plan(
        plan, params, arch=arch, seed=0, kv_prefill_mode="disagg",
        disagg_workers=1, disagg_opts=dict(OPTS))
    assert eng.prefill_mode == "disagg"
    rid = eng.submit(p, max_new_tokens=6)
    killed = False
    deadline = time.time() + 420
    while (eng.pending or eng.active or eng._disagg) \
            and time.time() < deadline:
        eng.step()
        fl = eng._disagg.get(rid)
        if not killed and fl is not None and 1 <= fl.acked < fl.nb_feed:
            killed = eng._fleet.kill_worker(rid=rid)
    assert killed, "kill window never opened mid-prefill"
    [r] = [q for q in eng.finished if q.rid == rid]
    assert list(r.out_tokens) == want, "TOKEN DIVERGENCE after kill"
    tel = eng.telemetry()
    json.dumps(tel)                  # the snapshot serializes whole
    assert tel["prefill"]["disagg"]["fleet"]["deaths"] >= 1
    assert tel["prefill"]["disagg"]["resumes"] >= 1
    st = eng.block_stats()
    assert st["in_use"] == st["cached"], f"blocks leaked: {st}"
    eng.shutdown()


def test_restart_budget_exhaustion_degrades_to_inline(setup):
    """Kill the only worker under ``max_restarts=0``: the fleet
    retires, the engine flips to a typed DegradedMode, and the orphaned
    request completes in-process with identical tokens — never a
    crash."""
    import time
    arch, plan, params = setup
    p = (np.arange(33, dtype=np.int32) * 11 + 5) % arch.vocab_size

    ref = ServeEngine.from_plan(plan, params, arch=arch, max_batch=1)
    ref.submit(p, max_new_tokens=6)
    want = list(ref.run_until_idle(256)[0].out_tokens)

    eng = ServeEngine.from_plan(
        plan, params, arch=arch, seed=0, kv_prefill_mode="disagg",
        disagg_workers=1, disagg_opts=dict(OPTS, max_restarts=0))
    rid = eng.submit(p, max_new_tokens=6)
    killed = False
    deadline = time.time() + 420
    while (eng.pending or eng.active or eng._disagg) \
            and time.time() < deadline:
        eng.step()
        fl = eng._disagg.get(rid)
        if not killed and fl is not None and fl.acked >= 1:
            killed = eng._fleet.kill_worker(rid=rid)
    assert killed
    [r] = [q for q in eng.finished if q.rid == rid]
    assert list(r.out_tokens) == want, "TOKEN DIVERGENCE in fallback"
    assert eng.prefill_mode == "degraded"
    assert eng.degraded is not None and eng.degraded.worker_deaths >= 1
    press = eng.pressure_stats()
    assert press["degraded"]["reason"].startswith("all 1 prefill worker")
    st = eng.block_stats()
    assert st["in_use"] == st["cached"], f"blocks leaked: {st}"
    eng.shutdown()


def test_from_plan_inline_without_workers(setup):
    """disagg mode with zero workers quietly keeps the inline path —
    the same fallback the pass itself takes for SSM archs."""
    arch, plan, params = setup
    eng = ServeEngine.from_plan(plan, params, arch=arch,
                                kv_prefill_mode="disagg")
    assert eng.prefill_mode == "inline"
    p = (np.arange(17, dtype=np.int32) * 3 + 1) % arch.vocab_size
    eng.submit(p, max_new_tokens=4)
    [r] = eng.run_until_idle(256)
    assert len(r.out_tokens) == 4
    json.dumps(eng.telemetry())     # fleet=None branch serializes too


def test_plan_records_prefill_mode(setup):
    """The data-organization pass records the interference verdict in
    the plan estimates; the full-size 32k deployment flips to disagg
    while the reduced test plan stays inline."""
    _, plan, _ = setup
    est = plan.estimates
    assert est.get("kv_prefill_mode") == "inline"
    assert est.get("kv_prefill_chunk", 0) >= 1
    full = specialize("qwen3-8b", "decode_32k")
    assert full.estimates.get("kv_prefill_mode") == "disagg"
    assert full.estimates.get("kv_prefill_stall_ticks", 0.0) > 8.0
