"""Integration: lowered steps execute; trainer fits; checkpoint-restart
replays bit-identically; serving engine completes requests; MoE paths
agree.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_arch
from repro.core.pipeline import specialize
from repro.core.passes.lowering import lower_serve_step, lower_train_step
from repro.launch.mesh import make_host_mesh
from repro.models import RunCfg, init_params, synthetic_batch
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

SMOKE = ShapeConfig("smoke", "train", 64, 4)
DEC = ShapeConfig("smoke_dec", "decode", 64, 4)


def _plan(arch, shape, mesh):
    return specialize(arch, shape, mesh_axes=tuple(mesh.axis_names),
                      mesh_shape=tuple(mesh.devices.shape))


@pytest.mark.parametrize("name", ["qwen3-8b", "granite-moe-1b-a400m",
                                  "mamba2-2.7b", "hymba-1.5b"])
def test_lowered_train_step_executes(name):
    mesh = make_host_mesh()
    arch = get_arch(name).reduced()
    plan = _plan(arch, SMOKE, mesh)
    step = lower_train_step(plan, arch, SMOKE, mesh,
                            OptConfig(total_steps=10))
    fn = step.jit()
    tr = Trainer(plan, mesh, TrainerConfig(n_steps=1, ckpt_every=0),
                 arch=arch, shape=SMOKE)
    state = tr.init_state()
    batch = synthetic_batch(arch, SMOKE, jax.random.PRNGKey(1))
    state, metrics = fn(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-2.7b"])
def test_lowered_serve_step_executes(name):
    mesh = make_host_mesh()
    arch = get_arch(name).reduced()
    plan = _plan(arch, DEC, mesh)
    step = lower_serve_step(plan, arch, DEC, mesh)
    fn = step.jit()
    from repro.core.passes.lowering import _padded, init_plan_cache
    params = init_params(arch, jax.random.PRNGKey(0), *_padded(plan))
    # the cache must match the plan's residency decision (a decode plan
    # for an attention arch now carries a paged block pool)
    cache = init_plan_cache(plan, arch, DEC.global_batch, DEC.seq_len)
    if "block_tbl" in cache:
        assert plan.estimates["kv_residency"] == "paged"
        nb = cache["block_tbl"].shape[1]
        cache["block_tbl"] = jnp.arange(
            DEC.global_batch * nb, dtype=jnp.int32).reshape(
                DEC.global_batch, nb)
    tokens = {"tokens": jnp.ones((DEC.global_batch, 1), jnp.int32)}
    logits, cache = fn(params, cache, tokens)
    assert logits.shape[0] == DEC.global_batch
    assert cache["pos"].shape == (DEC.global_batch,)   # per-slot positions
    assert np.all(np.asarray(cache["pos"]) == 1)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_train_step_memorizes_fixed_batch():
    """Synthetic random targets sit at the log(V) CE floor, so learning is
    only visible by memorizing one FIXED batch — which the full lowered
    step (microbatching/remat/optimizer) must be able to do."""
    mesh = make_host_mesh()
    arch = dataclasses.replace(get_arch("qwen3-8b").reduced(), vocab_size=64)
    plan = _plan(arch, SMOKE, mesh)
    tr = Trainer(plan, mesh, TrainerConfig(n_steps=1, ckpt_every=0),
                 opt_cfg=OptConfig(peak_lr=3e-3, warmup_steps=5,
                                   total_steps=60, weight_decay=0.0),
                 arch=arch, shape=SMOKE)
    state = tr.init_state()
    batch = synthetic_batch(arch, SMOKE, jax.random.PRNGKey(7))
    step = tr.step_fn
    losses = []
    for _ in range(40):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_checkpoint_restart_bitexact(tmp_path):
    """Interrupted training == uninterrupted training (replayed data)."""
    mesh = make_host_mesh()
    arch = get_arch("qwen3-8b").reduced()
    plan = _plan(arch, SMOKE, mesh)
    mk = lambda: Trainer(
        plan, mesh,
        TrainerConfig(n_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                      log_every=100),
        opt_cfg=OptConfig(total_steps=8), arch=arch, shape=SMOKE)

    # uninterrupted run
    t0 = mk()
    t0.fit()
    ref = [h["loss"] for h in t0.history]

    # interrupted at 4, resumed from the checkpoint
    t1 = mk()
    t1.cfg = dataclasses.replace(t1.cfg, ckpt_dir=str(tmp_path / "b"))
    t1.ckpt = type(t0.ckpt)(tmp_path / "b")
    t1.fit(n_steps=4)
    state, step = t1.resume()
    assert step == 4
    t1.fit(state=state, start_step=step, n_steps=8)
    got = [h["loss"] for h in t1.history if h["step"] >= 4]
    np.testing.assert_allclose(got, ref[4:], rtol=1e-5)


def test_moe_paths_agree():
    """gshard_einsum vs shard_map_alltoall on a 1-device mesh."""
    mesh = make_host_mesh(model=1)
    arch = get_arch("granite-moe-1b-a400m").reduced()
    params = init_params(arch, jax.random.PRNGKey(0))
    batch = synthetic_batch(arch, SMOKE, jax.random.PRNGKey(1))
    from repro.models import train_loss
    c1 = RunCfg(block_q=32, moe_impl="gshard_einsum")
    c2 = RunCfg(block_q=32, moe_impl="shard_map_alltoall", mesh=mesh,
                data_axes=("data",), model_axis="model")
    l1, _ = jax.jit(lambda p, b: train_loss(arch, p, b, c1))(params, batch)
    l2, _ = jax.jit(lambda p, b: train_loss(arch, p, b, c2))(params, batch)
    assert abs(float(l1) - float(l2)) < 0.05, (float(l1), float(l2))


def test_serve_engine_completes():
    from repro.serve import ServeEngine
    arch = get_arch("qwen3-8b").reduced()
    params = init_params(arch, jax.random.PRNGKey(0))
    eng = ServeEngine(arch, params, RunCfg(block_q=16), max_batch=2,
                      max_len=48)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, arch.vocab_size, (12,)), max_new_tokens=6)
    done = eng.run_until_idle(max_ticks=64)
    assert len(done) == 3
    assert all(len(r.out_tokens) == 6 for r in done)
    assert all(r.t_done >= r.t_first >= r.t_submit for r in done)


def test_serve_engine_greedy_matches_prefill_oracle():
    """First generated token == argmax of the prefill logits."""
    from repro.models import lm, prefill
    from repro.serve import ServeEngine
    arch = get_arch("qwen3-8b").reduced()
    params = init_params(arch, jax.random.PRNGKey(0))
    cfg = RunCfg(block_q=16)
    prompt = np.arange(10, dtype=np.int32) % arch.vocab_size
    logits, _ = prefill(arch, params, {"tokens": prompt[None]}, cfg,
                        max_len=32)
    want = int(jnp.argmax(logits[0, :arch.vocab_size]))
    eng = ServeEngine(arch, params, cfg, max_batch=1, max_len=32)
    eng.submit(prompt, max_new_tokens=2)
    done = eng.run_until_idle(max_ticks=8)
    assert done and done[0].out_tokens[0] == want
