"""Victim preemption, grow-on-demand grants, and overload degradation.

The robustness contract this file pins: exhaustion of the paged block
pool is a *handled* condition.  A mid-decode grant failure walks the
grant → migrate → preempt ladder; an evicted request is re-admitted by
re-prefilling prompt+generated and must emit **exactly** the tokens of
an uninterrupted run (greedy argmax; the re-prefill rebuilds the same
KV rows, so the decode picks up bit-where it left off).  Past the retry
budget or a missed deadline the request is shed with ``Request.error``
set; past the preemption-rate threshold ``submit()`` raises the typed
:class:`OverloadError` instead of hanging the queue.  The acceptance
matrix runs the eviction + re-prefill cycle across the attention, SSM,
and hybrid architectures — preemption must round-trip *every* per-slot
state the template holds (KV blocks, SSM state, conv tail), not just
the attention cache.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.models import lm
from repro.models.lm import RunCfg
from repro.serve.engine import (OverloadError, PreemptionPolicy, Request,
                                ServeEngine)

CFG = RunCfg(block_q=16, ssd_chunk=16)

ARCHS = ["qwen3-8b", "mamba2-2.7b", "hymba-1.5b"]

_PARAMS_CACHE: dict = {}


def _arch_params(name):
    if name not in _PARAMS_CACHE:
        arch = get_arch(name).reduced()
        _PARAMS_CACHE[name] = (arch, lm.init_params(arch,
                                                    jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[name]


def _prompts(arch):
    return [np.arange(5, dtype=np.int32) % arch.vocab_size,
            (np.arange(11, dtype=np.int32) * 3) % arch.vocab_size,
            (np.arange(8, dtype=np.int32) * 7 + 2) % arch.vocab_size]


def _oracle(arch, params, prompts, new):
    out = []
    for p in prompts:
        eng = ServeEngine(arch, params, CFG, max_batch=1, max_len=32)
        eng.submit(p, max_new_tokens=new)
        out.append(eng.run_until_idle(max_ticks=64)[0].out_tokens)
    return out


# ---------------- acceptance matrix: eviction + re-prefill ------------

@pytest.mark.parametrize("name", ARCHS)
def test_preemption_token_identity_per_arch(name):
    """>=1 forced eviction + re-prefill per arch: every finished request
    is token-identical to the uninterrupted sequential oracle, and the
    pool drains whole.  Paged grant-mode engines for attention archs
    (the autonomous ladder exists there); the SSM-only arch is evicted
    through the public hook — its per-slot recurrent state is exactly
    what re-prefill must reconstruct."""
    arch, params = _arch_params(name)
    prompts = _prompts(arch)
    want = _oracle(arch, params, prompts, 6)

    kw = {}
    if arch.has_attention:
        kw = dict(kv_residency="paged", kv_block_len=8, kv_n_blocks=4,
                  kv_admission="grant")
    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                      preemption=PreemptionPolicy(max_preemptions=16,
                                                  backoff_base_ticks=1),
                      **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    forced = 0
    ticks = 0
    while (eng.pending or eng.active or eng.preempted) and ticks < 400:
        if eng.active and ticks in (2, 9):
            # evict whoever has made the most progress — the hardest
            # re-prefill (longest retained generation)
            victim = max(eng.active.values(),
                         key=lambda r: len(r.out_tokens))
            eng.preempt(victim.rid)
            forced += 1
        eng.step()
        ticks += 1
    assert forced >= 1 and eng.preemptions >= forced
    assert not (eng.pending or eng.active or eng.preempted)
    assert not eng.shed, [r.error for r in eng.shed]
    got = {r.prompt.tobytes(): r.out_tokens for r in eng.finished}
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w, (name, got[p.tobytes()], w)
    stats = eng.block_stats()
    assert stats["free"] == stats["total"], "blocks leaked"
    for r in eng.finished:
        assert not r.blocks


def test_natural_exhaustion_preempts_and_recovers():
    """A pool too small for concurrent growth: the engine preempts on
    its own (no injected faults), and the outcome is still
    token-identical with zero leaks."""
    arch, params = _arch_params("qwen3-8b")
    prompts = _prompts(arch)
    want = _oracle(arch, params, prompts, 6)
    # 3 blocks of 8 rows: the 11-token prompt alone peaks at 3 blocks,
    # so two concurrent requests MUST collide mid-decode
    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=3,
                      kv_admission="grant",
                      preemption=PreemptionPolicy(max_preemptions=8,
                                                  backoff_base_ticks=1))
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    done = eng.run_until_idle(max_ticks=400)
    assert eng.preemptions >= 1, "tight pool never forced an eviction"
    got = {r.prompt.tobytes(): r.out_tokens for r in done}
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w
    assert eng.block_stats()["free"] == 3


def test_preempted_request_state_is_host_side():
    """While parked, an evicted request holds no slot, no blocks, and
    its generated tokens — the whole resumption state is the host-side
    token list."""
    arch, params = _arch_params("qwen3-8b")
    eng = ServeEngine(arch, params, CFG, max_batch=1, max_len=32,
                      kv_residency="paged", kv_block_len=8,
                      kv_admission="grant",
                      preemption=PreemptionPolicy(backoff_base_ticks=8))
    rid = eng.submit(_prompts(arch)[0], max_new_tokens=8)
    for _ in range(3):
        eng.step()
    tokens_so_far = list(eng.active[0].out_tokens)
    eng.preempt(rid)
    assert len(eng.preempted) == 1
    parked = eng.preempted[0]
    assert parked.request.rid == rid
    assert parked.request.slot == -1 and not parked.request.blocks
    assert parked.request.out_tokens == tokens_so_far
    assert parked.not_before_tick > eng.tick, "backoff must delay re-entry"
    # feed = prompt + generated[:-1]; the last token is the next decode's
    # input, not a KV row to rebuild
    assert len(parked.request.feed_tokens) \
        == len(parked.request.prompt) + len(tokens_so_far) - 1
    assert eng.block_stats()["free"] == eng.block_stats()["total"]


def test_tiered_park_resumes_without_reprefill():
    """With a host tier behind the pool, a preemption victim parks its
    KV blocks in host DRAM and resumes by promoting them back — the
    re-admission must NOT re-prefill (``prefill_calls`` frozen across
    the park/resume cycle; the legacy stateless park re-prefills), and
    the tokens still equal the uninterrupted oracle."""
    arch, params = _arch_params("qwen3-8b")
    prompts = _prompts(arch)
    want = _oracle(arch, params, prompts, 6)
    eng = ServeEngine(arch, params, CFG, max_batch=3, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=9,
                      kv_admission="grant", kv_host_blocks=16,
                      preemption=PreemptionPolicy(max_preemptions=8,
                                                  backoff_base_ticks=1))
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    for _ in range(3):
        eng.step()                   # all three admitted, mid-decode
    calls = eng.prefill_calls
    victim = max(eng.active.values(), key=lambda r: len(r.out_tokens))
    eng.preempt(victim.rid)
    parked = eng.preempted[0]
    assert parked.parked_state is not None, "victim did not park with state"
    spilled = parked.parked_state.get("kv_host")
    assert spilled, "no KV blocks went to the host tier"
    assert all(b >= eng.n_blocks for b in parked.request.blocks), \
        "parked request still holds HBM block ids"
    eng.run_until_idle(max_ticks=200)
    assert eng.prefill_calls == calls, \
        "tiered resume re-prefilled instead of promoting"
    assert eng.preemptions == 1 and not eng.shed
    got = {r.prompt.tobytes(): r.out_tokens for r in eng.finished}
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w
    assert eng._alloc.promotes >= len(spilled)
    eng.drop_block_cache()
    st = eng.block_stats()
    assert st["free"] == st["total"], "HBM blocks leaked"
    assert st["host_free"] == st["host_total"], "host blocks leaked"


# ---------------- migration (sub-pool rebalancing) --------------------

def test_migration_rebalances_to_idle_sub_pool():
    """Two same-length requests land in one sub-pool; when it drains,
    one slot migrates — blocks, table row, per-slot state — to the
    idling donor sub-pool instead of evicting anyone, and the
    slot→sub-pool contract holds on every tick."""
    arch, params = _arch_params("qwen3-8b")
    p1 = (np.arange(8, dtype=np.int32) * 7 + 2) % arch.vocab_size
    p2 = (np.arange(8, dtype=np.int32) * 3 + 1) % arch.vocab_size
    want = _oracle(arch, params, [p1, p2], 9)
    # 2 sub-pools x 3 blocks; slots {0,1}->g0, {2,3}->g1.  Both prompts
    # bucket into one admission (same length) and grab g0's slots; both
    # cross a block boundary on the first decode tick, and g0 has one
    # spare block for two askers.
    eng = ServeEngine(arch, params, CFG, max_batch=4, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=6,
                      kv_admission="grant", kv_pool_groups=2,
                      preemption=PreemptionPolicy(max_preemptions=8))
    eng.submit(p1, max_new_tokens=9)
    eng.submit(p2, max_new_tokens=9)
    while eng.pending or eng.active or eng.preempted:
        eng.step()
        for slot, r in eng.active.items():
            g = eng._slot_group(slot)
            assert all(eng._alloc.group_of(b) == g for b in r.blocks), \
                "migrated slot holds foreign blocks"
        assert eng.tick < 200, "stuck"
    assert eng.migrations >= 1, "hot/idle split never migrated"
    assert eng.preemptions == 0, "migration should have avoided eviction"
    got = {r.prompt.tobytes(): r.out_tokens for r in eng.finished}
    assert got[p1.tobytes()] == want[0] and got[p2.tobytes()] == want[1]
    assert eng.block_stats()["free"] == 6


def test_kv_pool_groups_validation():
    arch, params = _arch_params("qwen3-8b")
    with pytest.raises(ValueError, match="kv_pool_groups"):
        ServeEngine(arch, params, CFG, max_batch=3, max_len=32,
                    kv_residency="paged", kv_block_len=8, kv_n_blocks=6,
                    kv_pool_groups=2)
    with pytest.raises(ValueError, match="kv_admission"):
        ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                    kv_admission="lazy")


# ---------------- overload: shed, don't hang --------------------------

def test_overload_sheds_with_typed_error():
    """Sustained demand past the pool's thrash point trips the
    preemption-rate threshold: submit() raises OverloadError, already-
    doomed requests are shed with errors (holding nothing), and the
    engine still drains clean instead of hanging."""
    arch, params = _arch_params("qwen3-8b")
    p5 = np.arange(5, dtype=np.int32) % arch.vocab_size
    pol = PreemptionPolicy(max_preemptions=2, backoff_base_ticks=1,
                           shed_window_ticks=8, shed_rate=0.25)
    eng = ServeEngine(arch, params, CFG, max_batch=4, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=3,
                      kv_admission="grant", preemption=pol)
    with pytest.raises(OverloadError, match="shedding load"):
        for _ in range(60):
            eng.submit(p5, max_new_tokens=12)
            eng.step()
    assert eng.overloaded()
    eng.run_until_idle(max_ticks=600)       # must NOT hang or raise
    assert eng.shed, "thrashing load should shed someone"
    for r in eng.shed:
        assert r.error and not r.blocks and not r.done
    assert eng.finished, "overload must degrade, not stop all service"
    assert eng.block_stats()["free"] == eng.block_stats()["total"]
    assert eng.pressure_stats()["preemptions"] == eng.preemptions > 0


def test_deadline_sheds_pending_and_spares_victims():
    arch, params = _arch_params("qwen3-8b")
    p = _prompts(arch)[0]
    eng = ServeEngine(arch, params, CFG, max_batch=1, max_len=32)
    rid = eng.submit(p, max_new_tokens=4, deadline_s=-1.0)   # already late
    ok = eng.submit(p, max_new_tokens=4)
    eng.run_until_idle(max_ticks=32)
    assert [r.rid for r in eng.shed] == [rid]
    assert "deadline" in eng.shed[0].error
    assert [r.rid for r in eng.finished] == [ok]
    # victim selection: deadline'd requests are spared while any
    # deadline-free candidate exists; among the deadline-free, fewest
    # tokens generated goes first
    import time
    now = time.time()
    a = Request(0, p, out_tokens=[1, 2, 3], deadline=now + 5)
    b = Request(1, p, out_tokens=[1, 2])
    c = Request(2, p, out_tokens=[1, 2, 3, 4])
    pol = PreemptionPolicy()
    assert pol.pick_victim([a, b, c], now) is b
    assert pol.pick_victim([a, c], now) is c
    # among deadline'd candidates: latest deadline evicts first
    d = Request(3, p, out_tokens=[1, 2, 3], deadline=now + 50)
    assert pol.pick_victim([a, d], now) is d


def test_run_until_idle_raises_loud_timeout():
    """Tick exhaustion with live work names the stuck rids — a
    deadlocked admission loop must not look like success."""
    arch, params = _arch_params("qwen3-8b")
    eng = ServeEngine(arch, params, CFG, max_batch=1, max_len=64)
    rid = eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=40)
    with pytest.raises(TimeoutError, match=f"rids \\[{rid}\\]"):
        eng.run_until_idle(max_ticks=3)


# ---------------- the plan is the deployment contract -----------------

def test_plan_records_admission_mode_and_headroom():
    """Single-host worst-case pools reserve; data-sharded reclamation-
    bet pools grant — recorded in the plan estimates with a decision-log
    entry, surfaced by `plan show`, and honored by from_plan (with an
    explicit override as the ops escape hatch)."""
    from repro.configs import ShapeConfig
    from repro.core.pipeline import specialize
    from repro.launch.plan import _DECISION_KEYS

    arch = get_arch("qwen3-8b").reduced()
    plan = specialize(arch, ShapeConfig("pre_r", "decode", 32, 2),
                      mesh_axes=("data", "model"), mesh_shape=(1, 1))
    assert plan.estimates["kv_admission"] == "reserve"
    assert plan.estimates["kv_preempt_headroom"] >= 0
    assert any(s == "kv_admission" for _, s, _, _ in plan.log)
    assert "kv_admission" in _DECISION_KEYS
    assert "kv_preempt_headroom" in _DECISION_KEYS

    gplan = specialize(arch, ShapeConfig("pre_g", "decode", 256, 8),
                       mesh_axes=("data", "model"), mesh_shape=(2, 2))
    assert gplan.estimates["kv_admission"] == "grant"
    why = [w for _, s, _, w in gplan.log if s == "kv_admission"][-1]
    assert "reclamation" in why

    params = lm.init_params(arch, jax.random.PRNGKey(0),
                            *plan.padded_sizes())
    eng = ServeEngine.from_plan(plan, params, arch=arch)
    assert eng.kv_admission == "reserve"
    eng = ServeEngine.from_plan(plan, params, arch=arch,
                                kv_admission="grant")
    assert eng.kv_admission == "grant"


def test_reserve_mode_never_walks_the_ladder():
    """Reserve admission (the plan default on worst-case pools) must
    keep PR-4/5 behavior bit-for-bit: full budget up front, no grants,
    no preemptions, serialization on exhaustion."""
    arch, params = _arch_params("qwen3-8b")
    prompts = _prompts(arch)
    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                      kv_residency="paged", kv_block_len=16)
    assert eng.kv_admission == "reserve"
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    done = eng.run_until_idle(max_ticks=64)
    assert len(done) == 3
    assert eng.preemptions == 0 and eng.migrations == 0
    assert eng.grant_denials == 0
    assert not eng.shed and not eng.preempted
