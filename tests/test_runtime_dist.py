"""Fault tolerance, stragglers, compression collectives, hlo_stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.collectives import compressed_psum, ef_compress
from repro.runtime import (DeadlineSkipper, HealthMonitor, RestartPolicy,
                           StepTimer, elastic_mesh)


def test_health_monitor():
    hm = HealthMonitor(timeout_s=10)
    hm.beat(0, t=100.0)
    hm.beat(1, t=105.0)
    assert hm.dead_hosts(now=109.0) == []
    assert hm.dead_hosts(now=112.0) == [0]
    assert not hm.healthy(now=130.0)


def test_health_monitor_expect_flags_dead_on_arrival():
    """A worker that dies between spawn and its first heartbeat must
    still show up dead: ``expect`` starts the deadline clock, so a
    beats-only scan can't report it healthy forever."""
    hm = HealthMonitor(timeout_s=10)
    hm.expect([7], t=100.0)
    assert hm.dead_hosts(now=105.0) == []      # still in its grace window
    assert hm.dead_hosts(now=111.0) == [7]     # never beat: DOA
    hm.beat(7, t=112.0)
    assert hm.dead_hosts(now=120.0) == []      # late first beat clears it
    hm.expect([7], t=200.0)                    # respawn: stale beat dropped
    assert hm.dead_hosts(now=205.0) == []
    assert hm.dead_hosts(now=211.0) == [7]
    hm.forget(7)
    assert hm.dead_hosts(now=500.0) == []      # retired on purpose


def test_restart_policy_backoff_and_budget():
    rp = RestartPolicy(max_restarts=3, backoff_base_s=1.0, backoff_cap_s=10)
    ds = [rp.next_delay() for _ in range(3)]
    assert ds == [1.0, 2.0, 4.0]
    with pytest.raises(RuntimeError):
        rp.next_delay()


def test_restart_policy_zero_budget_and_cap():
    """max_restarts=0 refuses the first restart (the degrade-now
    config); the backoff series clamps at the cap instead of doubling
    unbounded."""
    with pytest.raises(RuntimeError, match="restart budget exhausted"):
        RestartPolicy(max_restarts=0).next_delay()
    rp = RestartPolicy(max_restarts=6, backoff_base_s=1.0, backoff_cap_s=3.0)
    assert [rp.next_delay() for _ in range(6)] == [1.0, 2.0, 3.0, 3.0,
                                                  3.0, 3.0]


def test_elastic_mesh_preserves_model_axis():
    m = elastic_mesh(1, model_parallel=1)
    assert m.devices.shape == (1, 1)
    with pytest.raises(RuntimeError):
        elastic_mesh(0, model_parallel=2)


def test_elastic_mesh_typed_errors_name_the_shortfall():
    """Both failure modes are typed with actionable messages: too few
    surviving devices for one TP group, and a survivor count that
    overstates what this process can actually see."""
    with pytest.raises(RuntimeError, match="cannot host"):
        elastic_mesh(1, model_parallel=2)
    # claims 16 survivors but only 1 CPU device is visible here
    with pytest.raises(RuntimeError, match="visible"):
        elastic_mesh(16, model_parallel=2)


def test_step_timer_flags_stragglers():
    t = StepTimer()
    for _ in range(20):
        t.observe(0.1)
    assert not t.is_straggler(0.11)
    assert t.is_straggler(0.5)


def test_deadline_skipper_bounded():
    t = StepTimer()
    for _ in range(20):
        t.observe(0.1)
    sk = DeadlineSkipper(deadline_factor=2.0, max_skips=2)
    assert sk.should_skip(1, waited_s=0.5, timer=t)
    assert sk.should_skip(2, waited_s=0.5, timer=t)
    assert not sk.should_skip(3, waited_s=0.5, timer=t)   # budget exhausted
    assert sk.skipped_steps == [1, 2]


def test_step_guard_recovers_from_failure(tmp_path):
    """Inject a failure mid-run; the guard restores and completes."""
    from repro.runtime.fault import StepGuard

    saves = {}

    def make_step(mesh):
        def step(state, batch):
            new = {"x": state["x"] + batch}
            saves[int(new["x"])] = new
            return new, {"x": new["x"]}
        return step

    def restore(mesh):
        best = max(saves)
        return saves[best], int(best)

    calls = {"n": 0}

    def injector(step):
        if step == 3 and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("simulated device failure")

    guard = StepGuard(make_step, restore, model_parallel=1)
    state, step, _ = guard.run({"x": jnp.asarray(0)},
                               batches=lambda s: jnp.asarray(1),
                               n_steps=6, fail_injector=injector)
    assert step == 6
    assert int(state["x"]) == 6
    assert len(guard.events) == 1


def test_step_guard_replay_is_deterministic(tmp_path):
    """Replay after restore must be bit-exact: the batch stream is a
    pure function of the step index, so a run that failed and replayed
    ends in the same state as one that never failed."""
    from repro.runtime.fault import StepGuard

    def run(inject):
        saves = {}

        def make_step(mesh):
            def step(state, batch):
                new = {"x": jnp.tanh(state["x"] * 0.9 + batch)}
                saves[len(saves)] = (new, None)
                return new, {}
            return step

        def restore(mesh):
            # restore from the checkpoint taken at step 2
            return ckpt[0], ckpt[1]

        ckpt = [None, 0]
        calls = {"n": 0}

        def stepper(s):
            return jnp.asarray(np.sin(s + 1), jnp.float32)

        def injector(step):
            if step == 2 and ckpt[0] is None:
                ckpt[0] = dict(state_box[0])
                ckpt[1] = step
            if inject and step == 4 and calls["n"] == 0:
                calls["n"] += 1
                raise RuntimeError("simulated failure")

        guard = StepGuard(make_step, restore, model_parallel=1)
        state_box = [{"x": jnp.asarray(0.5)}]

        def tracked_batches(s):
            b = stepper(s)
            return b

        # wrap step to keep a live view for the injector's checkpoint
        inner_make = guard.make_step

        def make_step_tracking(mesh):
            fn = inner_make(mesh)

            def step(state, batch):
                out, m = fn(state, batch)
                state_box[0] = out
                return out, m
            return step

        guard.make_step = make_step_tracking
        state, step, _ = guard.run(state_box[0], tracked_batches,
                                   n_steps=6, fail_injector=injector)
        assert step == 6
        assert len(guard.events) == (1 if inject else 0)
        return float(state["x"])

    assert run(inject=True) == run(inject=False)


# ---------------- compression collectives ----------------

def test_compressed_psum_single_axis():
    mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        y, err = compressed_psum(x, "data")
        return y, err

    x = jnp.linspace(-3, 3, 64)
    y, err = jax.shard_map(f, mesh=mesh, in_specs=P(None),
                           out_specs=(P(None), P(None)))(x)
    assert float(jnp.abs(y - x).max()) < 3 / 127 + 1e-6


def test_ef_compress_reduces_bias_over_steps():
    """Constant input: cumulative delivered ≈ cumulative true signal."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal(512),
                    jnp.float32) * 0.01 + 1.7
    err = None
    total = jnp.zeros_like(x)
    for i in range(16):
        xh, err = ef_compress(x, err)
        total = total + xh
    rel = float(jnp.abs(total / 16 - x).max() / jnp.abs(x).max())
    assert rel < 0.005


# ---------------- hlo_stats trip-count correction ----------------

def test_hlo_stats_counts_loop_trips():
    from repro.analysis.hlo_stats import parse_hlo

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None
        x, _ = jax.lax.scan(body, x, w)
        return x

    w = jnp.zeros((6, 32, 32))
    x = jnp.zeros((8, 32))
    txt = jax.jit(f).lower(w, x).compile().as_text()
    stats = parse_hlo(txt, 1)
    expect = 6 * 2 * 8 * 32 * 32          # 6 scan iterations
    assert abs(stats["flops"] - expect) / expect < 0.05
