"""Optimizer: convergence, precision ladder, schedules, clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptConfig, apply_updates, global_norm,
                         init_opt_state, lr_schedule)


def _fit_quadratic(cfg, steps=200):
    """Minimize ||Wx - y||^2; returns final loss."""
    key = jax.random.PRNGKey(0)
    W = {"w": jax.random.normal(key, (8, 8)) * 0.5}
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    Wtrue = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    y = x @ Wtrue

    def loss_fn(p):
        return jnp.mean(jnp.square(x @ p["w"] - y))

    state = init_opt_state(W, cfg)
    step = jax.jit(lambda p, s: (
        lambda g: apply_updates(p, g, s, cfg))(jax.grad(loss_fn)(p)))
    for _ in range(steps):
        W, state, _ = step(W, state)
    return float(loss_fn(W))


def test_adamw_converges():
    cfg = OptConfig(peak_lr=5e-2, warmup_steps=10, total_steps=200,
                    weight_decay=0.0)
    assert _fit_quadratic(cfg) < 1e-2


def test_bf16_moments_still_converge():
    cfg = OptConfig(peak_lr=5e-2, warmup_steps=10, total_steps=200,
                    weight_decay=0.0, moment_dtype="bfloat16")
    assert _fit_quadratic(cfg) < 5e-2


def test_no_master_weights_with_bf16_params():
    cfg = OptConfig(peak_lr=5e-2, warmup_steps=10, total_steps=300,
                    weight_decay=0.0, master_weights=False)
    key = jax.random.PRNGKey(0)
    W = {"w": (jax.random.normal(key, (8, 8)) * 0.5).astype(jnp.bfloat16)}
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8)).astype(jnp.bfloat16)
    y = x @ jax.random.normal(jax.random.PRNGKey(2), (8, 8)).astype(jnp.bfloat16)

    def loss_fn(p):
        return jnp.mean(jnp.square((x @ p["w"] - y).astype(jnp.float32)))

    state = init_opt_state(W, cfg)
    assert "master" not in state
    step = jax.jit(lambda p, s: (
        lambda g: apply_updates(p, g, s, cfg))(jax.grad(loss_fn)(p)))
    l0 = float(loss_fn(W))
    for _ in range(300):
        W, state, _ = step(W, state)
    assert W["w"].dtype == jnp.bfloat16
    assert float(loss_fn(W)) < 0.25 * l0    # stochastic rounding still learns


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=100, total_steps=1000)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 50, 100, 500, 1000)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-6            # mid-warmup
    assert abs(lrs[2] - 1e-3) < 1e-6            # peak
    assert lrs[3] < lrs[2]                      # decaying
    assert abs(lrs[4] - 1e-4) < 1e-5            # floor = 10% of peak


def test_grad_clipping_bounds_update():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=0, total_steps=10,
                    grad_clip=1.0, weight_decay=0.0)
    W = {"w": jnp.zeros((4, 4))}
    g = {"w": jnp.full((4, 4), 1e6)}
    state = init_opt_state(W, cfg)
    W2, _, metrics = apply_updates(W, g, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    # post-clip Adam step magnitude is bounded by lr
    assert float(jnp.abs(W2["w"]).max()) <= 1.05


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5
