"""Architecture configs: counts, registry, applicability matrix."""

import pytest

from repro.configs import (SHAPES, all_archs, all_cells, applicable,
                           get_arch, get_shape)

# labelled sizes from the assignment (total params, billions)
LABELED = {
    "hubert-xlarge": (0.9, 1.1),
    "qwen2-vl-72b": (70, 75),
    "mamba2-2.7b": (2.6, 2.8),
    "granite-moe-1b-a400m": (1.2, 1.5),
    "llama4-maverick-400b-a17b": (380, 420),
    "qwen3-8b": (7.5, 8.5),
    "deepseek-7b": (6.5, 7.2),
    "deepseek-coder-33b": (32, 34.5),
    "minitron-8b": (7.3, 8.6),
    "hymba-1.5b": (1.4, 1.8),
}

ACTIVE = {
    "granite-moe-1b-a400m": (0.35, 0.5),
    "llama4-maverick-400b-a17b": (16, 18.5),
}


@pytest.mark.parametrize("name", all_archs())
def test_param_count_matches_label(name):
    lo, hi = LABELED[name]
    n = get_arch(name).param_count() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.2f}B outside [{lo},{hi}]"


@pytest.mark.parametrize("name", sorted(ACTIVE))
def test_active_params(name):
    lo, hi = ACTIVE[name]
    n = get_arch(name).active_param_count() / 1e9
    assert lo <= n <= hi, f"{name}: active {n:.2f}B outside [{lo},{hi}]"


def test_cells_total_40():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 9          # DESIGN.md §5
    assert len(runnable) == 31
    for _, _, ok, why in skipped:
        assert why


def test_encoder_skips_decode():
    a = get_arch("hubert-xlarge")
    assert not applicable(a, SHAPES["decode_32k"])[0]
    assert not applicable(a, SHAPES["long_500k"])[0]
    assert applicable(a, SHAPES["prefill_32k"])[0]


def test_long_context_only_subquadratic():
    for name in all_archs():
        a = get_arch(name)
        ok, _ = applicable(a, SHAPES["long_500k"])
        assert ok == a.sub_quadratic or a.is_encoder and not ok


@pytest.mark.parametrize("name", all_archs())
def test_reduced_configs_are_small(name):
    r = get_arch(name).reduced()
    assert r.d_model <= 128 and r.n_layers <= 4
    assert r.param_count() < 5e7
    assert r.family == get_arch(name).family


def test_unknown_names_raise():
    with pytest.raises(KeyError):
        get_arch("nope")
    with pytest.raises(KeyError):
        get_shape("nope")
