"""Paged KV residency: block-pool kernels vs oracles, the plan decision,
and the `repro plan` CLI.

The paged contract: attention/append over (pool, block table) must equal
the dense computation over the gathered view (`ref.paged_gather_ref`),
for every implementation — the XLA gather path, the scalar-prefetch
Pallas kernel, and the flash-decode paged combine (single-shard here;
the pool-sharded shard_map run lives in test_multidevice).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.models import lm
from repro.models.attention import attention_decode_paged


def _pool_case(key, B=3, H=4, K=2, D=16, bl=8, N=10, nb=4, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    kp = jax.random.normal(ks[1], (N, bl, K, D)).astype(dtype)
    vp = jax.random.normal(ks[2], (N, bl, K, D)).astype(dtype)
    kn = jax.random.normal(ks[3], (B, 1, K, D)).astype(dtype)
    vn = jax.random.normal(ks[4], (B, 1, K, D)).astype(dtype)
    # staggered tables: unassigned tails, non-contiguous blocks
    tbl = jnp.asarray([[0, 3, 7, -1], [5, 1, -1, -1], [2, 4, 6, 8]][:B],
                      jnp.int32)
    cl = jnp.asarray([17, 9, 32][:B], jnp.int32)
    return q, kp, vp, kn, vn, tbl, cl


def test_paged_gather_ref_dense_equivalence():
    """The gather oracle really is the dense view: scattering a dense
    cache into blocks and gathering back is the identity (valid rows)."""
    key = jax.random.PRNGKey(0)
    B, S, K, D, bl = 2, 32, 2, 8, 8
    dense = jax.random.normal(key, (B, S, K, D))
    nb = S // bl
    # slot 0 takes blocks 0..3, slot 1 blocks 4..7
    tbl = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    pool = dense.reshape(B * nb, bl, K, D)
    got = ref.paged_gather_ref(pool, tbl)
    assert np.allclose(np.asarray(got), np.asarray(dense))
    # unassigned entries gather as zeros
    got0 = ref.paged_gather_ref(pool, jnp.full((B, nb), -1, jnp.int32))
    assert float(jnp.abs(got0).max()) == 0.0


def test_append_kv_paged_matches_ref():
    q, kp, vp, kn, vn, tbl, cl = _pool_case(jax.random.PRNGKey(1))
    pos = jnp.asarray([16, 8, 31], jnp.int32)
    got = lm.append_kv_paged(kp, kn, pos, tbl)
    want = ref.paged_append_ref(kp, kn, pos, tbl)
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))
    # a freed slot (all-unassigned row) must not write to the pool
    tbl2 = tbl.at[1].set(-1)
    got2 = lm.append_kv_paged(kp, kn, jnp.asarray([16, 0, 31]), tbl2)
    want2 = ref.paged_append_ref(kp, kn, jnp.asarray([16, 0, 31]), tbl2)
    assert np.array_equal(np.asarray(got2, np.float32),
                          np.asarray(want2, np.float32))
    assert np.array_equal(np.asarray(got2[tbl[1, 0]]), np.asarray(kp[tbl[1, 0]]))


@pytest.mark.parametrize("window", [0, 6])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_paged_decode_attention_kernel_vs_oracle(window, dtype):
    """The scalar-prefetch Pallas kernel (interpret mode) streams blocks
    via the table and matches the gather oracle exactly."""
    q, kp, vp, *_ , tbl, cl = _pool_case(jax.random.PRNGKey(2), dtype=dtype)
    got = paged_decode_attention(q, kp, vp, tbl, cache_len=cl,
                                 window=window, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, tbl, cache_len=cl,
                                          window=window)
    err = np.abs(np.asarray(got, np.float32)
                 - np.asarray(want, np.float32)).max()
    assert err < (2e-2 if dtype == jnp.bfloat16 else 1e-5), (window, err)


def test_paged_decode_attention_xla_gather_vs_oracle():
    q, kp, vp, *_, tbl, cl = _pool_case(jax.random.PRNGKey(3))
    got = attention_decode_paged(q[:, None], kp, vp, tbl, cache_len=cl)
    want = ref.paged_decode_attention_ref(q, kp, vp, tbl, cache_len=cl)
    err = np.abs(np.asarray(got[:, 0], np.float32)
                 - np.asarray(want, np.float32)).max()
    assert err < 2e-2, err


@pytest.mark.parametrize("window", [0, 6])
def test_flash_decode_paged_single_shard_vs_oracle(window):
    from repro.launch.mesh import make_host_mesh
    from repro.dist.flash_decode import flash_decode_paged
    mesh = make_host_mesh()
    q, kp, vp, kn, vn, tbl, cl = _pool_case(jax.random.PRNGKey(4),
                                            dtype=jnp.float32)
    pos = jnp.asarray([16, 8, 30], jnp.int32)
    ctx, kp2, vp2 = jax.jit(
        lambda *a: flash_decode_paged(*a, mesh=mesh))(
            q[:, None], kn, vn, kp, vp, tbl, pos, window)
    kr = ref.paged_append_ref(kp, kn, pos, tbl)
    vr = ref.paged_append_ref(vp, vn, pos, tbl)
    r = ref.paged_decode_attention_ref(q, kr, vr, tbl, cache_len=pos + 1,
                                       window=window)
    assert float(jnp.abs(ctx[:, 0] - r).max()) < 1e-5
    assert np.allclose(np.asarray(kp2), np.asarray(kr))
    assert np.allclose(np.asarray(vp2), np.asarray(vr))


def test_decode_step_paged_matches_dense_cache():
    """One lm.decode_step over a paged cache == the same step over the
    equivalent dense cache (same staggered fill), logits and appended
    rows both."""
    from repro.configs import get_arch
    from repro.models.lm import RunCfg
    cfg = RunCfg(block_q=16, ssd_chunk=16)
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(5))
    B, max_len, bl = 2, 32, 16
    plens = [5, 11]
    dense = lm.init_cache(arch, B, max_len)
    paged = lm.init_paged_cache(arch, B, max_len, bl, 2 * (max_len // bl))
    toks = []
    for slot, plen in enumerate(plens):
        p = (np.arange(plen, dtype=np.int32) * 3 + slot) % arch.vocab_size
        lg, c1 = lm.prefill(arch, params,
                            {"tokens": jnp.asarray(p[None], jnp.int32)},
                            cfg, max_len=max_len)
        for key in ("k", "v"):
            dense[key] = dense[key].at[:, slot].set(c1[key][:, 0])
        toks.append(int(jnp.argmax(lg[0, :arch.vocab_size])))
    # paged layout: slot 0 owns blocks [0, 1], slot 1 owns [2, 3]
    nb = max_len // bl
    tbl = np.asarray([[0, 1], [2, 3]], np.int32)
    for key in ("k", "v"):
        pool = dense[key].reshape(dense[key].shape[0], B * nb, bl,
                                  *dense[key].shape[3:])
        paged[key] = pool
    paged["block_tbl"] = jnp.asarray(tbl)
    pos = jnp.asarray(plens, jnp.int32)
    dense["pos"] = pos
    paged["pos"] = pos
    t = jnp.asarray(toks, jnp.int32)[:, None]
    lg_d, dense2 = lm.decode_step(arch, params, dense, {"tokens": t}, cfg)
    lg_p, paged2 = lm.decode_step(arch, params, paged, {"tokens": t}, cfg)
    err = np.abs(np.asarray(lg_d, np.float32)
                 - np.asarray(lg_p, np.float32)).max()
    assert err < 1e-3, err
    # the appended pool rows match the dense appended rows
    for key in ("k", "v"):
        dview = dense2[key].reshape(dense2[key].shape[0], B * nb, bl,
                                    *dense2[key].shape[3:])
        assert np.allclose(np.asarray(paged2[key], np.float32),
                           np.asarray(dview, np.float32))


# ---------------- cross-request block aliasing ----------------
#
# The sharing contract every paged reader must honour: a block id that
# appears in TWO slots' tables (a refcounted prefix hit) reads exactly
# like a private copy of the same rows.  Readers are pure functions of
# (pool, table) — any kernel that mutated its streamed blocks, or
# special-cased duplicate ids, would break aliased decoding.

def _aliased_vs_private_case(key, bl=8, K=2, D=16, dtype=jnp.float32):
    """Two slots share blocks {0, 3} as their 2-block prefix; slot 0
    appends into private block 7, slot 1 into private block 5.  The
    private twin duplicates the shared rows into blocks {2, 6} so slot 1
    no longer aliases slot 0."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 4, D)).astype(dtype)
    kp = jax.random.normal(ks[1], (10, bl, K, D)).astype(dtype)
    vp = jax.random.normal(ks[2], (10, bl, K, D)).astype(dtype)
    tbl_alias = jnp.asarray([[0, 3, 7, -1], [0, 3, 5, -1]], jnp.int32)
    kp_priv = kp.at[2].set(kp[0]).at[6].set(kp[3])
    vp_priv = vp.at[2].set(vp[0]).at[6].set(vp[3])
    tbl_priv = jnp.asarray([[0, 3, 7, -1], [2, 6, 5, -1]], jnp.int32)
    cl = jnp.asarray([2 * bl + 3, 2 * bl + 6], jnp.int32)
    return q, kp, vp, kp_priv, vp_priv, tbl_alias, tbl_priv, cl


def test_aliased_tables_read_identical_xla_gather():
    q, kp, vp, kpp, vpp, ta, tp, cl = _aliased_vs_private_case(
        jax.random.PRNGKey(7))
    got = attention_decode_paged(q[:, None], kp, vp, ta, cache_len=cl)
    want = attention_decode_paged(q[:, None], kpp, vpp, tp, cache_len=cl)
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))


@pytest.mark.parametrize("window", [0, 6])
def test_aliased_tables_read_identical_pallas(window):
    q, kp, vp, kpp, vpp, ta, tp, cl = _aliased_vs_private_case(
        jax.random.PRNGKey(8))
    got = paged_decode_attention(q, kp, vp, ta, cache_len=cl,
                                 window=window, interpret=True)
    want = paged_decode_attention(q, kpp, vpp, tp, cache_len=cl,
                                  window=window, interpret=True)
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))


def test_aliased_tables_read_identical_flash_and_append_private():
    """flash-decode over aliased tables matches the private twin — and
    the fused append only writes each slot's PRIVATE tail block (the
    engine's CoW barrier guarantees no slot ever appends into a block
    with refcount > 1, so appends land on distinct ids here)."""
    from repro.launch.mesh import make_host_mesh
    from repro.dist.flash_decode import flash_decode_paged
    mesh = make_host_mesh()
    q, kp, vp, kpp, vpp, ta, tp, cl = _aliased_vs_private_case(
        jax.random.PRNGKey(9))
    kn = jax.random.normal(jax.random.PRNGKey(10), (2, 1, 2, 16))
    vn = jax.random.normal(jax.random.PRNGKey(11), (2, 1, 2, 16))
    pos = cl
    run = jax.jit(lambda kk, vv, tt: flash_decode_paged(
        q[:, None], kn, vn, kk, vv, tt, pos, 0, mesh=mesh))
    ctx_a, kp2, vp2 = run(kp, vp, ta)
    ctx_p, kpp2, vpp2 = run(kpp, vpp, tp)
    assert np.array_equal(np.asarray(ctx_a, np.float32),
                          np.asarray(ctx_p, np.float32))
    # appends landed in private blocks 7 and 5 under both layouts, and
    # the shared prefix blocks 0 and 3 were left untouched
    for b in (5, 7):
        assert np.array_equal(np.asarray(kp2[b]), np.asarray(kpp2[b]))
        assert np.array_equal(np.asarray(vp2[b]), np.asarray(vpp2[b]))
    for b in (0, 3):
        assert np.array_equal(np.asarray(kp2[b]), np.asarray(kp[b]))


# ---------------- the prefix cache ----------------

def test_chain_hashes_properties():
    from repro.serve.prefix_cache import chain_hashes
    t = np.arange(40, dtype=np.int32)
    h = chain_hashes(t, 16)
    assert len(h) == 2                       # partial tail never hashed
    assert chain_hashes(t[:32], 16) == h     # pure prefix function
    # chaining: a change in block 0 reflows every downstream hash
    t2 = t.copy()
    t2[0] += 1
    h2 = chain_hashes(t2, 16)
    assert h2[0] != h[0] and h2[1] != h[1]
    # a change confined to block 1 keeps block 0's hash
    t3 = t.copy()
    t3[20] += 1
    h3 = chain_hashes(t3, 16)
    assert h3[0] == h[0] and h3[1] != h[1]
    assert chain_hashes(t[:15], 16) == []
    assert chain_hashes(t, 0) == []
    # dtype-stable: the engine hashes int64 so int32/int64 feeds agree
    assert chain_hashes(t.astype(np.int64), 16) == h


def test_prefix_cache_match_insert_evict():
    from repro.serve.prefix_cache import PrefixCache, chain_hashes
    pc = PrefixCache(groups=2)
    t = np.arange(48, dtype=np.int32)
    h = chain_hashes(t, 16)                  # 3 chained block hashes
    pc.insert(h, [4, 9, 2], group=0)
    assert len(pc) == 3
    # longest-prefix walk, and divergence stops the descent
    assert pc.match(h, group=0) == [4, 9, 2]
    assert pc.match(h[:2], group=0) == [4, 9]
    div = chain_hashes(np.concatenate([t[:16], t[:32]]), 16)
    assert pc.match(div, group=0) == [4]     # block 0 equal, then split
    # sub-pool isolation: group 1's trie is empty
    assert pc.match(h, group=1) == []
    # first-writer-wins: a second resident with the same prefix does
    # not steal the mapping (its blocks are the refcount aliases)
    pc.insert(h, [7, 8, 1], group=0)
    assert pc.match(h, group=0) == [4, 9, 2]
    # evicting a middle block prunes that entry only; the walk now
    # stops at the gap (the trailing block is unreachable by prefix)
    pc.evict([9])
    assert pc.match(h, group=0) == [4]
    pc.evict([4, 2, 99])                     # unknown ids are ignored
    assert len(pc) == 0 and pc.match(h, group=0) == []
    st = pc.stats()
    assert st["trie_blocks"] == 0


# ---------------- the plan decision ----------------

def test_kv_residency_plan_decision():
    from repro.configs import ShapeConfig
    from repro.core.pipeline import specialize
    # model-only mesh (data degree 1): one sub-pool, model-shardable
    plan = specialize("qwen2-vl-72b", "decode_32k", mesh_shape=(1, 16))
    assert plan.estimates["kv_residency"] == "paged"
    assert plan.estimates["kv_block_len"] >= 16
    assert plan.estimates["kv_n_blocks"] >= 1
    assert plan.estimates["kv_n_blocks"] % 16 == 0      # model-shardable
    assert plan.estimates["kv_pool_data_degree"] == 1
    assert plan.estimates["kv_paged_bytes"] <= plan.estimates["kv_dense_bytes"]
    assert any(s == "kv_residency" for _, s, _, _ in plan.log)
    # prefix reuse rides on every paged plan, with its headroom estimate
    # and a decision-log entry carrying the hit-rate bet
    assert plan.estimates["kv_prefix_reuse"] == "on"
    assert plan.estimates["kv_prefix_hit_headroom"] >= 0
    assert any(s == "kv_prefix_reuse" and "aliased" in why
               for _, s, _, why in plan.log)

    # a >1 data degree now 2-D-shards the pool (data-major sub-pools,
    # batch partitioned across data) instead of forcing dense — and the
    # per-chip paged bytes land BELOW the dense stripes they replace
    dp = specialize("qwen2-vl-72b", "decode_32k")       # 16x16 mesh
    assert dp.estimates["kv_residency"] == "paged"
    assert dp.estimates["kv_pool_data_degree"] == 16
    assert dp.estimates["kv_pool_model_degree"] == 16
    assert dp.estimates["kv_n_blocks"] % (16 * 16) == 0  # 2-D-shardable
    assert dp.estimates["kv_paged_bytes"] < dp.estimates["kv_dense_bytes"]
    assert any(s == "kv_residency" and "2-D" in why
               for _, s, _, why in dp.log)

    # ...but a batch that cannot partition over the data degree would
    # force the pool back to data-replication: honestly dense
    odd = specialize("qwen3-8b",
                     ShapeConfig("decode_odd_batch", "decode", 512, 3),
                     mesh_shape=(2, 4))
    assert odd.estimates["kv_residency"] == "dense"
    assert any(s == "kv_residency" and "partition" in why
               for _, s, _, why in odd.log)

    # too shallow for >=2 blocks/seq -> dense
    shallow = specialize("qwen3-8b",
                         ShapeConfig("decode_shallow", "decode", 16, 2),
                         mesh_shape=(1, 1))
    assert shallow.estimates["kv_residency"] == "dense"
    assert "kv_block_len" not in shallow.estimates

    # option override forces either way (and is part of the request key)
    forced = specialize("qwen2-vl-72b", "decode_32k", mesh_shape=(1, 16),
                        kv_residency="dense")
    assert forced.estimates["kv_residency"] == "dense"

    # training shapes and SSM-only archs never page
    train = specialize("qwen3-8b", "train_4k")
    assert "kv_residency" not in train.estimates
    ssm = specialize("mamba2-2.7b", "long_500k")
    assert "kv_residency" not in ssm.estimates


def test_costmodel_kv_block_geometry():
    from repro.core.costmodel import kv_block_geometry
    geo = kv_block_geometry(32768, 128, 80, 8, 128)
    assert geo.block_len == 512
    assert geo.blocks_per_seq == 64
    assert geo.n_blocks == 128 * 64           # uncapped: dense worst case
    assert geo.paged_bytes == geo.dense_bytes
    # a budget cap shrinks the pool but never below one full sequence
    capped = kv_block_geometry(32768, 128, 80, 8, 128,
                               budget_bytes=geo.dense_bytes / 4)
    assert geo.n_blocks / 4.1 < capped.n_blocks <= geo.n_blocks // 4
    tiny = kv_block_geometry(32768, 128, 80, 8, 128, budget_bytes=1.0)
    assert tiny.n_blocks == tiny.blocks_per_seq
    # zero headroom is a real cap (the one-sequence floor), NOT uncapped
    zero = kv_block_geometry(32768, 128, 80, 8, 128, budget_bytes=0.0)
    assert zero.n_blocks == zero.blocks_per_seq
    # 2-D: the data degree still divides capacity (the reclamation
    # bet), but the pool splits into data_shards sub-pools, each
    # model-aligned and never below one sequence — the 16x16 case's
    # raw 512-block target bumps to the 16 x 64-block sub-pool floor
    dp = kv_block_geometry(32768, 128, 80, 8, 128, data_shards=16, align=16)
    assert dp.data_degree == 16 and dp.model_degree == 16
    assert dp.n_blocks == 16 * 64           # 16 sub-pools at the floor
    assert dp.sub_pool_blocks == 64 and dp.n_blocks % (16 * 16) == 0
    assert dp.paged_bytes < dp.dense_bytes
    wide = kv_block_geometry(32768, 2048, 80, 8, 128,
                             data_shards=16, align=16)
    assert wide.n_blocks == 2048 * 64 // 16     # bet above the floor
    assert wide.sub_pool_blocks % 16 == 0
    odd = kv_block_geometry(64, 3, 2, 2, 16, align=8)     # want=12 -> 8
    assert odd.n_blocks == 8
    floor = kv_block_geometry(64, 1, 2, 2, 16, align=8)   # per_seq=4 -> 8
    assert floor.n_blocks == 8
    # prefix-reuse capacity math: r/(h + r(1-h)) approaches 1/(1-h),
    # headroom is (r-1)*floor(h*blocks_per_seq) capped at the sub-pool,
    # and both collapse to the no-op when reuse is off or r <= 1
    assert geo.prefix_capacity_factor(1) == 1.0
    f8 = geo.prefix_capacity_factor(8)
    assert 1.0 < f8 < geo.prefix_capacity_factor(64) < 2.0   # h = 0.5
    assert geo.prefix_hit_headroom(1) == 0
    per = geo.blocks_per_seq
    assert geo.prefix_hit_headroom(2) == int(0.5 * per)
    assert geo.prefix_hit_headroom(10 ** 6) <= geo.sub_pool_blocks
    assert geo.prefix_hit_headroom(4, hit_rate=1.0) == 3 * per
    import dataclasses as _dc
    off = _dc.replace(geo, prefix_reuse="off")
    assert off.prefix_capacity_factor(8) == 1.0
    assert off.prefix_hit_headroom(8) == 0


# ---------------- the `repro plan` CLI ----------------

def test_plan_cli_list_show_diff(capsys, tmp_path):
    from repro.core.pipeline import specialize
    from repro.launch.plan import main
    d = str(tmp_path / "plans")
    a = specialize("qwen3-8b", "train_4k", plan_dir=d)
    b = specialize("qwen3-8b", "train_4k", plan_dir=d, decode_impl="xla")

    assert main(["--plan-dir", d, "list"]) == 0
    out = capsys.readouterr().out
    assert a.content_hash()[:12] in out and "qwen3-8b" in out

    assert main(["--plan-dir", d, "show", a.content_hash()[:10],
                 "--log"]) == 0
    out = capsys.readouterr().out
    assert a.content_hash() in out
    assert "train seq=4096 batch=256" in out
    assert "[data_organization]" in out

    rc = main(["--plan-dir", d, "diff", a.content_hash()[:10],
               b.content_hash()[:10]])
    out = capsys.readouterr().out
    if a.content_hash() != b.content_hash():
        assert rc == 1
    else:
        assert rc == 0 and "identical" in out

    assert main(["--plan-dir", d, "diff", a.content_hash()[:10],
                 a.content_hash()[:10]]) == 0
    assert "identical" in capsys.readouterr().out

    with pytest.raises(SystemExit, match="no stored plan"):
        main(["--plan-dir", d, "show", "ffffffffffff"])


def test_plan_cli_renders_tier_decisions_schema_tolerant(capsys, tmp_path):
    """`plan show` renders the new tier fields, and artifacts stored
    before the multi-tier refactor (no ``kv_tier_split`` key) display
    as an hbm-only pool instead of raising or dropping the field."""
    from types import SimpleNamespace

    from repro.configs import ShapeConfig
    from repro.core.pipeline import specialize
    from repro.launch.plan import _decisions, main
    d = str(tmp_path / "plans")
    plan = specialize("qwen3-8b", ShapeConfig("tiered", "decode", 64, 2),
                      mesh_shape=(1, 1), plan_dir=d)
    assert main(["--plan-dir", d, "show", plan.content_hash()[:10]]) == 0
    out = capsys.readouterr().out
    assert '"kv_tier_split": "hbm+host"' in out
    assert '"kv_host_blocks"' in out and '"kv_prefetch": "on"' in out

    # a pre-tier paged artifact: same decisions minus every tier key
    est = {k: v for k, v in plan.estimates.items()
           if k not in ("kv_tier_split", "kv_host_blocks", "kv_prefetch")}
    dec = _decisions(SimpleNamespace(estimates=est))
    assert dec["kv_tier_split"] == "hbm-only"
    assert "kv_host_blocks" not in dec and "kv_prefetch" not in dec
    # dense plans get no synthesized tier field — there is no pool
    dense = _decisions(SimpleNamespace(estimates={"kv_residency": "dense"}))
    assert "kv_tier_split" not in dense


def test_plan_cli_verify_reports_corrupt_and_stale(capsys, tmp_path):
    from repro.core.pipeline import specialize
    from repro.launch.plan import main
    import json as _json

    from repro.configs import ShapeConfig
    d = tmp_path / "plans"
    plans = [specialize("qwen3-8b",
                        ShapeConfig(f"verify_{i}", "decode", seq, 2),
                        mesh_shape=(1, 1), plan_dir=str(d))
             for i, seq in enumerate((32, 64, 128))]
    files = [d / f"{p.content_hash()}.json" for p in plans]
    assert len({f.name for f in files}) == 3 and all(f.exists()
                                                     for f in files)

    # a healthy store verifies clean
    assert main(["--plan-dir", str(d), "verify"]) == 0
    out = capsys.readouterr().out
    assert "0 bad" in out and "0 dangling" in out

    # truncate one entry -> corrupt; stamp another stale; tamper the
    # third's payload (valid JSON, wrong hash — only the re-hash sees
    # it); dangle a by_key ref
    files[0].write_text(files[0].read_text()[:40])
    e = _json.loads(files[1].read_text())
    e["schema"] = -1
    files[1].write_text(_json.dumps(e))
    e = _json.loads(files[2].read_text())
    e["plan"]["arch"] = "tampered"
    files[2].write_text(_json.dumps(e))
    (d / "by_key").mkdir(exist_ok=True)
    (d / "by_key" / "deadbeef").write_text("f" * 64)
    assert main(["--plan-dir", str(d), "verify"]) == 1
    out = capsys.readouterr().out
    assert "corrupt" in out and "stale-schema" in out
    assert "3 bad" in out and "dangling" in out


def test_plan_cli_gc_manual_eviction(capsys, tmp_path):
    from repro.configs import ShapeConfig
    from repro.core.pipeline import specialize
    from repro.launch.plan import main
    d = tmp_path / "plans"
    for i, seq in enumerate((32, 64, 128)):
        specialize("qwen3-8b", ShapeConfig(f"gc_{i}", "decode", seq, 2),
                   mesh_shape=(1, 1), plan_dir=str(d))
    assert len(list(d.glob("*.json"))) == 3
    assert main(["--plan-dir", str(d), "gc", "--max-entries", "1"]) == 0
    out = capsys.readouterr().out
    assert "removed 2" in out
    assert len(list(d.glob("*.json"))) == 1
    # surviving store verifies clean (refs were trimmed with entries)
    assert main(["--plan-dir", str(d), "verify"]) == 0
