"""Checkpointer (atomicity, restore, gc) + data pipeline (determinism)."""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer
from repro.configs import ShapeConfig, get_arch
from repro.data import PrefetchPipeline, SyntheticSource


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,))},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    state = _state()
    ck.save(12, state, meta={"arch": "t"}, blocking=True)
    restored, manifest = ck.restore()
    assert manifest["step"] == 12
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(state["params"]["w"]))
    assert int(restored["opt"]["step"]) == 7


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (10, 20, 30, 40):
        ck.save(s, _state(s), blocking=True)
    assert ck.latest_step() == 40
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert kept == ["step_00000030", "step_00000040"]
    assert ck.validate(40)


def test_atomicity_no_partial_visible(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _state(), blocking=True)
    # a stale .tmp dir from a crashed writer must not be picked up
    (tmp_path / "step_00000099.tmp").mkdir()
    assert ck.latest_step() == 5


def test_restore_with_shardings(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    ck = Checkpointer(tmp_path)
    ck.save(1, _state(), blocking=True)
    restored, _ = ck.restore(shardings={"params": {"w": sh, "b": sh},
                                        "opt": {"step": sh}})
    assert restored["params"]["w"].sharding == sh


# ---------------- data pipeline ----------------

def test_source_deterministic_per_step():
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("t", "train", 32, 4)
    s1 = SyntheticSource(arch, shape, seed=3)
    s2 = SyntheticSource(arch, shape, seed=3)
    b1, b2 = s1.batch_at(17), s2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_source_host_sharding_disjoint():
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("t", "train", 32, 8)
    a = SyntheticSource(arch, shape, host_id=0, n_hosts=2).batch_at(0)
    b = SyntheticSource(arch, shape, host_id=1, n_hosts=2).batch_at(0)
    assert a["tokens"].shape == (4, 32)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_prefetch_pipeline_order_and_restart():
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("t", "train", 16, 2)
    src = SyntheticSource(arch, shape, seed=1)
    pipe = PrefetchPipeline(src, prefetch_depth=3, start_step=5)
    got = []
    for step, batch in pipe:
        got.append((step, batch["tokens"].copy()))
        if len(got) == 4:
            break
    pipe.close()
    assert [g[0] for g in got] == [5, 6, 7, 8]
    # restart replay: same steps -> same bytes
    np.testing.assert_array_equal(got[2][1], src.batch_at(7)["tokens"])
