"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs; decode/prefill consistency vs the full forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, all_archs, get_arch
from repro.models import (RunCfg, decode_step, forward, init_params, prefill,
                          synthetic_batch, train_loss)
from repro.models.lm import _logits

CFG = RunCfg(block_q=32, ssd_chunk=16)
SMOKE_TRAIN = ShapeConfig("smoke", "train", 64, 2)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", all_archs())
def test_train_step_smoke(name, key):
    arch = get_arch(name).reduced()
    params = init_params(arch, key)
    batch = synthetic_batch(arch, SMOKE_TRAIN, key)
    loss, metrics = jax.jit(
        lambda p, b: train_loss(arch, p, b, CFG))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), name
    assert 2.0 < float(loss) < 12.0, (name, float(loss))
    if arch.is_moe:
        assert jnp.isfinite(metrics["aux_loss"])

    # gradients exist and are finite for every leaf
    g = jax.grad(lambda p: train_loss(arch, p, batch, CFG)[0])(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves), name


@pytest.mark.parametrize("name", all_archs())
def test_forward_shapes(name, key):
    arch = get_arch(name).reduced()
    params = init_params(arch, key)
    batch = synthetic_batch(arch, SMOKE_TRAIN, key)
    x, aux = forward(arch, params, batch, CFG)
    assert x.shape == (2, 64, arch.d_model)
    assert not bool(jnp.isnan(x.astype(jnp.float32)).any())


DECODE_ARCHS = [a for a in all_archs()
                if not get_arch(a).is_encoder]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_forward(name, key):
    arch = get_arch(name).reduced()
    params = init_params(arch, key)
    if arch.modality == "vlm":
        dec = {"tokens": jax.random.randint(key, (2, 1), 0, arch.vocab_size,
                                            dtype=jnp.int32)}
        emb = jax.random.normal(key, (2, 32, arch.d_model)).astype(jnp.bfloat16)
        tok_emb = jnp.take(params["embed"], dec["tokens"], axis=0)
        full = {"embeds": jnp.concatenate([emb, tok_emb], axis=1)}
        pre = {"embeds": emb}
    else:
        toks = jax.random.randint(key, (2, 33), 0, arch.vocab_size,
                                  dtype=jnp.int32)
        full, pre, dec = ({"tokens": toks}, {"tokens": toks[:, :32]},
                          {"tokens": toks[:, 32:33]})
    x, _ = forward(arch, params, full, CFG)
    oracle = _logits(arch, params, x[:, -1:], CFG)[:, 0].astype(jnp.float32)
    logits_p, cache = prefill(arch, params, pre, CFG, max_len=48)
    logits_d, cache2 = decode_step(arch, params, cache, dec, CFG)
    err = jnp.abs(oracle - logits_d.astype(jnp.float32)).max()
    scale = jnp.abs(oracle).max()
    tol = 0.02 if (arch.has_ssm or arch.is_moe) else 1e-3
    assert float(err) <= tol * max(float(scale), 1.0), (name, float(err))
    assert np.all(np.asarray(cache2["pos"]) == 33)     # per-slot (B,)


@pytest.mark.parametrize("name", DECODE_ARCHS[:4])
def test_multi_token_decode_advances(name, key):
    arch = get_arch(name).reduced()
    params = init_params(arch, key)
    toks = jax.random.randint(key, (1, 8), 0, arch.vocab_size, jnp.int32)
    _, cache = prefill(arch, params, {"tokens": toks}, CFG, max_len=24)
    step = jax.jit(lambda p, c, b: decode_step(arch, p, c, b, CFG))
    tok = toks[:, -1:]
    for i in range(4):
        logits, cache = step(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits[:, :arch.vocab_size], axis=-1)[:, None] \
            .astype(jnp.int32)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert np.all(np.asarray(cache["pos"]) == 12)      # per-slot (B,)


def test_padded_heads_equivalent_at_init(key):
    """Dead (padded) heads must not change the forward at init."""
    arch = get_arch("hymba-1.5b").reduced()
    p0 = init_params(arch, key)
    p1 = init_params(arch, key, heads_padded=8, kv_heads_padded=4)
    batch = synthetic_batch(arch, SMOKE_TRAIN, key)
    # same *live* weights: copy the unpadded leaves into the padded pytree
    def graft(dst, src, cut_q, cut_kv):
        dst["blocks"]["attn"]["wq"] = dst["blocks"]["attn"]["wq"].at[
            ..., :cut_q].set(src["blocks"]["attn"]["wq"])
        dst["blocks"]["attn"]["wk"] = dst["blocks"]["attn"]["wk"].at[
            ..., :cut_kv].set(src["blocks"]["attn"]["wk"])
        dst["blocks"]["attn"]["wv"] = dst["blocks"]["attn"]["wv"].at[
            ..., :cut_kv].set(src["blocks"]["attn"]["wv"])
        dst["blocks"]["attn"]["wo"] = jnp.zeros_like(
            dst["blocks"]["attn"]["wo"]).at[:, :cut_q, :].set(
                src["blocks"]["attn"]["wo"])
        for k in ("pre_norm", "mlp_norm"):
            dst["blocks"][k] = src["blocks"][k]
        dst["blocks"]["mlp"] = src["blocks"]["mlp"]
        dst["blocks"]["ssm"] = src["blocks"]["ssm"]
        dst["embed"], dst["final_norm"] = src["embed"], src["final_norm"]
        if "lm_head" in src:
            dst["lm_head"] = src["lm_head"]
        return dst
    # hymba reduced: 4 heads/2 kv (no padding needed in reduced) — force a
    # padded variant and check the dead heads contribute ~nothing
    hd = arch.hd
    p1 = graft(p1, p0, arch.n_heads * hd, arch.n_kv_heads * hd)
    l0, _ = train_loss(arch, p0, batch, CFG)
    l1, _ = train_loss(arch, p1, batch, CFG)
    assert abs(float(l0) - float(l1)) < 5e-2
