"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import tiled_matmul


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


@pytest.mark.parametrize("B,S,H,K,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 4, 1, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 32)])
def test_flash_attention(B, S, H, K, D, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, D)).astype(dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=64, block_kv=64, interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert _rel_err(o, r) < tol


@pytest.mark.parametrize("blocks", [(32, 128), (128, 32), (64, 64)])
def test_flash_attention_block_invariance(blocks):
    """Output must not depend on the partitioning-pass tile choice."""
    bq, bkv = blocks
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    o = flash_attention(q, k, v, block_q=bq, block_kv=bkv, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    assert _rel_err(o, r) < 1e-5


@pytest.mark.parametrize("S,cl,window", [(256, 256, 0), (256, 100, 0),
                                         (512, 300, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(S, cl, window, dtype):
    B, H, K, D = 2, 8, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, D)).astype(dtype)
    o = decode_attention(q, k, v, cache_len=jnp.int32(cl), window=window,
                         block_kv=64, interpret=True)
    r = ref.decode_attention_ref(q, k, v, cache_len=jnp.int32(cl),
                                 window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert _rel_err(o, r) < tol


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 96, 2, 64, 32, 32),   # S not a multiple of 2*chunk
])
def test_ssd_scan(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    assert _rel_err(y, yr) < 1e-3


@pytest.mark.parametrize("M,K,N,bm,bk,bn", [
    (128, 256, 128, 64, 128, 64),
    (256, 128, 512, 128, 64, 128),
    (64, 64, 64, 64, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_matmul(M, K, N, bm, bk, bn, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.random.normal(ks[0], (M, K)).astype(dtype)
    b = jax.random.normal(ks[1], (K, N)).astype(dtype)
    o = tiled_matmul(a, b, bm=bm, bk=bk, bn=bn, interpret=True)
    r = ref.tiled_matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert _rel_err(o, r) < tol


def test_ops_dispatch_uses_plan_blocks():
    """ops.py must configure kernels from the plan's BlockPlans."""
    from repro.core import specialize
    from repro.kernels import ops
    plan = specialize("qwen3-8b", "train_4k")
    bp = plan.partitions["flash_attention"]
    assert bp.blocks["block_q"] >= 128
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    S = bp.blocks["block_q"]            # single block
    q = jax.random.normal(ks[0], (1, S, 4, 128)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, S, 2, 128)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, S, 2, 128)).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, plan=plan, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    assert _rel_err(o, r) < 2e-2
