"""Mixed-length continuous batching: the per-slot position contract.

The serving regression this pins: with an engine-global scalar decode
position, a continuous batch that mixes prompt lengths appends every
slot's KV at the *max* slot length and masks attention with the wrong
``cache_len`` — silently corrupting the specialized KV memory of every
shorter slot.  ``cache["pos"]`` is now a per-slot ``(B,)`` vector, so a
staggered batch must be token-identical to sequential single-request
runs, across architectures (attention/GQA, SSM, hybrid) and both decode
implementations (XLA and the flash-decode combine).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels import ref
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.models.lm import RunCfg
from repro.serve.engine import ServeEngine

CFG = RunCfg(block_q=16, ssd_chunk=16)


def _prompts(arch, n=3):
    return [np.arange(5, dtype=np.int32) % arch.vocab_size,
            (np.arange(11, dtype=np.int32) * 3) % arch.vocab_size,
            (np.arange(8, dtype=np.int32) * 7 + 2) % arch.vocab_size][:n]


def _serve_sequential(arch, params, cfg, prompts, new_tokens, max_len):
    out = []
    for p in prompts:
        eng = ServeEngine(arch, params, cfg, max_batch=1, max_len=max_len)
        eng.submit(p, max_new_tokens=new_tokens)
        done = eng.run_until_idle(max_ticks=4 * new_tokens)
        assert len(done) == 1
        out.append(done[0].out_tokens)
    return out


# ---------------- the token-identity matrix ----------------
#
# One seeded grid over {arch} x {decode impl} x {kv residency}: every
# runnable cell pins a staggered continuous batch token-identical to
# sequential single-request serving through the SAME impl's dense
# engine (which also pins paged-vs-dense identity — both residencies
# must reproduce the one oracle).  Cross-impl equality is NOT asserted:
# flash's online-softmax combine and XLA's dense softmax round
# differently, which can flip a near-tie greedy argmax.  Infeasible
# cells are skipped with explicit reasons instead of silently not
# existing.

ARCHS = ["qwen3-8b", "mamba2-2.7b", "hymba-1.5b"]
IMPLS = ["xla", "flash", "shard_map_flash"]
RESIDENCIES = ["dense", "paged"]

_PARAMS_CACHE: dict = {}
_ORACLE_CACHE: dict = {}


def _arch_params(name):
    if name not in _PARAMS_CACHE:
        arch = get_arch(name).reduced()
        _PARAMS_CACHE[name] = (arch, lm.init_params(arch,
                                                    jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[name]


def _impl_cfg(impl):
    if impl == "xla":
        return CFG
    # "flash": the shard_map implementation on the in-process host mesh
    # (its single-shard online-softmax combine; decode_path == "flash")
    return dataclasses.replace(CFG, decode_impl="shard_map_flash",
                               mesh=make_host_mesh())


@pytest.mark.parametrize("residency", RESIDENCIES)
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("name", ARCHS)
def test_token_identity_matrix(name, impl, residency):
    """Staggered prompts, fewer slots than requests (slots freed and
    reused mid-flight), through every (arch x impl x residency) cell ->
    token-identical to one-request-at-a-time dense serving."""
    if impl == "shard_map_flash":
        pytest.skip("the real sharded shard_map path needs >1 host "
                    "device; covered by tests/test_multidevice.py "
                    "(dense seq-sharded + 2-D pool-sharded runs)")
    if residency == "paged" and name == "mamba2-2.7b":
        pytest.skip("SSM-only arch has no KV stripes to page — the "
                    "engine honestly degrades to dense (asserted in "
                    "the dense cell)")
    arch, params = _arch_params(name)
    cfg = _impl_cfg(impl)
    prompts = _prompts(arch)
    okey = (name, impl)
    if okey not in _ORACLE_CACHE:
        _ORACLE_CACHE[okey] = _serve_sequential(arch, params, cfg,
                                                prompts, 6, 32)
    want = _ORACLE_CACHE[okey]

    kw = dict(PAGED) if residency == "paged" else {}
    eng = ServeEngine(arch, params, cfg, max_batch=2, max_len=32, **kw)
    if impl == "flash":
        # single-device host mesh: flash_decode runs its single-shard
        # combine — decode_path reports that honestly
        assert eng.decode_path == "flash"
    if residency == "paged":
        assert eng.kv_residency == ("paged" if arch.has_attention
                                    else "dense")
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    done = eng.run_until_idle(max_ticks=64)
    assert len(done) == len(prompts)
    got = {r.prompt.tobytes(): r.out_tokens for r in done}
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w, (name, impl, residency,
                                       got[p.tobytes()], w)
    if residency == "paged" and arch.has_attention:
        stats = eng.block_stats()
        assert stats["free"] == stats["total"] > 0, "blocks leaked"


def test_decode_step_per_slot_positions_vs_oracle():
    """One lm.decode_step over a hand-staggered cache == per-sequence
    decode_attention oracle (exact, including RoPE at per-slot offsets)."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(1))
    prompts = _prompts(arch, 2)
    max_len = 16
    cache = lm.init_cache(arch, 2, max_len)
    toks = []
    singles = []
    for slot, p in enumerate(prompts):
        lg, c1 = lm.prefill(arch, params,
                            {"tokens": jnp.asarray(p[None], jnp.int32)},
                            CFG, max_len=max_len)
        for key in ("k", "v"):
            cache[key] = cache[key].at[:, slot].set(c1[key][:, 0])
        toks.append(int(jnp.argmax(lg[0, :arch.vocab_size])))
        singles.append(c1)
    cache["pos"] = jnp.asarray([len(p) for p in prompts], jnp.int32)
    t = jnp.asarray(toks, jnp.int32)[:, None]
    logits, cache2 = lm.decode_step(arch, params, cache, {"tokens": t}, CFG)
    assert np.array_equal(np.asarray(cache2["pos"]),
                          [len(p) + 1 for p in prompts])
    # each slot's batched logits == its own single-sequence decode
    for slot, (p, c1) in enumerate(zip(prompts, singles)):
        lg1, _ = lm.decode_step(arch, params, c1,
                                {"tokens": t[slot:slot + 1]}, CFG)
        err = np.abs(np.asarray(logits[slot], np.float32)
                     - np.asarray(lg1[0], np.float32)).max()
        assert err < 1e-3, (slot, err)


def test_append_kv_matches_ref_oracle():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    c = jax.random.normal(ks[0], (4, 16, 2, 8)).astype(jnp.bfloat16)
    n = jax.random.normal(ks[1], (4, 1, 2, 8)).astype(jnp.bfloat16)
    pos = jnp.asarray([0, 5, 15, 9], jnp.int32)
    got = lm.append_kv(c, n, pos)
    want = ref.decode_append_ref(c, n, pos)
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))
    # scalar broadcast back-compat
    got = lm.append_kv(c, n, jnp.full((4,), 3, jnp.int32))
    want = ref.decode_append_ref(c, n, 3)
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))


def test_flash_decode_per_slot_matches_oracle_single_shard():
    from repro.dist.flash_decode import flash_decode
    mesh = make_host_mesh()
    B, S, H, K, D = 3, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kn = jax.random.normal(ks[1], (B, 1, K, D))
    vn = jax.random.normal(ks[2], (B, 1, K, D))
    kc = jax.random.normal(ks[3], (B, S, K, D))
    vc = jax.random.normal(ks[4], (B, S, K, D))
    for pos_list, win in (([0, 13, 31], 0), ([4, 20, 27], 8)):
        pos = jnp.asarray(pos_list, jnp.int32)
        ctx, kc2, vc2 = jax.jit(lambda *a: flash_decode(*a, mesh=mesh))(
            q, kn, vn, kc, vc, pos, win)
        kr = ref.decode_append_ref(kc, kn, pos)
        vr = ref.decode_append_ref(vc, vn, pos)
        r = ref.decode_attention_ref(q[:, 0], kr, vr, cache_len=pos + 1,
                                     window=win)
        assert float(jnp.abs(ctx[:, 0] - r).max()) < 1e-5, (pos_list, win)
        assert np.allclose(np.asarray(kc2), np.asarray(kr))


# ---------------- engine PRNG threading ----------------

def test_engine_sampling_seeded_and_reproducible():
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    prompts = _prompts(arch, 2)

    def run(seed):
        eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                          seed=seed)
        for p in prompts:
            eng.submit(p, max_new_tokens=5, temperature=1.0)
        eng.run_until_idle(max_ticks=32)
        return [r.out_tokens for r in
                sorted(eng.finished, key=lambda r: r.rid)]

    assert run(0) == run(0), "same seed must reproduce the run"
    assert run(0) != run(1), "different seeds must diverge"


def test_engine_slots_get_distinct_keys_within_tick():
    """Two slots sampling the same logits in the same tick must not be
    forced to the same token (the time_ns()-seeded engine collided)."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    p = _prompts(arch, 1)[0]
    draws_a, draws_b = [], []
    for trial in range(4):
        eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                          seed=trial)
        eng.submit(p, max_new_tokens=6, temperature=5.0)
        eng.submit(p, max_new_tokens=6, temperature=5.0)   # identical twin
        eng.run_until_idle(max_ticks=32)
        a, b = (r.out_tokens for r in
                sorted(eng.finished, key=lambda r: r.rid))
        draws_a += a[1:]
        draws_b += b[1:]          # [0] is greedy-ish prefill-tick sample
    assert draws_a != draws_b, "slots shared a PRNG key within ticks"


# ---------------- freed-slot masking ----------------

def test_freed_slots_do_not_perturb_live_ones():
    """A long request keeps decoding while its neighbor finishes and the
    slot sits idle -> its tokens must equal the run where it was alone."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    prompts = _prompts(arch, 2)

    alone = ServeEngine(arch, params, CFG, max_batch=2, max_len=32)
    alone.submit(prompts[0], max_new_tokens=10)
    done = alone.run_until_idle(max_ticks=32)
    want = done[0].out_tokens

    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32)
    eng.submit(prompts[0], max_new_tokens=10)    # long-lived
    eng.submit(prompts[1], max_new_tokens=2)     # finishes early, slot idles
    done = eng.run_until_idle(max_ticks=32)
    got = {r.prompt.tobytes(): r.out_tokens for r in done}
    assert got[prompts[0].tobytes()] == want
    # the freed slot is masked to pos 0 on every later tick
    assert eng.slot_len[1] == 0 or eng.slot_len[0] == 0


def test_submit_rejects_requests_past_cache_capacity():
    """prompt + max_new_tokens beyond max_len would clamp appends onto
    the last cache row (silent corruption) -> loud ValueError instead."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    eng = ServeEngine(arch, params, CFG, max_batch=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.arange(12, dtype=np.int32), max_new_tokens=10)
    # exactly at capacity is fine
    eng.submit(np.arange(12, dtype=np.int32) % arch.vocab_size,
               max_new_tokens=4)
    done = eng.run_until_idle(max_ticks=16)
    assert len(done) == 1 and len(done[0].out_tokens) == 4


def test_request_satisfied_by_prefill_finishes_without_decode():
    """max_new_tokens=1 is met by the prefill sample: exactly one token,
    no decode tick, and the slot is returned immediately."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32)
    eng.submit(_prompts(arch, 1)[0], max_new_tokens=1)
    done = eng.run_until_idle(max_ticks=8)
    assert len(done) == 1 and len(done[0].out_tokens) == 1
    assert not eng.active and sorted(eng.free_slots) == [0, 1]


# ---------------- paged KV residency ----------------

PAGED = dict(kv_residency="paged", kv_block_len=16)


def _run_engine(arch, params, cfg, prompts, new_tokens, max_batch=2,
                max_len=32, **kw):
    eng = ServeEngine(arch, params, cfg, max_batch=max_batch,
                      max_len=max_len, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=new_tokens)
    done = eng.run_until_idle(max_ticks=128)
    assert len(done) == len(prompts)
    return {r.prompt.tobytes(): r.out_tokens for r in done}, eng


def test_bucketed_prefill_admits_batch_in_one_call():
    """Same-length pending prompts are admitted through ONE jitted
    prefill call per bucket — and stay token-identical to sequential."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    bucket_a = [rng.integers(0, arch.vocab_size, (7,)).astype(np.int32)
                for _ in range(4)]
    bucket_b = [rng.integers(0, arch.vocab_size, (11,)).astype(np.int32)
                for _ in range(2)]
    prompts = bucket_a + bucket_b
    want = _serve_sequential(arch, params, CFG, prompts, 4, 32)

    got, eng = _run_engine(arch, params, CFG, prompts, 4, max_batch=8,
                           **PAGED)
    assert eng.prefill_calls == 2, eng.prefill_batches
    assert sorted(eng.prefill_batches) == [2, 4]
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w


def test_pool_exhaustion_serializes_and_recycles():
    """A pool of 2 blocks with 2-block requests: admissions serialize on
    block availability (head-of-line waits for a finisher), outputs stay
    token-identical to a fresh engine, and nothing leaks."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    prompts = _prompts(arch)
    want = _serve_sequential(arch, params, CFG, prompts, 5, 32)

    # block_len=8: every prompt (5/11/8 tokens) + 5 new needs exactly 2
    # blocks, and the pool holds exactly one request's worth
    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=2)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    peak = 0
    ticks = 0
    while (eng.pending or eng.active) and ticks < 256:
        eng.step()
        stats = eng.block_stats()
        assert 0 <= stats["free"] <= stats["total"]
        peak = max(peak, stats["in_use"])
        assert len(eng.active) <= 1, "pool of 2 cannot host two requests"
        ticks += 1
    got = {r.prompt.tobytes(): r.out_tokens for r in eng.finished}
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w
    assert peak == 2
    assert eng.block_stats()["free"] == 2, "blocks leaked"
    # a request no amount of churn could ever admit is a loud error,
    # not an admission queue that waits forever
    with pytest.raises(ValueError, match="blocks"):
        eng.submit(prompts[0], max_new_tokens=20)
    # ...but a prefill-satisfied request (max_new=1) allocates NOTHING,
    # so even a long prompt sails past an undersized pool
    eng.submit(np.arange(24, dtype=np.int32) % arch.vocab_size,
               max_new_tokens=1)
    done = eng.run_until_idle(max_ticks=8)
    assert len(done[-1].out_tokens) == 1
    assert eng.block_stats()["free"] == 2


def test_block_recycling_churn_at_full_occupancy():
    """admit -> finish -> re-admit churn at full pool occupancy: the
    second wave reuses reclaimed blocks and is token-identical to a
    fresh engine serving the same wave."""
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    prompts = _prompts(arch)

    eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32, **PAGED)
    total = eng.block_stats()["total"]
    for wave in range(2):
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        eng.run_until_idle(max_ticks=128)
        assert eng.block_stats()["free"] == total, f"leak after wave {wave}"
    fresh, _ = _run_engine(arch, params, CFG, prompts, 6, **PAGED)
    wave2 = {r.prompt.tobytes(): r.out_tokens
             for r in eng.finished[len(prompts):]}
    for p in prompts:
        assert wave2[p.tobytes()] == fresh[p.tobytes()], \
            "recycled blocks changed tokens"


# ---------------- cross-request prefix KV reuse ----------------
#
# The shared-prefix axis of the token-identity matrix: requests with a
# common system prompt must decode token-identically whether their
# prefix blocks are private (reuse off) or aliased out of the radix
# trie (reuse on) — across archs (compute-skip vs hybrid aliasing) and
# decode impls, including the zero-prefill decode-ride, a forced-CoW
# divergence, and a preempt-victim-with-shared-blocks resume.

def _shared_prefix_prompts(arch, seed=0):
    """16-token shared system prompt (exactly one block at bl=16) plus
    one distinct continuation token each — so a repeat submission's
    match covers feed-minus-one tokens (the decode-ride shape)."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, arch.vocab_size, 16).astype(np.int32)
    p1 = np.concatenate(
        [sys_p, rng.integers(0, arch.vocab_size, 1).astype(np.int32)])
    p2 = np.concatenate(
        [sys_p, rng.integers(0, arch.vocab_size, 1).astype(np.int32)])
    return p1, p2


def _staggered_shared_run(arch, params, cfg, p1, p2, reuse, **kw):
    """p1 first (registers its blocks), p2 + a p1-repeat after it is
    resident — the repeat is the ride candidate, p2 the CoW-free
    divergent sharer."""
    eng = ServeEngine(arch, params, cfg, max_batch=4, max_len=32,
                      kv_residency="paged", kv_block_len=16,
                      kv_prefix_reuse=reuse, **kw)
    eng.submit(p1, max_new_tokens=6)
    eng.step()
    eng.step()
    eng.submit(p2, max_new_tokens=6)
    eng.step()
    eng.submit(p1.copy(), max_new_tokens=6)
    done = eng.run_until_idle(max_ticks=64)
    assert len(done) == 3
    return {r.rid: r.out_tokens for r in done}, eng


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("name", ARCHS)
def test_shared_prefix_token_identity(name, impl):
    if impl == "shard_map_flash":
        pytest.skip("the real sharded shard_map path needs >1 host "
                    "device; the 2-D pool-sharded aliased run lives in "
                    "tests/test_multidevice.py")
    arch, params = _arch_params(name)
    cfg = _impl_cfg(impl)
    p1, p2 = _shared_prefix_prompts(arch)
    want, _ = _staggered_shared_run(arch, params, cfg, p1, p2, "off")
    got, eng = _staggered_shared_run(arch, params, cfg, p1, p2, "on")
    assert got == want, (name, impl, got, want)
    stats = eng.block_stats()
    assert stats["free"] == stats["total"], "refcounts leaked"
    assert stats["shared"] == 0 and stats["prefix_trie"] == 0
    ps = eng.pressure_stats()
    if arch.has_attention:          # SSM-only degrades to dense honestly
        assert ps["prefix_hits"] >= 2, ps
        assert ps["prefix_hit_tokens"] >= 32, ps
        if not arch.has_ssm:
            # identical repeat prompt: whole feed-but-last resident ->
            # admitted with ZERO prefill calls
            assert ps["prefix_rides"] >= 1, ps
    else:
        assert ps["prefix_hits"] == 0


def test_shared_prefix_forced_cow_divergence():
    """Drive the CoW write barrier directly: alias a *partial* append
    block between holders (the state natural admission never creates —
    only full blocks are trie-matched) and check the writer copies
    before appending, token-identically and without leaking."""
    arch, params = _arch_params("qwen3-8b")
    rng = np.random.default_rng(3)
    p = rng.integers(0, arch.vocab_size, 20).astype(np.int32)

    def run(tamper):
        eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                          kv_residency="paged", kv_block_len=16,
                          kv_prefix_reuse="on")
        eng.submit(p, max_new_tokens=8)
        eng.step()                 # prefill: slot appends into block 1
        phantom = []
        if tamper:
            r = next(iter(eng.active.values()))
            blk = r.blocks[int(eng.slot_len[r.slot]) // eng.block_len]
            eng._alloc.retain([blk])   # a sharer appears mid-write
            phantom.append(blk)
        out = eng.run_until_idle(max_ticks=64)
        if phantom:
            eng._release_blocks(phantom)
        return out[0].out_tokens, eng

    want, _ = run(False)
    got, eng = run(True)
    assert got == want, "CoW changed the decoded tokens"
    assert eng.cow_copies >= 1, "write barrier never fired"
    stats = eng.block_stats()
    assert stats["free"] == stats["total"], "CoW leaked a block"


def test_shared_prefix_preempt_victim_resumes_token_identical():
    """Preempting a victim that holds shared blocks only drops its
    reference (the sharer keeps the prefix resident); the resume
    re-admission re-matches the still-resident prefix and the victim's
    tokens equal an uninterrupted reuse-off run."""
    arch, params = _arch_params("qwen3-8b")
    p1, p2 = _shared_prefix_prompts(arch, seed=7)

    def run(reuse, preempt):
        eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                          kv_residency="paged", kv_block_len=16,
                          kv_admission="grant", kv_prefix_reuse=reuse)
        eng.submit(p1, max_new_tokens=8)
        eng.step()
        eng.submit(p2, max_new_tokens=8)   # aliases p1's prefix block
        eng.step()
        if preempt:
            assert eng.pressure_stats()["shared_blocks"] >= 1 \
                or reuse == "off"
            victim = min(eng.active.values(), key=lambda r: r.rid)
            eng.preempt(victim.rid)
        done = eng.run_until_idle(max_ticks=128)
        assert len(done) == 2
        return {r.rid: r.out_tokens for r in done}, eng

    want, _ = run("off", False)
    got, eng = run("on", True)
    assert got == want, (got, want)
    assert eng.preemptions >= 1
    stats = eng.block_stats()
    assert stats["free"] == stats["total"], "resume leaked references"


# ---------------- multi-tier KV residency ----------------
#
# The host-DRAM tier behind the HBM pool: cold cached blocks spill,
# prefix hits on spilled blocks promote back (hit-after-spill), and a
# preemption victim parks its whole per-slot state host-side so its
# resume is promote-and-continue — zero re-prefill.  Every cell must
# stay token-identical to the untiered run and leak nothing in either
# tier.

def test_prefix_hit_after_spill_promotes_not_misses():
    """Spilling a trie-indexed cold block must not turn the next prefix
    hit into a miss: the tier-tagged entry survives the spill, the
    repeat submission promotes the block back into its sub-pool, and
    the decoded tokens equal the never-spilled run."""
    arch, params = _arch_params("qwen3-8b")
    p1, p2 = _shared_prefix_prompts(arch, seed=11)

    def run(spill):
        eng = ServeEngine(arch, params, CFG, max_batch=2, max_len=32,
                          kv_residency="paged", kv_block_len=16,
                          kv_prefix_reuse="on", kv_host_blocks=8)
        eng.submit(p1, max_new_tokens=6)
        eng.run_until_idle(max_ticks=64)
        # p1 finished, but its full prefix block stays engine-cached
        assert eng.block_stats()["cached"] >= 1
        if spill:
            assert eng.spill_cached() >= 1
            st = eng.block_stats()
            assert st["host_in_use"] >= 1, st
            # the trie entry followed the block to the host tier
            assert eng._prefix.stats()["host_blocks"] >= 1
        eng.submit(p2, max_new_tokens=6)   # same 16-token system prefix
        eng.run_until_idle(max_ticks=64)
        return {r.rid: r.out_tokens for r in eng.finished}, eng

    want, _ = run(False)
    got, eng = run(True)
    assert got == want, (got, want)
    ps = eng.pressure_stats()
    assert ps["prefix_hits"] >= 1, ps        # hit, not miss, after spill
    assert ps["promotes"] >= 1, ps           # ...served by a promote
    eng.drop_block_cache()
    st = eng.block_stats()
    assert st["free"] == st["total"], "HBM blocks leaked"
    assert st["host_free"] == st["host_total"], "host blocks leaked"
    assert st["prefix_trie"] == 0


@pytest.mark.parametrize("residency", RESIDENCIES)
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("name", ARCHS)
def test_tiered_park_token_identity_matrix(name, impl, residency):
    """Forced mid-decode park (spill to the host tier) + resume
    (promote back) in every runnable (arch x impl x residency) cell:
    token-identical to the unspilled sequential oracle, zero
    re-prefill across the park, zero leaks in either tier.  Paged
    cells round-trip KV blocks through host DRAM; dense attention
    cells park their valid KV stripe rows; the SSM-only arch parks its
    recurrent state — the whole per-slot template migrates."""
    if impl == "shard_map_flash":
        pytest.skip("the real sharded shard_map path needs >1 host "
                    "device; covered by tests/test_multidevice.py")
    if residency == "paged" and name == "mamba2-2.7b":
        pytest.skip("SSM-only arch has no KV stripes to page — its "
                    "state-park cell is the dense one")
    arch, params = _arch_params(name)
    cfg = _impl_cfg(impl)
    prompts = _prompts(arch)
    okey = (name, impl)
    if okey not in _ORACLE_CACHE:
        _ORACLE_CACHE[okey] = _serve_sequential(arch, params, cfg,
                                                prompts, 6, 32)
    want = _ORACLE_CACHE[okey]

    kw = dict(PAGED, kv_admission="grant") if residency == "paged" else {}
    eng = ServeEngine(arch, params, cfg, max_batch=3, max_len=32,
                      kv_host_blocks=16, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    for _ in range(2):
        eng.step()                       # all three admitted, mid-decode
    calls = eng.prefill_calls
    victim = max(eng.active.values(), key=lambda r: len(r.out_tokens))
    eng.preempt(victim.rid)
    assert eng.preempted, "forced preemption did not park"
    parked = eng.preempted[0]
    assert parked.parked_state is not None, \
        "tiered victim fell back to a stateless park"
    if eng.kv_residency == "paged":
        assert parked.parked_state.get("kv_host"), "no KV blocks spilled"
        assert all(b >= eng.n_blocks for b in parked.request.blocks), \
            "parked request still holds HBM ids"
    done = eng.run_until_idle(max_ticks=128)
    assert eng.prefill_calls == calls, "park/resume re-prefilled"
    assert len(done) == len(prompts) and not eng.shed
    got = {r.prompt.tobytes(): r.out_tokens for r in done}
    for p, w in zip(prompts, want):
        assert got[p.tobytes()] == w, (name, impl, residency,
                                       got[p.tobytes()], w)
    assert eng.preemptions == 1
    if eng.kv_residency == "paged":
        assert eng._alloc.spills >= 1 and eng._alloc.promotes >= 1
        eng.drop_block_cache()
        st = eng.block_stats()
        assert st["free"] == st["total"], "HBM blocks leaked"
        assert st["host_free"] == st["host_total"], "host blocks leaked"


# ---------------- from_plan workload-dims validation ----------------

def test_from_plan_rejects_incompatible_workload_dims():
    """Overrides larger than the dims the plan sized the cache for (and
    non-decode plans without explicit dims) are loud errors, not silent
    stale-dim cache sizing."""
    from repro.configs import ShapeConfig
    from repro.core.pipeline import specialize
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("serve_val", "decode", 32, 2)
    plan = specialize(arch, shape, mesh_axes=("data", "model"),
                      mesh_shape=(1, 1))
    params = lm.init_params(arch, jax.random.PRNGKey(0),
                            *plan.padded_sizes())
    with pytest.raises(ValueError, match="seq_len"):
        ServeEngine.from_plan(plan, params, arch=arch, max_len=64)
    with pytest.raises(ValueError, match="global_batch"):
        ServeEngine.from_plan(plan, params, arch=arch, max_batch=4)
    # smaller-than-plan overrides remain a supported deployment shrink
    eng = ServeEngine.from_plan(plan, params, arch=arch, max_batch=1)
    assert eng.max_batch == 1

    tplan = specialize(arch, ShapeConfig("train_val", "train", 32, 2),
                       mesh_axes=("data", "model"), mesh_shape=(1, 1))
    tparams = lm.init_params(arch, jax.random.PRNGKey(0),
                             *tplan.padded_sizes())
    with pytest.raises(ValueError, match="shape_kind"):
        ServeEngine.from_plan(tplan, tparams, arch=arch)
    eng = ServeEngine.from_plan(tplan, tparams, arch=arch,
                                max_batch=2, max_len=32)
    assert eng.max_len == 32


def test_from_plan_paged_engine_serves_plan_decision():
    """A decode plan that chose paged residency drives a paged engine
    end-to-end (pool sized from the artifact, blocks reclaimed)."""
    from repro.configs import ShapeConfig
    from repro.core.pipeline import specialize
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("serve_paged", "decode", 32, 2)
    plan = specialize(arch, shape, mesh_axes=("data", "model"),
                      mesh_shape=(1, 1))
    assert plan.estimates.get("kv_residency") == "paged"
    params = lm.init_params(arch, jax.random.PRNGKey(0),
                            *plan.padded_sizes())
    eng = ServeEngine.from_plan(plan, params, arch=arch)
    assert eng.kv_residency == "paged"
    assert eng.block_len == int(plan.estimates["kv_block_len"])
    for p in _prompts(arch):
        eng.submit(p, max_new_tokens=4)
    done = eng.run_until_idle(max_ticks=64)
    assert len(done) == 3
    assert eng.block_stats()["free"] == eng.block_stats()["total"]


# ---------------- plumbing the per-slot pos through sharding ----------

def test_cache_pspecs_pos_follows_batch_rule():
    from repro.dist.sharding import cache_pspecs
    from repro.core.pipeline import specialize
    plan = specialize("qwen2-vl-72b", "decode_32k")
    sizes = {"data": 16, "model": 16}
    shapes = {
        "pos": jax.ShapeDtypeStruct((128,), jnp.int32),
        "k": jax.ShapeDtypeStruct((80, 128, 32768, 8, 128), jnp.bfloat16),
    }
    specs = cache_pspecs(plan, None, shapes, sizes)
    # per-slot pos is sharded exactly like the cache's batch dim
    assert tuple(specs["pos"]) == (tuple(specs["k"])[1],)
    # a legacy scalar pos still resolves to the empty spec
    scalar = cache_pspecs(plan, None,
                          {"pos": jax.ShapeDtypeStruct((), jnp.int32)},
                          sizes)
    assert tuple(scalar["pos"]) == ()


def test_mesh_sizes_rejects_unknown_mesh_clearly():
    from repro.dist.sharding import mesh_sizes
    with pytest.raises(TypeError, match="mesh_sizes: unsupported"):
        mesh_sizes(object())
    with pytest.raises(TypeError, match="axis names"):
        class Bad:
            axes = ("data", "model")
            shape = (4,)
        mesh_sizes(Bad())
    # the supported flavors still resolve
    assert mesh_sizes({"data": 2}) == {"data": 2}
