"""The specialization flow: pass invariants, plan artifact, ablation."""

import math

import pytest

from repro.configs import all_archs, get_arch, get_shape
from repro.core import MemoryPlan, specialize
from repro.core.costmodel import MeshModel
from repro.core.describe import describe_program
from repro.core.ir import Role
from repro.core.passes import (CommunicationPass, DataOrganizationPass,
                               LayoutPass, LocalPartitioningPass)
from repro.hw import get_target

MESHES = [
    (("data", "model"), (16, 16)),
    (("pod", "data", "model"), (2, 16, 16)),
]


def _spec_factor(spec, sizes):
    f = 1
    for s in spec:
        if s is None:
            continue
        for n in ((s,) if isinstance(s, str) else s):
            f *= sizes[n]
    return f


@pytest.mark.parametrize("axes,shape", MESHES)
@pytest.mark.parametrize("arch", ["qwen3-8b", "llama4-maverick-400b-a17b",
                                  "mamba2-2.7b", "hymba-1.5b"])
def test_specialize_invariants(arch, axes, shape):
    plan = specialize(arch, "train_4k", mesh_axes=axes, mesh_shape=shape)
    sizes = dict(zip(axes, shape))
    ir = describe_program(get_arch(arch), get_shape("train_4k"))

    # every placement spec divides its tensor's dims
    for name, p in plan.placements.items():
        t = ir.tensors.get(name)
        if t is None or not p.spec:
            continue
        used = set()
        for dim, s in zip(t.shape, p.spec):
            if s is None:
                continue
            names = (s,) if isinstance(s, str) else tuple(s)
            for n in names:
                assert n not in used, f"{name}: axis {n} used twice"
                used.add(n)
            f = math.prod(sizes[n] for n in names)
            assert dim % f == 0, (name, t.shape, p.spec)

    # persistent state obeys the HBM budget
    tgt = get_target(plan.target)
    assert plan.estimates["persistent_bytes_per_dev"] <= \
        0.70 * tgt.hbm_bytes + 1

    # every pass left a decision trail
    passes = {entry[0] for entry in plan.log}
    assert {"data_organization", "layout", "communication",
            "local_partitioning"} <= passes

    # VMEM budget respected by every kernel partition (2 banks)
    for bp in plan.partitions.values():
        assert bp.n_buffers * bp.vmem_bytes <= tgt.vmem_bytes


def test_plan_json_roundtrip():
    from repro.core import FrozenPlan
    plan = specialize("qwen2-vl-72b", "decode_32k")
    rt = FrozenPlan.from_json(plan.to_json())
    assert rt.arch == plan.arch
    assert rt.axis_rules.keys() == plan.axis_rules.keys()
    assert rt.comm.grad_schedule == plan.comm.grad_schedule
    assert set(rt.partitions) == set(plan.partitions)
    assert rt.placements["cache.k"].spec == plan.placements["cache.k"].spec
    # full-fidelity round trip: pad_to / axis_rules / nested spec tuples
    # all come back as tuples, so the reloaded plan IS the original
    assert rt == plan
    assert rt.content_hash() == plan.content_hash()
    for k, v in plan.axis_rules.items():
        assert type(rt.axis_rules[k]) is type(v), (k, v)
    for name, p in plan.placements.items():
        assert rt.placements[name].pad_to == p.pad_to
        assert type(rt.placements[name].pad_to) is type(p.pad_to)
    # the mutable builder round-trips faithfully too
    builder = MemoryPlan.from_json(plan.to_json())
    assert builder.freeze() == plan


def test_pass_ablation_prefix():
    """Running a prefix of the flow yields progressively refined plans."""
    full = specialize("qwen3-8b", "train_4k")
    only_do = specialize("qwen3-8b", "train_4k",
                         passes=[DataOrganizationPass])
    no_part = specialize("qwen3-8b", "train_4k",
                         passes=[DataOrganizationPass, LayoutPass,
                                 CommunicationPass])
    assert not only_do.partitions and full.partitions
    assert only_do.comm.grad_schedule == "reduce_scatter"  # default untouched
    assert not no_part.partitions
    assert no_part.comm.remat_policy == full.comm.remat_policy


def test_opt_state_ladder_multi_pod_relaxes():
    one = specialize("llama4-maverick-400b-a17b", "train_4k")
    two = specialize("llama4-maverick-400b-a17b", "train_4k",
                     mesh_axes=("pod", "data", "model"),
                     mesh_shape=(2, 16, 16))
    # 1 pod must cut optimizer precision; 2 pods have room for fp32
    assert one.opt["moment_dtype"] == "bfloat16"
    assert not one.opt["master_weights"]
    assert two.opt["moment_dtype"] == "float32"
    assert two.opt["master_weights"]


def test_pod_axis_enables_compression():
    two = specialize("qwen3-8b", "train_4k",
                     mesh_axes=("pod", "data", "model"),
                     mesh_shape=(2, 16, 16))
    one = specialize("qwen3-8b", "train_4k")
    assert two.comm.compress_pod_grads
    assert not one.comm.compress_pod_grads
    # template records the channel decisions
    assert two.template_summary["components"]["channel.dcn"]["enabled"]
    assert not one.template_summary["components"]["channel.dcn"]["enabled"]


def test_head_padding_decisions():
    # decode keeps megatron_tp -> heads must be TP-expressible
    plan = specialize("hymba-1.5b", "decode_32k")
    assert plan.estimates["heads_padded"] == 32     # 25 -> 32
    assert plan.estimates["kv_heads_padded"] == 8   # 5 -> 8
    plan2 = specialize("deepseek-coder-33b", "decode_32k")
    assert plan2.estimates["heads_padded"] == 64    # 56 -> 64
    plan3 = specialize("qwen3-8b", "decode_32k")
    assert plan3.estimates["heads_padded"] == 32    # unchanged
    # fsdp_dp training keeps heads whole (no padding waste)
    plan4 = specialize("hymba-1.5b", "train_4k")
    if plan4.estimates.get("strategy") == "fsdp_dp":
        assert plan4.estimates["heads_padded"] == 25


def test_strategy_decision():
    """Weight-dominated archs keep TP; activation-dominated go FSDP-DP."""
    assert specialize("qwen3-8b", "train_4k").estimates["strategy"] \
        == "fsdp_dp"
    assert specialize("llama4-maverick-400b-a17b", "train_4k") \
        .estimates["strategy"] == "megatron_tp"
    assert specialize("qwen3-8b", "decode_32k").estimates["strategy"] \
        == "megatron_tp"


def test_moe_impl_decision():
    assert specialize("granite-moe-1b-a400m", "train_4k") \
        .estimates["moe_impl"] == "dense_einsum"     # 8-of-32, tiny ff
    assert specialize("llama4-maverick-400b-a17b", "train_4k") \
        .estimates["moe_impl"] == "gshard_einsum"    # 1-of-128


def test_cache_sharding_spill():
    # default decode impl is shard_map flash-decode -> seq dim sharded
    plan = specialize("qwen2-vl-72b", "decode_32k")
    assert plan.placements["cache.k"].spec[2] == "model"   # seq_kv
    assert plan.estimates["decode_impl"] == "shard_map_flash"
    # the XLA-automatic fallback shards head_dim (local append)
    plan2 = specialize("qwen2-vl-72b", "decode_32k", decode_impl="xla")
    assert plan2.placements["cache.k"].spec[-1] == "model"


def test_ir_describe_all_cells():
    for arch in all_archs():
        a = get_arch(arch)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            if a.is_encoder and s == "decode_32k":
                continue
            ir = describe_program(a, get_shape(s))
            ir.validate()
            assert ir.total_flops() > 0
            assert ir.by_role(Role.PARAM)
