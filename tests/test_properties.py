"""Property-based tests on the system's invariants.

Two tiers: hypothesis-driven properties (skipped when hypothesis is not
installed) and seeded stdlib-random fuzz that always runs — the block-
allocator suite is in the second tier so the serving layer's invariants
are exercised in every CI environment, not only where hypothesis
happens to be available.
"""

import math
import random

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

from repro.core.costmodel import (MeshModel, allgather_bytes, allreduce_bytes,
                                  kv_block_geometry, reduce_scatter_bytes)
from repro.dist.collectives import dequantize_int8, ef_compress, quantize_int8
from repro.dist.sharding import resolve_pspec
from repro.models.moe import _capacity
from repro.serve.allocator import BlockAllocator


AXIS_NAMES = [None, "batch", "embed", "heads", "ff", "vocab"]
RULES = {"batch": "data", "embed": None, "heads": "model", "ff": "model",
         "vocab": "model"}
SIZES = {"data": 16, "model": 16}


# =====================================================================
# block-allocator fuzz: randomized admit/finish/exhaustion/churn
# sequences against the paged serving layer's invariants, on both 1-D
# (one global pool) and 2-D (per-data-shard sub-pool) geometries.
# Runs on seeded stdlib random so it exercises in every environment;
# a hypothesis twin below widens the sequences when available.
# =====================================================================

#: (n_blocks, groups): 1-D pools and 2-D data-degree sub-pool splits
POOL_GEOMETRIES = [(8, 1), (24, 1), (16, 2), (32, 4), (64, 8)]


def _fuzz_allocator(n_blocks: int, groups: int, ops, max_need: int):
    """Drive one admit/grant/retain/finish sequence, asserting every
    invariant the serving engine relies on after each step.

    ``ops`` yields (kind, group, need, pick) tuples; kind < 0.4 admits
    a multi-block budget, kind < 0.55 is a one-block grow-on-demand
    grant appended to a random live holder, kind < 0.7 retains a random
    live holder's blocks into a new alias holder (a prefix-cache hit),
    else a random live holder finishes — its blocks only come back to
    the free list once every alias has finished too.  Returns the live
    set for the caller's drain check.

    The ``refs`` model (block -> holder count) encodes *no grant after
    free* AND *no free while shared* directly: a block leaves the model
    only when its last holder releases it, so a grant handing out a
    block some holder still owns — freed out from under it, or freed
    while a sharer survived — trips the double-assignment assert.
    """
    alloc = BlockAllocator(n_blocks, groups)
    sub = n_blocks // groups
    live = []                     # allocations currently held
    refs = {}                     # model: block id -> holder count
    water = [alloc.low_water(g) for g in range(groups)]
    for kind, group, need, pick in ops:
        if kind < 0.4 or not live:
            got = alloc.allocate(need, group)
            if got is None:
                # exhaustion is exact: refusal iff the sub-pool cannot
                # cover the request (head-of-line wait in the engine)
                assert need > alloc.free_in(group)
            else:
                assert len(got) == need
                assert not (set(got) & set(refs)), "double-assigned block"
                assert all(b // sub == group for b in got), \
                    "allocation crossed a sub-pool boundary"
                for b in got:
                    refs[b] = 1
                live.append(got)
        elif kind < 0.55:
            # grow-on-demand: one-block grant onto a live holder
            blk = alloc.allocate_one(group)
            if blk is None:
                assert alloc.free_in(group) == 0
            else:
                assert blk not in refs, "granted a freed/held block"
                assert blk // sub == group
                refs[blk] = 1
                live[pick % len(live)].append(blk)
        elif kind < 0.7:
            # prefix-cache hit: alias an existing holder's blocks
            got = list(live[pick % len(live)])
            alloc.retain(got)
            for b in got:
                refs[b] += 1
            live.append(got)
        else:
            got = live.pop(pick % len(live))
            freed = alloc.release(got)
            want_freed = set()
            for b in got:
                refs[b] -= 1
                if refs[b] == 0:
                    del refs[b]
                    want_freed.add(b)
            assert set(freed) == want_freed, \
                "release freed the wrong blocks (refcount drift)"
        stats = alloc.stats()
        assert stats["total"] == n_blocks
        assert stats["free"] + stats["in_use"] == n_blocks, \
            "blocks not conserved"
        assert stats["in_use"] == len(refs)
        assert stats["shared"] == sum(1 for c in refs.values() if c > 1)
        for b, c in refs.items():
            assert alloc.refcount(b) == c, "refcount drift"
        assert sum(alloc.free_in(g) for g in range(groups)) == stats["free"]
        for g in range(groups):
            # watermarks only ever ratchet down, and never sit above
            # the current free count (they are the historical minimum)
            assert alloc.low_water(g) <= min(water[g], alloc.free_in(g))
            water[g] = alloc.low_water(g)
    return alloc, live, refs


@pytest.mark.parametrize("n_blocks,groups", POOL_GEOMETRIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_block_allocator_churn_invariants(n_blocks, groups, seed):
    rng = random.Random(f"{n_blocks}/{groups}/{seed}")
    sub = n_blocks // groups
    ops = [(rng.random(), rng.randrange(groups),
            rng.randint(0, sub + 1),      # +1: requests past sub capacity
            rng.randrange(1 << 30)) for _ in range(400)]
    alloc, live, refs = _fuzz_allocator(n_blocks, groups, ops, sub)
    # drain: releasing every holder (aliases included) restores the
    # full pool — no leaks, no lingering refcounts
    for got in live:
        alloc.release(got)
    assert alloc.release([]) == []        # empty release is a no-op
    assert alloc.stats() == {"total": n_blocks, "free": n_blocks,
                             "in_use": 0, "shared": 0, "groups": groups}


def test_block_allocator_rejects_bad_usage():
    with pytest.raises(ValueError, match="multiple"):
        BlockAllocator(10, 4)             # unequal sub-pools
    with pytest.raises(ValueError, match="groups"):
        BlockAllocator(8, 0)
    alloc = BlockAllocator(8, 2)
    got = alloc.allocate(2, group=1)
    assert got == [4, 5]                  # group 1 owns ids [4, 8)
    alloc.release(got)
    with pytest.raises(ValueError, match="double free"):
        alloc.release(got)                # already back in the pool
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.release([0])                # never handed out
    assert alloc.allocate(5, group=0) is None      # > sub-pool capacity
    assert alloc.stats()["free"] == 8


def test_block_allocator_refcount_lifecycle():
    """The sharing contract the prefix cache leans on: retain bumps,
    release decrements, and a block returns to its free list only when
    the LAST holder lets go — with misuse staying loud."""
    alloc = BlockAllocator(8, 2)
    got = alloc.allocate(2, group=0)
    assert [alloc.refcount(b) for b in got] == [1, 1]
    alloc.retain(got)                     # a second holder aliases both
    assert [alloc.refcount(b) for b in got] == [2, 2]
    assert alloc.stats()["shared"] == 2
    assert alloc.release(got) == []       # first holder: nothing freed
    assert alloc.stats()["in_use"] == 2   # still resident via the alias
    assert alloc.stats()["shared"] == 0
    assert sorted(alloc.release(got)) == sorted(got)   # last holder frees
    with pytest.raises(ValueError, match="double free"):
        alloc.release(got)
    with pytest.raises(ValueError, match="retain a free"):
        alloc.retain(got)                 # can't resurrect a freed block
    with pytest.raises(ValueError, match="retain a free"):
        alloc.retain([7])                 # never handed out
    assert alloc.refcount(5) == 0         # free blocks report zero
    # empty-sequence release is an explicit no-op, not an error
    assert alloc.release([]) == []
    assert alloc.stats() == {"total": 8, "free": 8, "in_use": 0,
                             "shared": 0, "groups": 2}


def test_block_allocator_matches_engine_block_stats_contract():
    """The engine's block_stats() is exactly the allocator's stats():
    the keys the serving tests (and the churn invariants above) rely on
    are always present and always sum to n_blocks."""
    alloc = BlockAllocator(16, 2)
    a = alloc.allocate(3, 0)
    b = alloc.allocate(8, 1)
    s = alloc.stats()
    assert s["total"] == 16 and s["in_use"] == 11 and s["free"] == 5
    alloc.release(a + b)
    assert alloc.stats()["free"] == 16


def test_block_allocator_no_grant_after_free():
    """A released block sits in its free list until re-allocated — it
    is never still reachable through its previous holder.  Draining the
    sub-pool after a release must hand every id out exactly once."""
    alloc = BlockAllocator(8, 2)
    held = alloc.allocate(3, 0)
    freed = held.pop(1)
    alloc.release([freed])
    drained = []
    while True:
        blk = alloc.allocate_one(0)
        if blk is None:
            break
        drained.append(blk)
    # the freed block came back exactly once; the still-held ones never
    assert drained.count(freed) == 1
    assert not (set(drained) & set(held))
    assert sorted(drained + held) == list(range(4))   # group 0 = ids [0,4)
    assert alloc.free_in(0) == 0 and alloc.low_water(0) == 0


def test_block_allocator_low_water_tracks_minimum():
    alloc = BlockAllocator(8, 1)
    assert alloc.low_water() == 8
    a = alloc.allocate(5)
    assert alloc.low_water() == 3
    alloc.release(a)
    assert alloc.low_water() == 3, "watermark must survive the refill"
    b = alloc.allocate(7)
    assert alloc.low_water() == 1
    alloc.release(b)
    assert alloc.stats()["free"] == 8


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(POOL_GEOMETRIES),
           st.lists(st.tuples(st.floats(0, 1), st.integers(0, 7),
                              st.integers(0, 12), st.integers(0, 1 << 20)),
                    min_size=1, max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_block_allocator_churn_invariants_hypothesis(geom, raw_ops):
        n_blocks, groups = geom
        ops = [(k, g % groups, need, pick) for k, g, need, pick in raw_ops]
        alloc, live, refs = _fuzz_allocator(n_blocks, groups, ops,
                                            n_blocks // groups)
        for got in live:
            alloc.release(got)
        assert alloc.stats()["free"] == n_blocks


# =====================================================================
# serving-engine churn fuzz: grow-on-demand grants, victim preemption,
# sub-pool migration, and shedding under a seeded chaotic workload —
# the engine-level invariants the allocator fuzz cannot see (token
# identity across evictions, the slot→sub-pool contract through
# migration, shed requests never holding blocks)
# =====================================================================

@pytest.mark.parametrize("seed", [0, 1])
def test_engine_churn_fuzz_grant_preempt_migrate(seed):
    """Grant-mode engine on a deliberately tight 2-sub-pool geometry,
    with injected grant denials AND random forced evictions: every
    request that finishes must be token-identical to its uninterrupted
    single-request run, every tick must conserve blocks and respect the
    slot→sub-pool contract, parked/shed requests must hold nothing, and
    the drained pool must be whole."""
    import jax
    from repro.configs import get_arch
    from repro.models import lm
    from repro.models.lm import RunCfg
    from repro.serve.engine import PreemptionPolicy, ServeEngine

    cfg = RunCfg(block_q=16, ssd_chunk=16)
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    rng = random.Random(seed)
    prompts = [np.asarray([(i * 7 + j * 3 + 1) % arch.vocab_size
                           for j in range(plen)], np.int32)
               for i, plen in enumerate([5, 8, 11, 8, 5][:5])]
    new = 8
    want = []
    for p in prompts:
        e = ServeEngine(arch, params, cfg, max_batch=1, max_len=32)
        e.submit(p, max_new_tokens=new)
        want.append(e.run_until_idle(max_ticks=64)[0].out_tokens)

    eng = ServeEngine(arch, params, cfg, max_batch=4, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=8,
                      kv_admission="grant", kv_pool_groups=2,
                      preemption=PreemptionPolicy(max_preemptions=30,
                                                  backoff_base_ticks=1,
                                                  backoff_cap_ticks=4))
    eng.grant_fault = lambda: rng.random() < 0.2
    for p in prompts:
        eng.submit(p, max_new_tokens=new)
    ticks = 0
    while (eng.pending or eng.active or eng.preempted) and ticks < 600:
        if eng.active and (ticks == 3 or rng.random() < 0.05):
            # tick 3 guarantees >= 1 mid-decode eviction + re-prefill
            # even when migration absorbs every injected denial
            eng.preempt(rng.choice(list(eng.active.values())).rid)
        eng.step()
        ticks += 1
        stats = eng.block_stats()      # conservation asserts internally
        held = [b for r in eng.active.values() for b in r.blocks]
        assert len(held) == len(set(held)) == stats["in_use"], \
            "a block is held by two slots (or leaked)"
        for slot, r in eng.active.items():
            g = eng._slot_group(slot)
            assert all(eng._alloc.group_of(b) == g for b in r.blocks), \
                "slot -> sub-pool contract violated"
        for r in eng.shed:
            assert not r.blocks and r.error, "shed request holds blocks"
        for parked in eng.preempted:
            assert not parked.request.blocks, "parked eviction holds blocks"
    assert not (eng.pending or eng.active or eng.preempted), \
        "fuzz run did not drain"
    assert eng.preemptions >= 1, "churn never forced an eviction"
    assert len(eng.finished) + len(eng.shed) == len(prompts)
    got = {r.prompt.tobytes(): r.out_tokens for r in eng.finished}
    for p, w in zip(prompts, want):
        if p.tobytes() in got:
            assert got[p.tobytes()] == w, \
                "preempted request diverged from its uninterrupted run"
    assert eng.block_stats()["free"] == 8, "blocks leaked"


# =====================================================================
# pool-geometry invariants (the 2-D sharding contract the pass and the
# allocator both lean on) — seeded random, always runs
# =====================================================================

@pytest.mark.parametrize("seed", [0, 1])
def test_kv_block_geometry_2d_invariants(seed):
    rng = random.Random(seed)
    for _ in range(200):
        seq = rng.choice([64, 256, 1024, 4096, 32768])
        batch = rng.randint(1, 256)
        d = rng.choice([1, 2, 4, 8, 16])
        m = rng.choice([1, 2, 4, 8, 16])
        budget = rng.choice([None, 0.0, 2.0**rng.randint(20, 40)])
        geo = kv_block_geometry(seq, batch, 4, 2, 64, budget_bytes=budget,
                                data_shards=d, align=m)
        # the pool always splits into d equal, model-shardable sub-pools
        assert geo.n_blocks % d == 0
        sub = geo.n_blocks // d
        assert sub % m == 0
        # each sub-pool can always host at least one full sequence
        assert sub >= geo.blocks_per_seq
        # capacity never exceeds the worst case (every slot at max
        # depth) or the aligned one-sequence-per-sub-pool floor
        per = geo.blocks_per_seq
        floor_sub = m * math.ceil(per / m) if m > 1 else per
        assert geo.n_blocks <= max(batch * per, d * floor_sub)
        assert geo.data_degree == d and geo.sub_pool_blocks == sub


# =====================================================================
# hypothesis tier (skipped cleanly when hypothesis is unavailable)
# =====================================================================

if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
           st.data())
    @settings(max_examples=200, deadline=None)
    def test_resolve_pspec_always_divides(shape, data):
        axes = tuple(data.draw(st.sampled_from(AXIS_NAMES))
                     for _ in shape)
        spec = resolve_pspec(RULES, shape, axes, SIZES)
        used = set()
        for dim, s in zip(shape, tuple(spec) + (None,) * len(shape)):
            if s is None:
                continue
            names = (s,) if isinstance(s, str) else tuple(s)
            f = 1
            for n in names:
                assert n not in used      # a mesh axis shards one dim only
                used.add(n)
                f *= SIZES[n]
            assert dim % f == 0           # divisibility repair worked

    @given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                    min_size=1, max_size=2048))
    @settings(max_examples=100, deadline=None)
    def test_int8_quantization_error_bound(vals):
        x = jnp.asarray(np.array(vals, np.float32))
        q, s, pad = quantize_int8(x)
        xr = dequantize_int8(q, s, pad, x.shape)
        # per-block error bounded by scale/2 = amax/254
        blocks = np.asarray(jnp.abs(x)).reshape(-1)
        bound = max(blocks.max() / 254.0, 1e-6) * 1.001
        assert float(jnp.abs(xr - x).max()) <= bound + 1e-6

    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=8, max_size=256),
           st.integers(2, 10))
    @settings(max_examples=50, deadline=None)
    def test_error_feedback_preserves_sum(vals, steps):
        """Sum of delivered values + residual == sum of inputs."""
        x = jnp.asarray(np.array(vals, np.float32))
        err = None
        delivered = jnp.zeros_like(x)
        for _ in range(steps):
            xh, err = ef_compress(x, err)
            delivered = delivered + xh
        total_in = float(jnp.sum(x)) * steps
        total_out = float(jnp.sum(delivered)) + float(jnp.sum(
            err.astype(jnp.float32)))
        scale = max(abs(total_in), 1.0)
        assert abs(total_in - total_out) / scale < 0.02

    @given(st.integers(1, 100_000), st.integers(2, 64))
    @settings(max_examples=100, deadline=None)
    def test_ring_collective_inequalities(nbytes, n):
        ar = allreduce_bytes(nbytes, n)
        rs = reduce_scatter_bytes(nbytes, n)
        ag = allgather_bytes(nbytes, n)
        assert abs(ar - (rs + ag)) < 1e-6     # AR = RS + AG (ring identity)
        assert 0 <= rs < nbytes

    @given(st.integers(1, 65536), st.integers(1, 128), st.integers(1, 8),
           st.floats(1.0, 2.0))
    @settings(max_examples=100, deadline=None)
    def test_moe_capacity_sane(tokens, experts, topk, cf):
        c = _capacity(tokens, experts, topk, cf)
        assert c >= 4 and c % 4 == 0
        # enough capacity for a perfectly balanced router
        assert c * experts >= min(tokens * topk, 4 * experts) * 0.99

    @given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_mesh_model_device_count(a, b, c):
        m = MeshModel(axes=("pod", "data", "model"), shape=(a, b, c))
        assert m.n_devices == a * b * c
        assert m.axis_size("data") == b
        assert m.axis_size(None) == 1
