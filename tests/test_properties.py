"""Property-based tests (hypothesis) on the system's invariants."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.costmodel import (MeshModel, allgather_bytes, allreduce_bytes,
                                  reduce_scatter_bytes)
from repro.dist.collectives import dequantize_int8, ef_compress, quantize_int8
from repro.dist.sharding import resolve_pspec
from repro.models.moe import _capacity


AXIS_NAMES = st.sampled_from([None, "batch", "embed", "heads", "ff", "vocab"])
RULES = {"batch": "data", "embed": None, "heads": "model", "ff": "model",
         "vocab": "model"}
SIZES = {"data": 16, "model": 16}


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       st.data())
@settings(max_examples=200, deadline=None)
def test_resolve_pspec_always_divides(shape, data):
    axes = tuple(data.draw(AXIS_NAMES) for _ in shape)
    spec = resolve_pspec(RULES, shape, axes, SIZES)
    used = set()
    for dim, s in zip(shape, tuple(spec) + (None,) * len(shape)):
        if s is None:
            continue
        names = (s,) if isinstance(s, str) else tuple(s)
        f = 1
        for n in names:
            assert n not in used          # a mesh axis shards one dim only
            used.add(n)
            f *= SIZES[n]
        assert dim % f == 0               # divisibility repair worked


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=2048))
@settings(max_examples=100, deadline=None)
def test_int8_quantization_error_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, s, pad = quantize_int8(x)
    xr = dequantize_int8(q, s, pad, x.shape)
    # per-block error bounded by scale/2 = amax/254
    blocks = np.asarray(jnp.abs(x)).reshape(-1)
    bound = max(blocks.max() / 254.0, 1e-6) * 1.001
    assert float(jnp.abs(xr - x).max()) <= bound + 1e-6


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=8, max_size=256),
       st.integers(2, 10))
@settings(max_examples=50, deadline=None)
def test_error_feedback_preserves_sum(vals, steps):
    """Sum of delivered values + residual == sum of inputs (unbiasedness)."""
    x = jnp.asarray(np.array(vals, np.float32))
    err = None
    delivered = jnp.zeros_like(x)
    for _ in range(steps):
        xh, err = ef_compress(x, err)
        delivered = delivered + xh
    total_in = float(jnp.sum(x)) * steps
    total_out = float(jnp.sum(delivered)) + float(jnp.sum(
        err.astype(jnp.float32)))
    scale = max(abs(total_in), 1.0)
    assert abs(total_in - total_out) / scale < 0.02


@given(st.integers(1, 100_000), st.integers(2, 64))
@settings(max_examples=100, deadline=None)
def test_ring_collective_inequalities(nbytes, n):
    ar = allreduce_bytes(nbytes, n)
    rs = reduce_scatter_bytes(nbytes, n)
    ag = allgather_bytes(nbytes, n)
    assert abs(ar - (rs + ag)) < 1e-6     # AR = RS + AG (ring identity)
    assert 0 <= rs < nbytes


@given(st.integers(1, 65536), st.integers(1, 128), st.integers(1, 8),
       st.floats(1.0, 2.0))
@settings(max_examples=100, deadline=None)
def test_moe_capacity_sane(tokens, experts, topk, cf):
    c = _capacity(tokens, experts, topk, cf)
    assert c >= 4 and c % 4 == 0
    # enough capacity for a perfectly balanced router
    assert c * experts >= min(tokens * topk, 4 * experts) * 0.99


@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_mesh_model_device_count(a, b, c):
    m = MeshModel(axes=("pod", "data", "model"), shape=(a, b, c))
    assert m.n_devices == a * b * c
    assert m.axis_size("data") == b
    assert m.axis_size(None) == 1
