"""Property-based tests on the system's invariants.

Two tiers: hypothesis-driven properties (skipped when hypothesis is not
installed) and seeded stdlib-random fuzz that always runs — the block-
allocator suite is in the second tier so the serving layer's invariants
are exercised in every CI environment, not only where hypothesis
happens to be available.
"""

import math
import random

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

from repro.core.costmodel import (MeshModel, allgather_bytes, allreduce_bytes,
                                  kv_block_geometry, reduce_scatter_bytes)
from repro.dist.collectives import dequantize_int8, ef_compress, quantize_int8
from repro.dist.sharding import resolve_pspec
from repro.models.moe import _capacity
from repro.serve.allocator import BlockAllocator


AXIS_NAMES = [None, "batch", "embed", "heads", "ff", "vocab"]
RULES = {"batch": "data", "embed": None, "heads": "model", "ff": "model",
         "vocab": "model"}
SIZES = {"data": 16, "model": 16}


# =====================================================================
# block-allocator fuzz: randomized admit/finish/exhaustion/churn
# sequences against the paged serving layer's invariants, on both 1-D
# (one global pool) and 2-D (per-data-shard sub-pool) geometries.
# Runs on seeded stdlib random so it exercises in every environment;
# a hypothesis twin below widens the sequences when available.
# =====================================================================

#: (n_blocks, groups, host_blocks): 1-D pools and 2-D data-degree
#: sub-pool splits, with and without a host spill tier behind them
POOL_GEOMETRIES = [(8, 1, 0), (24, 1, 16), (16, 2, 8), (32, 4, 0),
                   (64, 8, 32)]


def _fuzz_allocator(n_blocks: int, groups: int, ops, max_need: int,
                    host_blocks: int = 0):
    """Drive one admit/grant/retain/spill/promote/finish sequence,
    asserting every invariant the serving engine relies on after each
    step.

    ``ops`` yields (kind, group, need, pick) tuples; kind < 0.35 admits
    a multi-block budget, kind < 0.5 is a one-block grow-on-demand
    grant appended to a random live holder, kind < 0.6 retains a random
    live holder's blocks into a new alias holder (a prefix-cache hit),
    kind < 0.7 spills a holder's *private* HBM blocks to the host tier
    (shared blocks stay put in the fuzz — the other holders' lists
    would go stale; the engine re-keys every table on a shared spill),
    kind < 0.8 promotes a holder's private host blocks into the op's
    sub-pool, kind < 0.85 starts a new low-water epoch, else a random
    live holder finishes — its blocks only come back to the free list
    once every alias has finished too.  Returns the live set for the
    caller's drain check.

    The ``refs`` model (block -> holder count) encodes *no grant after
    free* AND *no free while shared* directly: a block leaves the model
    only when its last holder releases it, so a grant handing out a
    block some holder still owns — freed out from under it, or freed
    while a sharer survived — trips the double-assignment assert.  The
    ``water`` model is exact: the watermark equals the minimum free
    count since the last epoch reset, pinned with equality — the
    ratchet-forever bug (a watermark that survives a reset) and a
    watermark that misses a spill/promote draw both trip it.
    """
    alloc = BlockAllocator(n_blocks, groups, host_blocks=host_blocks)
    sub = n_blocks // groups
    live = []                     # allocations currently held
    refs = {}                     # model: block id -> holder count
    water = [alloc.low_water(g) for g in range(groups)]
    epochs = 0
    for kind, group, need, pick in ops:
        if kind < 0.35 or not live:
            got = alloc.allocate(need, group)
            if got is None:
                # exhaustion is exact: refusal iff the sub-pool cannot
                # cover the request (head-of-line wait in the engine)
                assert need > alloc.free_in(group)
            else:
                assert len(got) == need
                assert not (set(got) & set(refs)), "double-assigned block"
                assert all(b // sub == group for b in got), \
                    "allocation crossed a sub-pool boundary"
                for b in got:
                    refs[b] = 1
                live.append(got)
        elif kind < 0.5:
            # grow-on-demand: one-block grant onto a live holder
            blk = alloc.allocate_one(group)
            if blk is None:
                assert alloc.free_in(group) == 0
            else:
                assert blk not in refs, "granted a freed/held block"
                assert blk // sub == group
                refs[blk] = 1
                live[pick % len(live)].append(blk)
        elif kind < 0.6:
            # prefix-cache hit: alias an existing holder's blocks
            got = list(live[pick % len(live)])
            alloc.retain(got)
            for b in got:
                refs[b] += 1
            live.append(got)
        elif kind < 0.7 and host_blocks:
            # spill: one holder's private HBM blocks move to host ids,
            # all-or-none (a partial spill would strand the holder)
            holder = live[pick % len(live)]
            cand = [b for b in holder if b < n_blocks and refs[b] == 1]
            pairs = alloc.spill(cand)
            if pairs is None:
                assert len(cand) > alloc.host_free, \
                    "spill refused despite host headroom"
            else:
                assert [o for o, _ in pairs] == cand
                for o, h in pairs:
                    assert h >= n_blocks, "spill produced an HBM id"
                    refs[h] = refs.pop(o)
                    holder[holder.index(o)] = h
        elif kind < 0.8 and host_blocks:
            # promote: one holder's private host blocks move back into
            # the op's sub-pool (group integrity by construction)
            holder = live[pick % len(live)]
            cand = [b for b in holder if b >= n_blocks and refs[b] == 1]
            pairs = alloc.promote(cand, group)
            if pairs is None:
                assert len(cand) > alloc.free_in(group), \
                    "promote refused despite sub-pool headroom"
            else:
                for h, b in pairs:
                    assert b // sub == group, "promote crossed a sub-pool"
                    refs[b] = refs.pop(h)
                    holder[holder.index(h)] = b
        elif kind < 0.85:
            # rebalance-cycle epoch boundary: the watermark snaps to
            # the current free count instead of ratcheting forever
            alloc.reset_low_water()
            epochs += 1
            assert alloc.low_water_epochs == epochs
            for g in range(groups):
                water[g] = alloc.free_in(g)
        else:
            got = live.pop(pick % len(live))
            freed = alloc.release(got)
            want_freed = set()
            for b in got:
                refs[b] -= 1
                if refs[b] == 0:
                    del refs[b]
                    want_freed.add(b)
            assert set(freed) == want_freed, \
                "release freed the wrong blocks (refcount drift)"
        stats = alloc.stats()
        assert stats["total"] == n_blocks
        assert stats["free"] + stats["in_use"] == n_blocks, \
            "HBM blocks not conserved"
        assert stats["host_free"] + stats["host_in_use"] == host_blocks, \
            "host blocks not conserved"
        hbm_refs = sum(1 for b in refs if b < n_blocks)
        assert stats["in_use"] == hbm_refs
        assert stats["host_in_use"] == len(refs) - hbm_refs
        assert stats["shared"] == sum(1 for c in refs.values() if c > 1)
        for b, c in refs.items():
            assert alloc.refcount(b) == c, "refcount drift"
            assert alloc.tier_of(b) == ("hbm" if b < n_blocks else "host")
        assert sum(alloc.free_in(g) for g in range(groups)) == stats["free"]
        for g in range(groups):
            # exact watermark model: the minimum free count since the
            # last epoch reset (free only dips within an op, so the
            # post-op value is the op's minimum)
            water[g] = min(water[g], alloc.free_in(g))
            assert alloc.low_water(g) == water[g], "watermark drift"
    return alloc, live, refs


@pytest.mark.parametrize("n_blocks,groups,host_blocks", POOL_GEOMETRIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_block_allocator_churn_invariants(n_blocks, groups, host_blocks,
                                          seed):
    rng = random.Random(f"{n_blocks}/{groups}/{host_blocks}/{seed}")
    sub = n_blocks // groups
    ops = [(rng.random(), rng.randrange(groups),
            rng.randint(0, sub + 1),      # +1: requests past sub capacity
            rng.randrange(1 << 30)) for _ in range(400)]
    alloc, live, refs = _fuzz_allocator(n_blocks, groups, ops, sub,
                                        host_blocks)
    # drain: releasing every holder (aliases included) restores the
    # full pool — no leaks, no lingering refcounts, in either tier
    for got in live:
        alloc.release(got)
    assert alloc.release([]) == []        # empty release is a no-op
    assert alloc.stats() == {"total": n_blocks, "free": n_blocks,
                             "in_use": 0, "shared": 0, "groups": groups,
                             "host_total": host_blocks,
                             "host_free": host_blocks, "host_in_use": 0}


def test_block_allocator_rejects_bad_usage():
    with pytest.raises(ValueError, match="multiple"):
        BlockAllocator(10, 4)             # unequal sub-pools
    with pytest.raises(ValueError, match="groups"):
        BlockAllocator(8, 0)
    alloc = BlockAllocator(8, 2)
    got = alloc.allocate(2, group=1)
    assert got == [4, 5]                  # group 1 owns ids [4, 8)
    alloc.release(got)
    with pytest.raises(ValueError, match="double free"):
        alloc.release(got)                # already back in the pool
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.release([0])                # never handed out
    assert alloc.allocate(5, group=0) is None      # > sub-pool capacity
    assert alloc.stats()["free"] == 8


def test_block_allocator_refcount_lifecycle():
    """The sharing contract the prefix cache leans on: retain bumps,
    release decrements, and a block returns to its free list only when
    the LAST holder lets go — with misuse staying loud."""
    alloc = BlockAllocator(8, 2)
    got = alloc.allocate(2, group=0)
    assert [alloc.refcount(b) for b in got] == [1, 1]
    alloc.retain(got)                     # a second holder aliases both
    assert [alloc.refcount(b) for b in got] == [2, 2]
    assert alloc.stats()["shared"] == 2
    assert alloc.release(got) == []       # first holder: nothing freed
    assert alloc.stats()["in_use"] == 2   # still resident via the alias
    assert alloc.stats()["shared"] == 0
    assert sorted(alloc.release(got)) == sorted(got)   # last holder frees
    with pytest.raises(ValueError, match="double free"):
        alloc.release(got)
    with pytest.raises(ValueError, match="retain a free"):
        alloc.retain(got)                 # can't resurrect a freed block
    with pytest.raises(ValueError, match="retain a free"):
        alloc.retain([7])                 # never handed out
    assert alloc.refcount(5) == 0         # free blocks report zero
    # empty-sequence release is an explicit no-op, not an error
    assert alloc.release([]) == []
    assert alloc.stats() == {"total": 8, "free": 8, "in_use": 0,
                             "shared": 0, "groups": 2, "host_total": 0,
                             "host_free": 0, "host_in_use": 0}


def test_block_allocator_matches_engine_block_stats_contract():
    """The engine's block_stats() is exactly the allocator's stats():
    the keys the serving tests (and the churn invariants above) rely on
    are always present and always sum to n_blocks."""
    alloc = BlockAllocator(16, 2)
    a = alloc.allocate(3, 0)
    b = alloc.allocate(8, 1)
    s = alloc.stats()
    assert s["total"] == 16 and s["in_use"] == 11 and s["free"] == 5
    alloc.release(a + b)
    assert alloc.stats()["free"] == 16


def test_block_allocator_no_grant_after_free():
    """A released block sits in its free list until re-allocated — it
    is never still reachable through its previous holder.  Draining the
    sub-pool after a release must hand every id out exactly once."""
    alloc = BlockAllocator(8, 2)
    held = alloc.allocate(3, 0)
    freed = held.pop(1)
    alloc.release([freed])
    drained = []
    while True:
        blk = alloc.allocate_one(0)
        if blk is None:
            break
        drained.append(blk)
    # the freed block came back exactly once; the still-held ones never
    assert drained.count(freed) == 1
    assert not (set(drained) & set(held))
    assert sorted(drained + held) == list(range(4))   # group 0 = ids [0,4)
    assert alloc.free_in(0) == 0 and alloc.low_water(0) == 0


def test_block_allocator_low_water_tracks_minimum():
    alloc = BlockAllocator(8, 1)
    assert alloc.low_water() == 8
    a = alloc.allocate(5)
    assert alloc.low_water() == 3
    alloc.release(a)
    assert alloc.low_water() == 3, "watermark must survive the refill"
    b = alloc.allocate(7)
    assert alloc.low_water() == 1
    alloc.release(b)
    assert alloc.stats()["free"] == 8


def test_reset_low_water_starts_new_epoch():
    """The ratchet-forever fix: without an epoch reset, one transient
    dip pins the watermark for the allocator's whole lifetime and the
    engine's rebalancer reads a permanently hot sub-pool.  After
    ``reset_low_water()`` the mark reports only *this* epoch's minimum
    — and a promote's sub-pool draw dips it exactly like a grant."""
    alloc = BlockAllocator(8, 1, host_blocks=4)
    a = alloc.allocate(7)
    alloc.release(a)
    assert alloc.low_water() == 1         # the transient dip, ratcheted
    alloc.reset_low_water()
    assert alloc.low_water() == 8, "epoch reset must snap to current free"
    assert alloc.low_water_epochs == 1
    b = alloc.allocate(2)
    assert alloc.low_water() == 6         # this epoch's own minimum
    pairs = alloc.spill(b)
    assert alloc.free == 8                # spill returns the HBM ids…
    assert alloc.low_water() == 6         # …but never raises the mark
    got = alloc.promote([h for _, h in pairs], 0)
    assert alloc.low_water() == 6         # promote drew 2 of 8 again
    alloc.release([nb for _, nb in got])
    alloc.reset_low_water()
    assert alloc.low_water() == 8 and alloc.low_water_epochs == 2


def test_block_allocator_tier_transitions_reject_bad_usage():
    """Spill/promote misuse stays loud: wrong tier, non-resident ids,
    duplicates, and over-capacity moves all refuse instead of
    corrupting the accounting."""
    alloc = BlockAllocator(8, 2, host_blocks=4)
    got = alloc.allocate(3, group=0)
    pairs = alloc.spill(got[:2])
    host = [h for _, h in pairs]
    assert all(alloc.tier_of(h) == "host" for h in host)
    assert alloc.free_in(0) == 3          # the vacated ids came home
    with pytest.raises(ValueError, match="host-resident"):
        alloc.spill(host)                 # already host-tier
    with pytest.raises(ValueError, match="hbm-resident"):
        alloc.promote([got[2]], 0)        # still HBM-resident
    free_host = (set(range(8, 12)) - set(host)).pop()
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.promote([free_host], 0)     # never spilled into
    with pytest.raises(ValueError, match="listed twice"):
        alloc.promote([host[0], host[0]], 0)
    back = alloc.promote(host, 1)         # promote may target any group
    assert all(4 <= b < 8 for _, b in back), "promote missed its group"
    alloc.release([got[2]] + [b for _, b in back])
    assert alloc.stats()["free"] == 8
    assert alloc.stats()["host_free"] == 4
    with pytest.raises(ValueError, match="outside both tiers"):
        alloc.tier_of(12)
    with pytest.raises(ValueError, match="outside HBM pool"):
        alloc.group_of(8)                 # host ids have no group


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(POOL_GEOMETRIES),
           st.lists(st.tuples(st.floats(0, 1), st.integers(0, 7),
                              st.integers(0, 12), st.integers(0, 1 << 20)),
                    min_size=1, max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_block_allocator_churn_invariants_hypothesis(geom, raw_ops):
        n_blocks, groups, host_blocks = geom
        ops = [(k, g % groups, need, pick) for k, g, need, pick in raw_ops]
        alloc, live, refs = _fuzz_allocator(n_blocks, groups, ops,
                                            n_blocks // groups, host_blocks)
        for got in live:
            alloc.release(got)
        assert alloc.stats()["free"] == n_blocks
        assert alloc.stats()["host_free"] == host_blocks


# =====================================================================
# serving-engine churn fuzz: grow-on-demand grants, victim preemption,
# sub-pool migration, and shedding under a seeded chaotic workload —
# the engine-level invariants the allocator fuzz cannot see (token
# identity across evictions, the slot→sub-pool contract through
# migration, shed requests never holding blocks)
# =====================================================================

@pytest.mark.parametrize("seed", [0, 1])
def test_engine_churn_fuzz_grant_preempt_migrate(seed):
    """Grant-mode engine on a deliberately tight 2-sub-pool geometry,
    with injected grant denials AND random forced evictions: every
    request that finishes must be token-identical to its uninterrupted
    single-request run, every tick must conserve blocks and respect the
    slot→sub-pool contract, parked/shed requests must hold nothing, and
    the drained pool must be whole."""
    import jax
    from repro.configs import get_arch
    from repro.models import lm
    from repro.models.lm import RunCfg
    from repro.serve.engine import PreemptionPolicy, ServeEngine

    cfg = RunCfg(block_q=16, ssd_chunk=16)
    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    rng = random.Random(seed)
    prompts = [np.asarray([(i * 7 + j * 3 + 1) % arch.vocab_size
                           for j in range(plen)], np.int32)
               for i, plen in enumerate([5, 8, 11, 8, 5][:5])]
    new = 8
    want = []
    for p in prompts:
        e = ServeEngine(arch, params, cfg, max_batch=1, max_len=32)
        e.submit(p, max_new_tokens=new)
        want.append(e.run_until_idle(max_ticks=64)[0].out_tokens)

    eng = ServeEngine(arch, params, cfg, max_batch=4, max_len=32,
                      kv_residency="paged", kv_block_len=8, kv_n_blocks=8,
                      kv_admission="grant", kv_pool_groups=2,
                      preemption=PreemptionPolicy(max_preemptions=30,
                                                  backoff_base_ticks=1,
                                                  backoff_cap_ticks=4))
    eng.grant_fault = lambda: rng.random() < 0.2
    for p in prompts:
        eng.submit(p, max_new_tokens=new)
    ticks = 0
    while (eng.pending or eng.active or eng.preempted) and ticks < 600:
        if eng.active and (ticks == 3 or rng.random() < 0.05):
            # tick 3 guarantees >= 1 mid-decode eviction + re-prefill
            # even when migration absorbs every injected denial
            eng.preempt(rng.choice(list(eng.active.values())).rid)
        eng.step()
        ticks += 1
        stats = eng.block_stats()      # conservation asserts internally
        held = [b for r in eng.active.values() for b in r.blocks]
        assert len(held) == len(set(held)) == stats["in_use"], \
            "a block is held by two slots (or leaked)"
        for slot, r in eng.active.items():
            g = eng._slot_group(slot)
            assert all(eng._alloc.group_of(b) == g for b in r.blocks), \
                "slot -> sub-pool contract violated"
        for r in eng.shed:
            assert not r.blocks and r.error, "shed request holds blocks"
        for parked in eng.preempted:
            assert not parked.request.blocks, "parked eviction holds blocks"
    assert not (eng.pending or eng.active or eng.preempted), \
        "fuzz run did not drain"
    assert eng.preemptions >= 1, "churn never forced an eviction"
    assert len(eng.finished) + len(eng.shed) == len(prompts)
    got = {r.prompt.tobytes(): r.out_tokens for r in eng.finished}
    for p, w in zip(prompts, want):
        if p.tobytes() in got:
            assert got[p.tobytes()] == w, \
                "preempted request diverged from its uninterrupted run"
    assert eng.block_stats()["free"] == 8, "blocks leaked"


def test_engine_resets_low_water_epoch_per_rebalance_cycle():
    """The engine owns the epoch clock: once per shed window it calls
    ``reset_low_water()``, so a burst that drained a sub-pool early in
    an engine's life stops reading as permanent pressure.  Before the
    fix the watermark ratcheted down forever."""
    import jax
    from repro.configs import get_arch
    from repro.models import lm
    from repro.models.lm import RunCfg
    from repro.serve.engine import PreemptionPolicy, ServeEngine

    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    eng = ServeEngine(arch, params, RunCfg(block_q=16, ssd_chunk=16),
                      max_batch=2, max_len=32, kv_residency="paged",
                      kv_block_len=8, kv_n_blocks=4, kv_admission="grant",
                      preemption=PreemptionPolicy(shed_window_ticks=4))
    eng.submit(np.arange(11, dtype=np.int32) % arch.vocab_size,
               max_new_tokens=6)
    eng.run_until_idle(max_ticks=64)
    assert eng._alloc.low_water() < 4, "the burst never dipped the mark"
    dipped = eng._alloc.low_water()
    ticks = eng.tick
    while eng.tick < ticks + 8:           # two idle rebalance windows
        eng.step()
    assert eng._alloc.low_water_epochs >= 2
    assert eng._alloc.low_water() == 4, \
        f"watermark stuck at the historical dip ({dipped}) after the " \
        "rebalance epoch reset"


# =====================================================================
# pool-geometry invariants (the 2-D sharding contract the pass and the
# allocator both lean on) — seeded random, always runs
# =====================================================================

@pytest.mark.parametrize("seed", [0, 1])
def test_kv_block_geometry_2d_invariants(seed):
    rng = random.Random(seed)
    for _ in range(200):
        seq = rng.choice([64, 256, 1024, 4096, 32768])
        batch = rng.randint(1, 256)
        d = rng.choice([1, 2, 4, 8, 16])
        m = rng.choice([1, 2, 4, 8, 16])
        budget = rng.choice([None, 0.0, 2.0**rng.randint(20, 40)])
        geo = kv_block_geometry(seq, batch, 4, 2, 64, budget_bytes=budget,
                                data_shards=d, align=m)
        # the pool always splits into d equal, model-shardable sub-pools
        assert geo.n_blocks % d == 0
        sub = geo.n_blocks // d
        assert sub % m == 0
        # each sub-pool can always host at least one full sequence
        assert sub >= geo.blocks_per_seq
        # capacity never exceeds the worst case (every slot at max
        # depth) or the aligned one-sequence-per-sub-pool floor
        per = geo.blocks_per_seq
        floor_sub = m * math.ceil(per / m) if m > 1 else per
        assert geo.n_blocks <= max(batch * per, d * floor_sub)
        assert geo.data_degree == d and geo.sub_pool_blocks == sub


# =====================================================================
# wire-compression round-trip fuzz (the lowered train step's reduction
# primitive) + combine-topology dispatch invariants — seeded random,
# always runs
# =====================================================================

#: (r, shape): stacked-slice degrees x leaf shapes, covering last dims
#: below / at / straddling the 128-element quantization block, a
#: 1-element last dim, and a multi-axis leaf
SLICE_SHAPES = [(1, (5,)), (2, (1,)), (2, (127,)), (3, (128,)),
                (4, (129,)), (8, (300,)), (2, (3, 70)), (4, (2, 2, 40))]


@pytest.mark.parametrize("seed", [0, 1])
def test_compressed_slice_sum_roundtrip_invariants(seed):
    """The contracts the lowered wire step leans on, fuzzed over shapes
    and scales: shape/dtype preservation, the telescoping identity
    ``mean + mean_i(err_i) == mean_i(x_i)`` (exact up to f32 rounding),
    the per-block error bound ``|err| <= amax_block / 254`` with the
    scale shared across slices, and the degenerates — an all-zero
    stack round-trips to exact zeros, and a single slice (r=1)
    reconstructs exactly via its own residual."""
    from repro.dist.collectives import BLOCK, compressed_slice_sum
    rng = np.random.default_rng(seed)
    for r, shape in SLICE_SHAPES:
        x = (rng.standard_normal((r,) + shape)
             * 10.0 ** rng.integers(-3, 3)).astype(np.float32)
        mean, err = compressed_slice_sum(jnp.asarray(x))
        assert mean.shape == shape and mean.dtype == jnp.float32
        assert err.shape == x.shape and err.dtype == jnp.float32
        m, e = np.asarray(mean), np.asarray(err)
        # telescoping identity, per element
        scale = max(np.abs(x).max(), 1.0)
        assert np.abs((m + e.mean(0)) - x.mean(0)).max() < 1e-6 * scale
        # per-block bound with the SHARED scale: amax over all slices
        d = x.shape[-1]
        pad = (-d) % BLOCK
        xp = np.concatenate(
            [x, np.zeros(x.shape[:-1] + (pad,), np.float32)], -1)
        blocks = xp.reshape(x.shape[:-1] + (-1, BLOCK))
        amax = np.abs(blocks).max(axis=-1).max(axis=0)   # shared over r
        ep = np.concatenate(
            [e, np.zeros(x.shape[:-1] + (pad,), np.float32)], -1)
        eb = np.abs(ep.reshape(x.shape[:-1] + (-1, BLOCK))).max(axis=-1)
        assert (eb <= amax[None] / 254.0 * 1.001 + 1e-9).all()
        if r == 1:
            # one slice: mean + err IS the input, exactly
            assert np.array_equal(m + e[0], x[0])
    # all-zero stack: codes are zero, scale floor never injects noise
    mean, err = compressed_slice_sum(jnp.zeros((4, 200), jnp.float32))
    assert not np.asarray(mean).any() and not np.asarray(err).any()


def test_compressed_slice_sum_matches_compressed_psum_degenerate():
    """r=1 slice sum == a 1-shard compressed_psum: the GSPMD twin and
    the shard_map primitive share one quantization recipe (a drift
    between them would silently change the wire semantics when the
    lowering gate flips between the two paths)."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import compressed_psum, compressed_slice_sum
    x = jnp.asarray(np.random.default_rng(3).standard_normal((3, 200)),
                    jnp.float32)
    mesh = jax.make_mesh((1,), ("data",))
    y1, e1 = jax.jit(jax.shard_map(
        lambda v: compressed_psum(v, "data"), mesh=mesh,
        in_specs=P(), out_specs=(P(), P())))(x)
    # jit both: op-by-op dequant rounds differently from the fused form
    y2, e2 = jax.jit(compressed_slice_sum)(x[None])
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert np.array_equal(np.asarray(e1), np.asarray(e2[0]))


def test_combine_topology_choice_is_a_total_order():
    """The calibrated thresholds induce a monotone map from model
    degree to topology rank (flat < ring < bidir): once the degree is
    large enough to leave a topology behind, no larger degree ever
    returns to it — the property that makes the plan decision stable
    under mesh growth."""
    from repro.core.costmodel import (COMBINE_BIDIR_DEGREE,
                                      COMBINE_RING_DEGREE,
                                      COMBINE_TOPOLOGIES,
                                      COMBINE_TOPOLOGY_RANK, combine_hops,
                                      choose_combine_topology)
    prev = 0
    for n in range(1, 65):
        topo = choose_combine_topology(n)
        assert topo in COMBINE_TOPOLOGIES
        rank = COMBINE_TOPOLOGY_RANK[topo]
        assert rank >= prev, (n, topo)
        prev = rank
    # the calibrated boundaries themselves
    assert choose_combine_topology(COMBINE_RING_DEGREE) == "flat"
    assert choose_combine_topology(COMBINE_RING_DEGREE + 1) == "ring"
    assert choose_combine_topology(COMBINE_BIDIR_DEGREE) == "ring"
    assert choose_combine_topology(COMBINE_BIDIR_DEGREE + 1) == "bidir"
    # hop counts: the latency-model ordering behind the thresholds
    for n in range(2, 65):
        assert combine_hops(n, "flat") == 6 * (n - 1)
        assert combine_hops(n, "ring") == n - 1
        assert combine_hops(n, "bidir") == (n - 1 + 1) // 2
        assert combine_hops(n, "bidir") <= combine_hops(n, "ring") \
            < combine_hops(n, "flat")
    for t in ("flat", "ring", "bidir"):
        assert combine_hops(1, t) == 0    # no cross-shard combine exists
    with pytest.raises(ValueError, match="topology"):
        combine_hops(4, "hypercube")


def test_combine_topology_dispatch_agreement_single_process():
    """Kernel predicate and engine agree off-mesh: a degenerate model
    axis reports "flat" regardless of the override (no combine exists
    to re-route), and a single-process engine — whose decode path is
    not shard_map — reports "flat" in telemetry even when its RunCfg
    pins "ring" (the plan override only binds where the sharded combine
    actually runs)."""
    import jax
    from repro.configs import get_arch
    from repro.dist.flash_decode import combine_topology
    from repro.models import lm
    from repro.models.lm import RunCfg
    from repro.serve.engine import ServeEngine

    mesh1 = jax.make_mesh((1,), ("model",))
    assert combine_topology(mesh1) == "flat"
    assert combine_topology(mesh1, override="bidir") == "flat"
    # the degenerate short-circuit wins even over a bogus override: no
    # combine exists to mis-route (the ValueError on real model axes is
    # pinned by the 8-device matrix test in test_multidevice)
    assert combine_topology(mesh1, override="hypercube") == "flat"
    # a mesh without the model axis at all is the same degenerate case
    assert combine_topology(jax.make_mesh((1,), ("data",))) == "flat"

    arch = get_arch("qwen3-8b").reduced()
    params = lm.init_params(arch, jax.random.PRNGKey(0))
    eng = ServeEngine(arch, params,
                      RunCfg(block_q=16, ssd_chunk=16,
                             combine_topology="ring"),
                      max_batch=1, max_len=32)
    assert eng.decode_path not in ("shard_map_flash",
                                   "shard_map_flash_paged_2d")
    assert eng.combine_topology == "flat"
    assert eng.telemetry()["combine_topology"] == "flat"


# =====================================================================
# hypothesis tier (skipped cleanly when hypothesis is unavailable)
# =====================================================================

if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
           st.data())
    @settings(max_examples=200, deadline=None)
    def test_resolve_pspec_always_divides(shape, data):
        axes = tuple(data.draw(st.sampled_from(AXIS_NAMES))
                     for _ in shape)
        spec = resolve_pspec(RULES, shape, axes, SIZES)
        used = set()
        for dim, s in zip(shape, tuple(spec) + (None,) * len(shape)):
            if s is None:
                continue
            names = (s,) if isinstance(s, str) else tuple(s)
            f = 1
            for n in names:
                assert n not in used      # a mesh axis shards one dim only
                used.add(n)
                f *= SIZES[n]
            assert dim % f == 0           # divisibility repair worked

    @given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                    min_size=1, max_size=2048))
    @settings(max_examples=100, deadline=None)
    def test_int8_quantization_error_bound(vals):
        x = jnp.asarray(np.array(vals, np.float32))
        q, s, pad = quantize_int8(x)
        xr = dequantize_int8(q, s, pad, x.shape)
        # per-block error bounded by scale/2 = amax/254
        blocks = np.asarray(jnp.abs(x)).reshape(-1)
        bound = max(blocks.max() / 254.0, 1e-6) * 1.001
        assert float(jnp.abs(xr - x).max()) <= bound + 1e-6

    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=8, max_size=256),
           st.integers(2, 10))
    @settings(max_examples=50, deadline=None)
    def test_error_feedback_preserves_sum(vals, steps):
        """Sum of delivered values + residual == sum of inputs."""
        x = jnp.asarray(np.array(vals, np.float32))
        err = None
        delivered = jnp.zeros_like(x)
        for _ in range(steps):
            xh, err = ef_compress(x, err)
            delivered = delivered + xh
        total_in = float(jnp.sum(x)) * steps
        total_out = float(jnp.sum(delivered)) + float(jnp.sum(
            err.astype(jnp.float32)))
        scale = max(abs(total_in), 1.0)
        assert abs(total_in - total_out) / scale < 0.02

    @given(st.integers(1, 6),
           st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                    min_size=1, max_size=512))
    @settings(max_examples=50, deadline=None)
    def test_compressed_slice_sum_telescopes_hypothesis(r, vals):
        """Hypothesis twin of the seeded round-trip fuzz: the
        telescoping identity holds for every stack degree and leaf the
        wire step could see."""
        from repro.dist.collectives import compressed_slice_sum
        base = np.asarray(vals, np.float32)
        x = jnp.asarray(np.stack([np.roll(base, i) for i in range(r)]))
        mean, err = compressed_slice_sum(x)
        lhs = np.asarray(mean) + np.asarray(err).mean(0)
        rhs = np.asarray(x).mean(0)
        scale = max(float(np.abs(base).max()), 1.0)
        assert np.abs(lhs - rhs).max() < 1e-6 * scale

    @given(st.integers(1, 100_000), st.integers(2, 64))
    @settings(max_examples=100, deadline=None)
    def test_ring_collective_inequalities(nbytes, n):
        ar = allreduce_bytes(nbytes, n)
        rs = reduce_scatter_bytes(nbytes, n)
        ag = allgather_bytes(nbytes, n)
        assert abs(ar - (rs + ag)) < 1e-6     # AR = RS + AG (ring identity)
        assert 0 <= rs < nbytes

    @given(st.integers(1, 65536), st.integers(1, 128), st.integers(1, 8),
           st.floats(1.0, 2.0))
    @settings(max_examples=100, deadline=None)
    def test_moe_capacity_sane(tokens, experts, topk, cf):
        c = _capacity(tokens, experts, topk, cf)
        assert c >= 4 and c % 4 == 0
        # enough capacity for a perfectly balanced router
        assert c * experts >= min(tokens * topk, 4 * experts) * 0.99

    @given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_mesh_model_device_count(a, b, c):
        m = MeshModel(axes=("pod", "data", "model"), shape=(a, b, c))
        assert m.n_devices == a * b * c
        assert m.axis_size("data") == b
        assert m.axis_size(None) == 1
