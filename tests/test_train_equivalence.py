"""Trajectory equivalence of the lowered int8+EF wire train step.

The tentpole claim of the lowered compression path is NOT "the loss is
close after one step" — it is that the *trajectory* of the compressed
run tracks the fp32 baseline across steps, because error feedback
telescopes: with per-slice residual ``e_i`` and delivered mean
``ghat_t = mean_i Q(g_i_t + e_i_t)``,

    sum_t ghat_t + mean_i e_i_T == sum_t mean_i g_i_t        (exactly)

so the cumulative delivered gradient differs from the cumulative true
gradient by ONE bounded residual (<= half a quantization step per
element), not by anything that grows with T.  Per-step loss divergence
is then bounded by the optimizer's sensitivity to that bounded kick —
small, and crucially not compounding.

These tests run the REAL lowered step (Trainer -> lower_train_step) on a
2x4 host-device mesh in a subprocess, and prove the wire claim on the
compiled HLO: with compression lowered there is no gradient-sized float
all-reduce in the step — the only big cross-data collectives are int16
code sums.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_subprocess(code: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_wire_trajectory_tracks_fp32_and_no_float_reduce_in_hlo():
    """int8+EF trajectory vs fp32 baseline over 4 steps on a 2x4 mesh,
    plus the wire proof: zero gradient-sized f32/bf16 reductions and >=1
    int16 all-reduce in the compiled compressed step."""
    run_subprocess("""
        import re
        import numpy as np
        import jax
        from collections import Counter
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import synthetic_batch
        from repro.optim.adamw import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig

        arch = get_arch("qwen3-8b").reduced()
        shape = ShapeConfig("wire_eq", "train", 64, 8)
        mesh = jax.make_mesh((2, 4), ("data", "model"))

        def run(gc):
            plan = specialize(arch, shape, mesh_axes=("data", "model"),
                              mesh_shape=(2, 4), cache=False,
                              grad_compression=gc)
            tr = Trainer(plan, mesh, TrainerConfig(n_steps=1, ckpt_every=0),
                         opt_cfg=OptConfig(total_steps=8),
                         arch=arch, shape=shape)
            state = tr.init_state()
            losses, gnorms = [], []
            for i in range(4):
                b = synthetic_batch(arch, shape, jax.random.PRNGKey(100 + i))
                state, m = tr.step_fn(state, b)
                losses.append(float(m["loss"]))
                gnorms.append(float(m["grad_norm"]))
            return plan, tr, state, losses, gnorms

        plan_on, tr_on, st_on, l_on, g_on = run("on")
        assert plan_on.comm.compress_grads and plan_on.comm.compress_lowered
        assert plan_on.estimates["grad_compress_lowered"] == 2.0  # dp

        # EF residuals live per DP slice: leading (dp,) axis, bf16
        for leaf in jax.tree.leaves(st_on["opt"]["ef"]):
            assert leaf.shape[0] == 2 and leaf.dtype == jax.numpy.bfloat16

        plan_off, tr_off, st_off, l_off, g_off = run("off")
        assert not plan_off.comm.compress_grads
        assert "grad_compress_lowered" not in plan_off.estimates

        # step 0's forward sees identical weights -> identical loss;
        # later steps track within the telescoping bound (measured
        # ~5e-5 on host CPU; 1e-3 pins the order of magnitude without
        # platform brittleness)
        assert l_on[0] == l_off[0], (l_on[0], l_off[0])
        for t, (a, b) in enumerate(zip(l_on, l_off)):
            assert abs(a - b) < 1e-3, (t, a, b)
        # grad norms: quantization perturbs but does not distort scale
        for t, (a, b) in enumerate(zip(g_on, g_off)):
            assert abs(a - b) / max(abs(b), 1e-9) < 0.05, (t, a, b)

        # ---- the wire proof on compiled HLO -------------------------
        # Replica groups, not element counts: on the reduced arch every
        # collective tops out at 16384 elements, and the megatron
        # model-axis activation reduces are shipped identically by both
        # steps — only collectives whose groups span the DATA axis
        # ({{0,4},{1,5},...} literal / [4,2]<=[2,4] iota on this (2,4)
        # mesh) are the gradient wire. "Gradient-sized" = >= 4096
        # elements; the surviving small cross-data floats are shared
        # quantizer scales and loss/grad-norm scalars.
        b = synthetic_batch(arch, shape, jax.random.PRNGKey(100))

        def xdata_counts(tr, state):
            txt = tr.step_fn.lower(state, b).compile().as_text()
            c = Counter()
            for line in txt.splitlines():
                m = re.search(
                    r"= (\\w+)\\[([\\d,]*)\\]\\S* (all-reduce|"
                    r"reduce-scatter)\\(", line)
                if m is None:
                    continue
                n = int(np.prod([int(t) for t in m.group(2).split(",")
                                 if t] or [1]))
                if ("replica_groups={{0,4}" in line
                        or "replica_groups=[4,2]<=[2,4]" in line):
                    c[m.group(1), n >= 4096] += 1
            return c

        on = xdata_counts(tr_on, st_on)
        off = xdata_counts(tr_off, st_off)
        # the baseline ships gradients as big cross-data float reduces
        # (proves the classifier actually sees the wire) ...
        assert off["f32", True] >= 1, off
        assert off["s16", True] == 0, off
        # ... and the compressed step ships ZERO — its only big
        # cross-data collectives are the int16 code sums
        assert on["f32", True] == 0 and on["bf16", True] == 0, on
        assert on["s16", True] >= 1, "no int16 code-sum all-reduce found"
        print("OK")
    """)


def test_compress_off_is_bit_deterministic():
    """Regression pin: the uncompressed step is bit-deterministic —
    two independent runs from the same seed produce identical losses
    (so any future trajectory drift is attributable to the wire path,
    not ambient nondeterminism)."""
    run_subprocess("""
        import jax
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import synthetic_batch
        from repro.optim.adamw import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig

        arch = get_arch("qwen3-8b").reduced()
        shape = ShapeConfig("wire_det", "train", 64, 8)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(2, 4), cache=False,
                          grad_compression="off")

        def run():
            tr = Trainer(plan, mesh, TrainerConfig(n_steps=1, ckpt_every=0),
                         opt_cfg=OptConfig(total_steps=8),
                         arch=arch, shape=shape)
            state = tr.init_state()
            out = []
            for i in range(3):
                b = synthetic_batch(arch, shape, jax.random.PRNGKey(7 + i))
                state, m = tr.step_fn(state, b)
                out.append(float(m["loss"]))
            return out

        a, b = run(), run()
        assert a == b, (a, b)
        print("OK")
    """)


# ---------------------------------------------------------------------
# unit-level telescoping identities (no mesh needed)
# ---------------------------------------------------------------------

def test_slice_sum_telescoping_identity_exact():
    """mean + mean_i(err_i) == mean_i(x_i) to f32 rounding, per element."""
    from repro.dist.collectives import compressed_slice_sum
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 3, 200)), jnp.float32)
    mean, err = compressed_slice_sum(x)
    lhs = np.asarray(mean + jnp.mean(err, axis=0))
    rhs = np.asarray(jnp.mean(x, axis=0))
    assert np.abs(lhs - rhs).max() < 1e-6


def test_ef_residual_bounded_on_constant_gradients():
    """Constant per-slice gradients: the cumulative delivered mean
    converges to the true mean at rate bound/T (the residual never
    drains below the quantization floor, but never grows either)."""
    from repro.dist.collectives import compressed_slice_sum
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((2, 257)) * 0.01 + 1.3, jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros(g.shape[1:], jnp.float32)
    T = 16
    step = float(jnp.abs(g).max()) / 127.0       # quantization step bound
    for _ in range(T):
        mean, err = compressed_slice_sum(g + err)
        total = total + mean
    true = np.asarray(jnp.mean(g, axis=0))
    # telescoping: |total/T - true| == |mean residual| / T <= step/2/T
    # (2% slack: the shared scale quantizes acc = g + err, whose amax
    # can exceed g's by up to half a step)
    gap = np.abs(np.asarray(total) / T - true).max()
    assert gap <= step / 2 / T * 1.02 + 1e-7, (gap, step / 2 / T)
    # and the residual itself stays at the quantization floor
    assert float(jnp.abs(err).max()) <= step / 2 * 1.02
