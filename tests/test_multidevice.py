"""Multi-device SPMD tests (subprocess with 8 host devices).

The main test process must keep the single real CPU device (smoke tests),
so anything needing a real mesh runs in a subprocess with
``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_subprocess(code: str, timeout: int = 300) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    # force the host platform: the device-count flag only applies to it,
    # and autodetection in the child probes for a Cloud TPU (30 slow
    # metadata retries) on machines with libtpu installed but no TPU
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_flash_decode_sharded_matches_oracle():
    run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.dist.flash_decode import flash_decode
        from repro.kernels import ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, S, H, K, D = 4, 64, 8, 4, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kn = jax.random.normal(ks[1], (B, 1, K, D))
        vn = jax.random.normal(ks[2], (B, 1, K, D))
        kc = jax.random.normal(ks[3], (B, S, K, D))
        vc = jax.random.normal(ks[4], (B, S, K, D))
        for pos, win in ((10, 0), (40, 16), (63, 0)):
            ctx, kc2, vc2 = jax.jit(
                lambda *a: flash_decode(*a, mesh=mesh))(
                    q, kn, vn, kc, vc, pos, win)
            kr = kc.at[:, pos].set(kn[:, 0])
            vr = vc.at[:, pos].set(vn[:, 0])
            r = ref.decode_attention_ref(q[:, 0], kr, vr,
                                         cache_len=jnp.int32(pos + 1),
                                         window=win)
            err = float(jnp.abs(ctx[:, 0] - r).max())
            assert err < 1e-5, (pos, win, err)
            assert bool(jnp.allclose(kc2, kr)), "append corrupted cache"
        # per-slot (B,) positions: mixed batch fill, appends cross shard
        # boundaries (local seq slice is 16 wide) and masks stay exact
        for pos_list, win in (([10, 40, 63, 0], 0), ([5, 17, 33, 60], 16)):
            pos = jnp.asarray(pos_list, jnp.int32)
            ctx, kc2, vc2 = jax.jit(
                lambda *a: flash_decode(*a, mesh=mesh))(
                    q, kn, vn, kc, vc, pos, win)
            kr = ref.decode_append_ref(kc, kn, pos)
            vr = ref.decode_append_ref(vc, vn, pos)
            r = ref.decode_attention_ref(q[:, 0], kr, vr,
                                         cache_len=pos + 1, window=win)
            err = float(jnp.abs(ctx[:, 0] - r).max())
            assert err < 1e-5, (pos_list, win, err)
            assert bool(jnp.allclose(kc2, kr)), "per-slot append corrupted"
        print("OK")
    """)


def test_serve_from_plan_shard_map_flash_end_to_end():
    """ServeEngine.from_plan(mesh=...) drives the plan's seq-sharded
    shard_map flash-decode path on a real 8-wide model axis, and a mixed
    continuous batch is token-identical to sequential single-request
    serving through the same path (cross-impl token equality is NOT
    asserted: flash's online-softmax combine and XLA's dense softmax
    differ in rounding, which can flip a near-tie greedy argmax)."""
    run_subprocess("""
        import dataclasses, jax, numpy as np
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import lm
        from repro.serve.engine import ServeEngine

        # GQA-on-wide-TP: kv=1 not shardable by model=8 -> seq spill
        arch = dataclasses.replace(get_arch("qwen3-8b").reduced(),
                                   n_kv_heads=1)
        shape = ShapeConfig("serve_md", "decode", 32, 2)
        # this test pins the DENSE seq-sharded path; the paged pool-
        # sharded run is test_serve_from_plan_paged_pool_sharded
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(1, 8), cache=False,
                          kv_residency="dense")
        assert plan.estimates.get("decode_impl") == "shard_map_flash"
        assert plan.estimates.get("kv_residency") == "dense"
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        params = lm.init_params(arch, jax.random.PRNGKey(0),
                                *plan.padded_sizes())
        eng = ServeEngine.from_plan(plan, params, arch=arch, mesh=mesh)
        assert eng.decode_path == "shard_map_flash", eng.decode_path
        # KV cache really lands seq-sharded on the model axis
        kshard = eng.cache["k"].sharding.spec
        assert kshard[2] == "model", kshard
        prompts = [np.arange(5, dtype=np.int32) % arch.vocab_size,
                   (np.arange(11, dtype=np.int32) * 3) % arch.vocab_size,
                   (np.arange(8, dtype=np.int32) * 7) % arch.vocab_size]
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        done = eng.run_until_idle(max_ticks=64)
        assert len(done) == 3 and all(len(r.out_tokens) == 5 for r in done)
        # sequential single-request oracles through the SAME sharded path
        a = {r.prompt.tobytes(): r.out_tokens for r in done}
        for p in prompts:
            eng2 = ServeEngine.from_plan(plan, params, arch=arch,
                                         mesh=mesh, max_batch=1)
            assert eng2.decode_path == "shard_map_flash"
            eng2.submit(p, max_new_tokens=5)
            done2 = eng2.run_until_idle(max_ticks=32)
            assert a[p.tobytes()] == done2[0].out_tokens, (
                p, a[p.tobytes()], done2[0].out_tokens)
        print("OK")
    """, timeout=600)


def test_flash_decode_paged_pool_sharded_matches_oracle():
    """The 1-D paged combine over a pool sharded on the model axis:
    owning-shard appends + per-shard partial softmax over owned blocks
    == the gather oracle, for staggered tables with unassigned tails.
    B=3 on data=2 cannot partition the batch, so the pool replicates
    over the data axis and every data shard must append the FULL batch
    or the replicas diverge — regression for the batch-sharded-append
    bug (the partitioned-batch run is the 2-D test below)."""
    run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.dist.flash_decode import flash_decode_paged, \\
            pool_sharding_kind
        from repro.kernels import ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, H, K, D, bl, N = 3, 8, 4, 16, 8, 16       # 4 blocks per shard
        assert pool_sharding_kind(mesh, N, B) == "1d"
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kn = jax.random.normal(ks[1], (B, 1, K, D))
        vn = jax.random.normal(ks[2], (B, 1, K, D))
        kp = jax.random.normal(ks[3], (N, bl, K, D))
        vp = jax.random.normal(ks[4], (N, bl, K, D))
        tbl = jnp.asarray([[0, 9, 3, -1], [14, 2, -1, -1],
                           [5, 7, 11, 13]], jnp.int32)
        for pos_list, win in (([16, 8, 31], 0), ([20, 14, 27], 8)):
            pos = jnp.asarray(pos_list, jnp.int32)
            ctx, kp2, vp2 = jax.jit(
                lambda *a: flash_decode_paged(*a, mesh=mesh))(
                    q, kn, vn, kp, vp, tbl, pos, win)
            kr = ref.paged_append_ref(kp, kn, pos, tbl)
            vr = ref.paged_append_ref(vp, vn, pos, tbl)
            r = ref.paged_decode_attention_ref(
                q[:, 0], kr, vr, tbl, cache_len=pos + 1, window=win)
            err = float(jnp.abs(ctx[:, 0] - r).max())
            assert err < 1e-5, (pos_list, win, err)
            assert bool(jnp.allclose(kp2, kr)), "paged append corrupted"
            assert bool(jnp.allclose(vp2, vr))
        print("OK")
    """)


def test_flash_decode_paged_2d_matches_oracle():
    """The 2-D paged combine on a 2x4 data×model mesh: the block dim is
    sharded data-major over both axes, batch slots are partitioned (not
    replicated) across data, appends land on the one (data, model)
    shard owning the block, and the model-axis-only 3-term combine ==
    the gather oracle — for staggered tables (each slot's blocks inside
    its data shard's sub-pool, the allocator contract) with unassigned
    tails and windows."""
    run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.dist.flash_decode import flash_decode_paged, \\
            pool_sharding_kind
        from repro.kernels import ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, H, K, D, bl, N = 4, 8, 4, 16, 8, 16   # 2 blocks/(data,model) shard
        assert pool_sharding_kind(mesh, N, B) == "2d"
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kn = jax.random.normal(ks[1], (B, 1, K, D))
        vn = jax.random.normal(ks[2], (B, 1, K, D))
        kp = jax.random.normal(ks[3], (N, bl, K, D))
        vp = jax.random.normal(ks[4], (N, bl, K, D))
        # slots 0-1 live on data shard 0 (sub-pool ids [0, 8)), slots
        # 2-3 on data shard 1 (ids [8, 16)); non-contiguous, unordered
        tbl = jnp.asarray([[0, 5, 3, -1], [7, 2, -1, -1],
                           [8, 15, 11, 13], [9, 14, -1, -1]], jnp.int32)
        for pos_list, win in (([16, 8, 31, 10], 0), ([20, 14, 27, 4], 8),
                              ([0, 15, 24, 9], 6)):
            pos = jnp.asarray(pos_list, jnp.int32)
            ctx, kp2, vp2 = jax.jit(
                lambda *a: flash_decode_paged(*a, mesh=mesh))(
                    q, kn, vn, kp, vp, tbl, pos, win)
            kr = ref.paged_append_ref(kp, kn, pos, tbl)
            vr = ref.paged_append_ref(vp, vn, pos, tbl)
            r = ref.paged_decode_attention_ref(
                q[:, 0], kr, vr, tbl, cache_len=pos + 1, window=win)
            err = float(jnp.abs(ctx[:, 0] - r).max())
            assert err < 1e-5, (pos_list, win, err)
            assert bool(jnp.allclose(kp2, kr)), "2-D append corrupted"
            assert bool(jnp.allclose(vp2, vr))
        # the pool really lands sharded over BOTH axes under jit
        from jax.sharding import NamedSharding, PartitionSpec as P
        kp_s = jax.device_put(kp, NamedSharding(mesh,
                                                P(("data", "model"))))
        ctx2, _, _ = jax.jit(lambda *a: flash_decode_paged(*a, mesh=mesh))(
            q, kn, vn, kp_s, vp, tbl, jnp.asarray([16, 8, 31, 10]), 0)
        print("OK")
    """)


def test_flash_decode_paged_2d_aliased_tables():
    """Cross-request block aliasing through the 2-D combine: a block id
    appearing in TWO slots' tables (a refcounted prefix hit, each inside
    its data shard's sub-pool) reads exactly like a private copy of the
    same rows, and the fused append only ever touches each slot's
    private tail block — the CoW contract the engine enforces means no
    slot appends into a shared id, so aliasing must be invisible to the
    kernel."""
    run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.dist.flash_decode import flash_decode_paged, \\
            pool_sharding_kind
        from repro.kernels import ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, H, K, D, bl, N = 4, 8, 4, 16, 8, 16
        assert pool_sharding_kind(mesh, N, B) == "2d"
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kn = jax.random.normal(ks[1], (B, 1, K, D))
        vn = jax.random.normal(ks[2], (B, 1, K, D))
        kp = jax.random.normal(ks[3], (N, bl, K, D))
        vp = jax.random.normal(ks[4], (N, bl, K, D))
        # slots 0-1 (data shard 0, ids [0,8)) share prefix blocks
        # {0, 5}; slots 2-3 (shard 1, ids [8,16)) share {8, 15}; every
        # slot appends into its own private tail block
        ta = jnp.asarray([[0, 5, 2, -1], [0, 5, 6, -1],
                          [8, 15, 11, 13], [8, 15, 12, -1]], jnp.int32)
        # private twin: duplicate the shared rows into same-shard ids
        # (1 sits on block 0's (data, model) shard, 4 on 5's, ...) so
        # the combine partitions identically and only aliasing differs
        kpp = kp.at[1].set(kp[0]).at[4].set(kp[5]) \\
                .at[9].set(kp[8]).at[14].set(kp[15])
        vpp = vp.at[1].set(vp[0]).at[4].set(vp[5]) \\
                .at[9].set(vp[8]).at[14].set(vp[15])
        tp = jnp.asarray([[0, 5, 2, -1], [1, 4, 6, -1],
                          [8, 15, 11, 13], [9, 14, 12, -1]], jnp.int32)
        pos = jnp.asarray([17, 20, 27, 16], jnp.int32)
        for win in (0, 8):
            run = jax.jit(lambda kk, vv, tt: flash_decode_paged(
                q, kn, vn, kk, vv, tt, pos, win, mesh=mesh))
            ctx_a, kp2, vp2 = run(kp, vp, ta)
            ctx_p, kpp2, vpp2 = run(kpp, vpp, tp)
            err = float(jnp.abs(ctx_a - ctx_p).max())
            assert err < 1e-5, (win, err)
            # aliased run matches the gather oracle too
            kr = ref.paged_append_ref(kp, kn, pos, ta)
            vr = ref.paged_append_ref(vp, vn, pos, ta)
            r = ref.paged_decode_attention_ref(
                q[:, 0], kr, vr, ta, cache_len=pos + 1, window=win)
            assert float(jnp.abs(ctx_a[:, 0] - r).max()) < 1e-5
            # appends landed only in private tail blocks; the shared
            # prefix blocks came through bit-identical
            assert bool(jnp.allclose(kp2, kr)), "2-D append corrupted"
            for b in (0, 5, 8, 15):
                assert bool((kp2[b] == kp[b]).all()), (win, b)
                assert bool((vp2[b] == vp[b]).all()), (win, b)
        print("OK")
    """)


def test_serve_paged_2d_shared_prefix_token_identity():
    """Prefix sharing under 2-D pool sharding respects the combine
    contract: one trie per data-shard sub-pool, admission prefers the
    sub-pool holding the longest match, aliased blocks stay inside the
    owning sub-pool, and a staggered shared-system-prompt batch through
    ``shard_map_flash_paged_2d`` is token-identical to the reuse-off
    run — with the pool whole and the tries empty after drain.

    Prompt tails are several tokens long so matched admissions take the
    tail-prefill path (exact: same kernel class as full prefill over
    identical pool rows).  The zero-prefill decode-ride is deliberately
    NOT exercised here: a ride computes its first token through the
    sharded decode combine, whose reduction order differs from the
    prefill kernel's on a >1-shard mesh — the same near-tie rounding
    caveat ``test_serve_paged_2d_token_identity_vs_dense_sequential``
    documents.  Ride token-identity is pinned bitwise on the
    single-shard paths in test_serve_mixed."""
    run_subprocess("""
        import dataclasses, jax, numpy as np
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import lm
        from repro.serve.engine import ServeEngine

        arch = dataclasses.replace(get_arch("qwen3-8b").reduced(),
                                   n_kv_heads=1)
        shape = ShapeConfig("serve_2d_px", "decode", 64, 4)
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(2, 4), cache=False)
        assert plan.estimates["kv_residency"] == "paged"
        assert plan.estimates["kv_prefix_reuse"] == "on"
        assert plan.estimates["kv_pool_data_degree"] == 2
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = lm.init_params(arch, jax.random.PRNGKey(0),
                                *plan.padded_sizes())

        def run(reuse):
            eng = ServeEngine.from_plan(plan, params, arch=arch,
                                        mesh=mesh, kv_prefix_reuse=reuse)
            assert eng.decode_path == "shard_map_flash_paged_2d"
            assert eng.pool_groups == 2
            bl = eng.block_len
            rng = np.random.default_rng(0)
            sysp = rng.integers(0, arch.vocab_size, bl).astype(np.int32)
            # 5-token tails: matched blocks cover 16 of 21 feed tokens,
            # so admission aliases the prefix and tail-prefills the rest
            prompts = [np.concatenate(
                           [sysp, rng.integers(0, arch.vocab_size, 5)]
                       ).astype(np.int32) for _ in range(4)]
            eng.submit(prompts[0], max_new_tokens=4)
            eng.step()
            eng.step()
            for p in prompts[1:]:
                eng.submit(p, max_new_tokens=4)
            done = eng.run_until_idle(max_ticks=64)
            assert len(done) == 4
            if reuse == "on":
                ps = eng.pressure_stats()
                assert ps["prefix_hits"] >= 1, ps
                # the per-sub-pool tries: one per data shard
                assert eng._prefix is not None \\
                    and eng._prefix.groups == 2
                # the plan sizes a host tier for this geometry, so the
                # engine retains finished trie-indexed blocks in its
                # cold cache after drain; release them to check the
                # pool identity
                assert eng.block_stats()["cached"] >= 1
                assert eng.drop_block_cache() >= 1
                st = eng.block_stats()
                assert st["prefix_trie"] == 0 and st["shared"] == 0
                assert st["cached"] == 0
                assert st["host_free"] == st["host_total"]
            stats = eng.block_stats()
            assert stats["free"] == stats["total"], stats
            return {r.rid: r.out_tokens for r in done}

        assert run("on") == run("off")
        print("OK")
    """, timeout=900)


def test_serve_from_plan_paged_pool_sharded():
    """A paged decode plan served end-to-end on an 8-wide model axis:
    the pool dim really lands sharded, the engine reports the pool-
    sharded path, blocks recycle across a staggered mix, and tokens
    match sequential single-request serving through the same path."""
    run_subprocess("""
        import dataclasses, jax, numpy as np
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import lm
        from repro.serve.engine import ServeEngine

        arch = dataclasses.replace(get_arch("qwen3-8b").reduced(),
                                   n_kv_heads=1)
        shape = ShapeConfig("serve_paged_md", "decode", 64, 4)
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(1, 8), cache=False)
        assert plan.estimates.get("decode_impl") == "shard_map_flash"
        assert plan.estimates.get("kv_residency") == "paged"
        assert plan.estimates["kv_n_blocks"] % 8 == 0   # pool shardable
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        params = lm.init_params(arch, jax.random.PRNGKey(0),
                                *plan.padded_sizes())
        eng = ServeEngine.from_plan(plan, params, arch=arch, mesh=mesh)
        assert eng.kv_residency == "paged"
        assert eng.decode_path == "shard_map_flash", eng.decode_path
        # the block pool really lands sharded on its pool dim
        kshard = eng.cache["k"].sharding.spec
        assert kshard[1] == "model", kshard
        prompts = [np.arange(5, dtype=np.int32) % arch.vocab_size,
                   (np.arange(11, dtype=np.int32) * 3) % arch.vocab_size,
                   (np.arange(8, dtype=np.int32) * 7) % arch.vocab_size,
                   (np.arange(11, dtype=np.int32) * 5) % arch.vocab_size,
                   (np.arange(5, dtype=np.int32) * 2) % arch.vocab_size]
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        done = eng.run_until_idle(max_ticks=64)
        assert len(done) == 5 and all(len(r.out_tokens) == 5 for r in done)
        stats = eng.block_stats()
        assert stats["free"] == stats["total"], stats
        a = {r.prompt.tobytes(): r.out_tokens for r in done}
        # sequential single-request runs through the SAME pool-sharded
        # path (same pool size -> same dispatch; a max_batch=1 engine
        # would clamp the pool below the 8-way divisibility)
        for p in prompts[:3]:
            eng2 = ServeEngine.from_plan(plan, params, arch=arch,
                                         mesh=mesh)
            assert eng2.decode_path == "shard_map_flash"
            eng2.submit(p, max_new_tokens=5)
            done2 = eng2.run_until_idle(max_ticks=32)
            assert a[p.tobytes()] == done2[0].out_tokens, (
                p, a[p.tobytes()], done2[0].out_tokens)
        print("OK")
    """, timeout=600)


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-2.7b", "hymba-1.5b"])
def test_serve_paged_2d_token_identity_vs_dense_sequential(name):
    """The tentpole acceptance: on a data-degree>1 (2x4) mesh,
    specialize() now records kv_residency=paged with 2-D geometry
    (batch-partitioned sub-pools — the pre-2-D pass forced dense here),
    ServeEngine.from_plan serves it end-to-end through
    ``decode_path == "shard_map_flash_paged_2d"``, and a staggered
    continuous batch is token-identical to the dense sequential oracle
    through the same mesh — across attention / SSM / hybrid archs
    (SSM-only has nothing to page and pins the honest dense fallback).

    The staggered-vs-sequential comparison through the SAME 2-D path is
    exact (the batching/allocator contract).  The cross-residency
    comparison pins per-step fp32 logits within bf16 combine-rounding
    tolerance and tokens exactly — except a *provable* near-tie argmax
    flip (the divergent token must be the oracle's runner-up within a
    tiny logit gap): the paged and dense combines partition the softmax
    sum differently, the same documented rounding caveat as xla-vs-
    flash, and a real bug (wrong block, wrong mask) shows up as an
    O(1) logit error, not a near-tie swap.
    """
    run_subprocess(f"""
        import dataclasses, jax, numpy as np
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import lm
        from repro.serve.engine import ServeEngine

        name = {name!r}

        class Probe(ServeEngine):
            # capture each sampled step's fp32 logits (single-request
            # engines only: one _sample call per emitted token)
            def __init__(self, *a, **kw):
                super().__init__(*a, **kw)
                self.steps = []
            def _sample(self, logits, temperature, key):
                self.steps.append(np.asarray(
                    logits[:self.arch.vocab_size], np.float32))
                return super()._sample(logits, temperature, key)

        arch = get_arch(name).reduced()
        if arch.has_attention:
            # GQA-on-wide-TP: kv=1 not shardable by model=4 -> seq spill
            # -> the plan picks shard_map_flash
            arch = dataclasses.replace(arch, n_kv_heads=1)
        shape = ShapeConfig("serve_2d", "decode", 64, 4)
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(2, 4), cache=False)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = lm.init_params(arch, jax.random.PRNGKey(0),
                                *plan.padded_sizes())
        prompts = [np.arange(5, dtype=np.int32) % arch.vocab_size,
                   (np.arange(11, dtype=np.int32) * 3) % arch.vocab_size,
                   (np.arange(8, dtype=np.int32) * 7) % arch.vocab_size,
                   (np.arange(11, dtype=np.int32) * 5) % arch.vocab_size,
                   (np.arange(5, dtype=np.int32) * 2) % arch.vocab_size]

        if arch.has_attention:
            assert plan.estimates["decode_impl"] == "shard_map_flash"
            assert plan.estimates["kv_residency"] == "paged", \\
                plan.estimates.get("kv_residency")
            assert plan.estimates["kv_pool_data_degree"] == 2
            assert plan.estimates["kv_n_blocks"] % (2 * 4) == 0
            assert plan.estimates["kv_paged_bytes"] \\
                < plan.estimates["kv_dense_bytes"]
            eng = ServeEngine.from_plan(plan, params, arch=arch, mesh=mesh)
            assert eng.kv_residency == "paged"
            assert eng.pool_groups == 2, eng.pool_groups
            assert eng.decode_path == "shard_map_flash_paged_2d", \\
                eng.decode_path
            # the pool really lands sharded over BOTH mesh axes
            kshard = eng.cache["k"].sharding.spec
            assert kshard[1] in (("data", "model"), ["data", "model"]), \\
                kshard
        else:
            assert "kv_residency" not in plan.estimates  # nothing to page
            eng = ServeEngine.from_plan(plan, params, arch=arch, mesh=mesh)
            assert eng.kv_residency == "dense"
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        done = eng.run_until_idle(max_ticks=64)
        assert len(done) == 5 and all(len(r.out_tokens) == 4 for r in done)
        stats = eng.block_stats()
        assert stats["free"] == stats["total"], stats
        got = {{r.prompt.tobytes(): r.out_tokens for r in done}}

        # dense sequential oracle over the SAME mesh (seq-sharded
        # flash-decode for attention archs)
        dplan = specialize(arch, shape, mesh_axes=("data", "model"),
                           mesh_shape=(2, 4), cache=False,
                           kv_residency="dense")
        for p in prompts:
            ep = Probe.from_plan(plan, params, arch=arch, mesh=mesh)
            ep.submit(p, max_new_tokens=4)
            seq = ep.run_until_idle(max_ticks=32)[0].out_tokens
            # staggered continuous batch == sequential single-request
            # through the SAME path: exact
            assert got[p.tobytes()] == seq, (p, got[p.tobytes()], seq)

            ed = Probe.from_plan(dplan, params, arch=arch, mesh=mesh,
                                 max_batch=1)
            assert ed.kv_residency == "dense"
            ed.submit(p, max_new_tokens=4)
            dseq = ed.run_until_idle(max_ticks=32)[0].out_tokens
            # cross-residency: token-identical, excusing only a provable
            # near-tie argmax flip (runner-up within a tiny gap, logits
            # within bf16 combine-rounding tolerance)
            for i, (tp, td) in enumerate(zip(seq, dseq)):
                if tp == td:
                    continue
                lp, ld = ep.steps[i], ed.steps[i]
                diff = float(np.abs(lp - ld).max())
                gap = float(ld[td] - ld[tp])
                assert diff < 0.3 and 0.0 <= gap < 0.15, (
                    "paged-2d diverged from the dense oracle outside "
                    "near-tie tolerance", p, i, tp, td, diff, gap)
                break          # prefixes differ from here on
        print("OK", name)
    """, timeout=900)


def test_moe_shard_map_matches_gshard_on_mesh():
    run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.models.moe import (MoEParams, moe_gshard_einsum,
                                      moe_shard_map)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        B, S, d, E, ff, k = 4, 32, 16, 8, 32, 2
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        p = MoEParams(
            router=jax.random.normal(keys[0], (d, E)) * 0.5,
            wi=jax.random.normal(keys[1], (E, d, 2 * ff)) * 0.1,
            wo=jax.random.normal(keys[2], (E, ff, d)) * 0.1)
        from jax.sharding import NamedSharding, PartitionSpec as P
        x = jax.random.normal(jax.random.PRNGKey(9), (B, S, d))
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y1, a1 = jax.jit(lambda x: moe_gshard_einsum(
            x, p, top_k=k, capacity_factor=4.0))(xs)
        y2, a2 = jax.jit(lambda x: moe_shard_map(
            x, p, top_k=k, capacity_factor=4.0, mesh=mesh))(xs)
        # capacity groups differ (global vs per-shard) so a few border
        # tokens may drop differently; demand bulk agreement
        diff = jnp.abs(y1 - y2)
        frac_close = float(jnp.mean((diff < 1e-3).astype(jnp.float32)))
        assert frac_close > 0.9, frac_close
        print("OK")
    """)


def test_compressed_psum_multi_shard():
    run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 7.0
        def f(xs):
            y, err = compressed_psum(xs[0], "data")
            return y[None], err[None]
        y, err = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=P("data", None),
            out_specs=(P("data", None), P("data", None))))(x)
        want = jnp.mean(x, axis=0)
        got = y[0]
        rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
        assert rel < 0.02, rel
        print("OK")
    """)


def test_combine_topology_matrix_ring_bidir_vs_flat():
    """The combine-topology oracle matrix: ring and bidirectional-ring
    softmax combines pinned against the flat-psum combine and the gather
    oracle across model degrees {2, 4, 8} for the dense seq-sharded
    kernel, the 1-D pool-sharded paged kernel, and the 2-D paged
    placement.

    Contracts (measured, not aspirational): ring == bidir BITWISE (both
    fold the same source-indexed gathered buffer in the same sequential
    order — the two ppermute arms only change how the buffer fills);
    ring vs flat agree to the last ulp (flat's psum is fused with the
    exp/mul rescale by XLA and re-rounds differently — 1-ulp class, not
    a reduction-order class, so a loose 1e-6); and every topology
    matches the unsharded gather oracle within the 1e-5 bound every
    other decode test in this file pins."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.flash_decode import (combine_topology, flash_decode,
                                             flash_decode_paged)
        from repro.kernels import ref

        TOPOS = ("flat", "ring", "bidir")

        def check(outs, oracle, tag):
            assert np.array_equal(outs["ring"], outs["bidir"]), tag
            d = np.abs(outs["ring"] - outs["flat"]).max()
            assert d < 1e-6, (tag, d)
            for t in TOPOS:
                e = np.abs(outs[t] - np.asarray(oracle)).max()
                assert e < 1e-5, (tag, t, e)

        # predicate: 8 host devices cap the natural degree at 8, all
        # flat; overrides force the wire pattern; a degenerate model
        # axis has no cross-shard combine so even an override is flat
        for dsz, msz in ((4, 2), (2, 4), (1, 8)):
            m = jax.make_mesh((dsz, msz), ("data", "model"))
            assert combine_topology(m) == "flat"
            assert combine_topology(m, override="ring") == "ring"
            assert combine_topology(m, override="bidir") == "bidir"
        m1 = jax.make_mesh((8, 1), ("data", "model"))
        assert combine_topology(m1) == "flat"
        assert combine_topology(m1, override="bidir") == "flat"
        try:
            combine_topology(jax.make_mesh((1, 8), ("data", "model")),
                             override="hypercube")
            raise SystemExit("expected ValueError on unknown topology")
        except ValueError:
            pass

        # dense seq-sharded kernel across model degrees 2/4/8
        B, S, H, K, D = 4, 64, 8, 4, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kn = jax.random.normal(ks[1], (B, 1, K, D))
        vn = jax.random.normal(ks[2], (B, 1, K, D))
        kc = jax.random.normal(ks[3], (B, S, K, D))
        vc = jax.random.normal(ks[4], (B, S, K, D))
        pos = jnp.asarray([10, 40, 63, 5], jnp.int32)
        kr = ref.decode_append_ref(kc, kn, pos)
        vr = ref.decode_append_ref(vc, vn, pos)
        r = ref.decode_attention_ref(q[:, 0], kr, vr,
                                     cache_len=pos + 1, window=0)
        for dsz, msz in ((4, 2), (2, 4), (1, 8)):
            mesh = jax.make_mesh((dsz, msz), ("data", "model"))
            outs = {}
            for t in TOPOS:
                ctx, _, _ = jax.jit(lambda *a, t=t: flash_decode(
                    *a, mesh=mesh, combine=t))(q, kn, vn, kc, vc, pos, 0)
                outs[t] = np.asarray(ctx[:, 0])
            check(outs, r, ("dense", msz))

        # 1-D pool-sharded paged kernel across model degrees 2/4/8
        # (B=3 keeps the batch unpartitionable over data>1, pinning the
        # replicated-pool 1-D combine)
        Bp, bl, N = 3, 8, 16
        kp = jax.random.normal(jax.random.split(ks[3])[0], (N, bl, K, D))
        vp = jax.random.normal(jax.random.split(ks[4])[0], (N, bl, K, D))
        tbl = jnp.asarray([[0, 9, 3, -1], [14, 2, -1, -1],
                           [5, 7, 11, 13]], jnp.int32)
        ppos = jnp.asarray([16, 8, 31], jnp.int32)
        kpr = ref.paged_append_ref(kp, kn[:Bp], ppos, tbl)
        vpr = ref.paged_append_ref(vp, vn[:Bp], ppos, tbl)
        pr = ref.paged_decode_attention_ref(
            q[:Bp, 0], kpr, vpr, tbl, cache_len=ppos + 1, window=0)
        for dsz, msz in ((4, 2), (2, 4), (1, 8)):
            mesh = jax.make_mesh((dsz, msz), ("data", "model"))
            outs = {}
            for t in TOPOS:
                ctx, _, _ = jax.jit(lambda *a, t=t: flash_decode_paged(
                    *a, mesh=mesh, combine=t))(
                        q[:Bp], kn[:Bp], vn[:Bp], kp, vp, tbl, ppos, 0)
                outs[t] = np.asarray(ctx[:, 0])
            check(outs, pr, ("paged-1d", msz))

        # 2-D placement (batch-partitioned sub-pools, model degree 4):
        # the combine override must plumb through the 2-D combine too
        from repro.dist.flash_decode import pool_sharding_kind
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        t2 = jnp.asarray([[0, 5, 3, -1], [7, 2, -1, -1],
                          [8, 15, 11, 13], [9, 14, -1, -1]], jnp.int32)
        p2 = jnp.asarray([16, 8, 31, 10], jnp.int32)
        assert pool_sharding_kind(mesh2, N, B) == "2d"
        k2r = ref.paged_append_ref(kp, kn, p2, t2)
        v2r = ref.paged_append_ref(vp, vn, p2, t2)
        r2 = ref.paged_decode_attention_ref(
            q[:, 0], k2r, v2r, t2, cache_len=p2 + 1, window=0)
        outs = {}
        for t in TOPOS:
            ctx, _, _ = jax.jit(lambda *a, t=t: flash_decode_paged(
                *a, mesh=mesh2, combine=t))(q, kn, vn, kp, vp, t2, p2, 0)
            outs[t] = np.asarray(ctx[:, 0])
        check(outs, r2, ("paged-2d", 4))
        print("OK")
    """, timeout=600)


def test_serve_from_plan_ring_combine_end_to_end():
    """A plan-recorded ring combine served end-to-end: specialize() with
    the ``combine_topology="ring"`` override records the decision (8
    host devices cannot exceed the flat<=8 threshold naturally), the
    RunCfg carries it through ``from_plan`` without any engine-side
    kwarg, the engine reports it in telemetry, and a staggered
    continuous batch through the ring combine is token-identical to
    sequential single-request serving through the same path."""
    run_subprocess("""
        import dataclasses, jax, numpy as np
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.models import lm
        from repro.serve.engine import ServeEngine

        arch = dataclasses.replace(get_arch("qwen3-8b").reduced(),
                                   n_kv_heads=1)
        shape = ShapeConfig("serve_ring", "decode", 32, 2)
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(1, 8), cache=False,
                          kv_residency="dense", combine_topology="ring")
        assert plan.estimates.get("decode_impl") == "shard_map_flash"
        assert plan.estimates["combine_topology"] == "ring"
        assert plan.comm.combine_topology == "ring"
        # the decision log narrates the override, not a modeled choice
        recs = [(d, w) for _, s, d, w in plan.log
                if s == "combine_topology"]
        assert recs and recs[-1][0] == "ring" \\
            and "forced by options" in recs[-1][1], recs

        mesh = jax.make_mesh((1, 8), ("data", "model"))
        params = lm.init_params(arch, jax.random.PRNGKey(0),
                                *plan.padded_sizes())
        eng = ServeEngine.from_plan(plan, params, arch=arch, mesh=mesh)
        assert eng.decode_path == "shard_map_flash", eng.decode_path
        assert eng.combine_topology == "ring", eng.combine_topology
        assert eng.telemetry()["combine_topology"] == "ring"

        prompts = [np.arange(5, dtype=np.int32) % arch.vocab_size,
                   (np.arange(11, dtype=np.int32) * 3) % arch.vocab_size,
                   (np.arange(8, dtype=np.int32) * 7) % arch.vocab_size]
        eng.submit(prompts[0], max_new_tokens=5)
        eng.step()
        for p in prompts[1:]:
            eng.submit(p, max_new_tokens=5)
        done = eng.run_until_idle(max_ticks=64)
        assert len(done) == 3 and all(len(r.out_tokens) == 5 for r in done)
        a = {r.prompt.tobytes(): r.out_tokens for r in done}
        for p in prompts:
            eng2 = ServeEngine.from_plan(plan, params, arch=arch,
                                         mesh=mesh, max_batch=1)
            assert eng2.combine_topology == "ring"
            eng2.submit(p, max_new_tokens=5)
            done2 = eng2.run_until_idle(max_ticks=32)
            assert a[p.tobytes()] == done2[0].out_tokens, (
                p, a[p.tobytes()], done2[0].out_tokens)
        print("OK")
    """, timeout=600)


def test_train_step_fsdp_dp_multidevice():
    """The fsdp_dp lowered train step executes on a real (2,4) mesh."""
    run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import ShapeConfig, get_arch
        from repro.core.pipeline import specialize
        from repro.core.passes.lowering import lower_train_step
        from repro.models import synthetic_batch
        from repro.optim import OptConfig
        from repro.train.trainer import Trainer, TrainerConfig
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        arch = get_arch("qwen3-8b").reduced()
        shape = ShapeConfig("t", "train", 64, 8)
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(2, 4))
        tr = Trainer(plan, mesh, TrainerConfig(n_steps=1, ckpt_every=0),
                     opt_cfg=OptConfig(total_steps=4),
                     arch=arch, shape=shape)
        state = tr.init_state()
        batch = synthetic_batch(arch, shape, jax.random.PRNGKey(1))
        state, m = tr.step_fn(state, batch)
        assert bool(jnp.isfinite(m["loss"]))
        print("OK strategy=", plan.estimates.get("strategy"))
    """, timeout=420)
