import os
import sys
from pathlib import Path

# tests are run as `PYTHONPATH=src pytest tests/`; make that robust even
# when invoked from elsewhere
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# smoke tests must see the single real CPU device (the dry-run sets its
# own 512-device flag in its own subprocess)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
