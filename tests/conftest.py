import os
import sys
from pathlib import Path

# tests are run as `PYTHONPATH=src pytest tests/`; make that robust even
# when invoked from elsewhere
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# smoke tests must see the single real CPU device (the dry-run sets its
# own 512-device flag in its own subprocess)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# isolate the on-disk plan store: tests must never see (or pollute) the
# developer's ~/.cache/repro/plans; subprocess tests inherit the same
# per-run directory via the environment
if "REPRO_PLAN_DIR" not in os.environ:
    import tempfile
    os.environ["REPRO_PLAN_DIR"] = tempfile.mkdtemp(prefix="repro_plans_test_")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
