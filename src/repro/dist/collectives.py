"""Compressed collectives: int8 block quantization + error feedback.

The communication pass turns these on when a gradient reduction is the
step bottleneck (slow DCN "pod" axis, or a collective-bound step on the
ICI mesh).  The math contract, verified by the property tests:

* ``quantize_int8`` is block-wise symmetric: per 128-element block the
  reconstruction error is bounded by ``amax_block / 254`` (half a
  quantization step);
* ``ef_compress`` is *unbiased over time*: the residual carries exactly
  what quantization dropped, so ``sum(delivered) + residual ==
  sum(inputs)`` (telescoping) and the time-averaged delivered gradient
  converges to the true gradient;
* ``compressed_psum`` is a mean-reduction (gradient-averaging semantics)
  with int16 *codes* as the wire carrier — every shard quantizes against
  a shared (pmax'd) scale so the code sum dequantizes exactly to the sum
  of the dequantized values — returning the local residual for feedback;
* ``compressed_slice_sum`` is its GSPMD twin for the lowered train step:
  the same shared-scale code summation over a stacked leading axis
  instead of a shard_map collective, so XLA's partitioner emits the
  reduction as an integer all-reduce (no f32 gradient ever crosses the
  data axis — the honest wire cut the cost model priced).

Shared-scale code sums overflow int16 at ``127 * n > 32767``, so both
wire paths are gated to reduction degrees <= 256.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

#: quantization block: one f32 scale per 128 values (~3% volume overhead)
BLOCK = 128


def quantize_int8(x: jax.Array, block: int = BLOCK
                  ) -> Tuple[jax.Array, jax.Array, int]:
    """Block-wise symmetric int8 quantization of an arbitrary-shape array.

    Returns ``(q, scales, pad)``: int8 codes of shape
    ``(nblocks, block)``, one f32 scale per block, and the number of
    zero-padded tail elements (non-multiple shapes pad up).
    """
    flat = jnp.asarray(x).astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], pad


def dequantize_int8(q: jax.Array, scales: jax.Array, pad: int,
                    shape: Tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`quantize_int8` (f32 output of ``shape``)."""
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_compress(g: jax.Array, err: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compression of one gradient leaf.

    ``ghat = Q(g + err)``; the new residual ``(g + err) - ghat`` is what
    the quantizer dropped this step and is re-injected next step, making
    the compression unbiased over time.  ``err=None`` starts a fresh
    residual; otherwise the residual keeps its storage dtype (the plan
    stores it bf16 — half the optimizer-state cost of an f32 residual).
    """
    g32 = g.astype(jnp.float32)
    acc = g32 if err is None else g32 + err.astype(jnp.float32)
    q, scales, pad = quantize_int8(acc)
    ghat = dequantize_int8(q, scales, pad, g.shape)
    new_err = (acc - ghat).astype(jnp.float32 if err is None else err.dtype)
    return ghat.astype(g.dtype), new_err


def ef_state(params, replicas: int = 1) -> dict:
    """Zero-initialized error-feedback residuals, one per parameter leaf.

    bf16 storage: the residual is bounded by half a quantization step, so
    bf16's ~3 significant digits lose <0.5% of an already-small term.

    ``replicas > 1`` is the lowered-wire layout: one residual per
    data-parallel slice, stacked on a leading ``(replicas,)`` axis that
    the train step shards over the data axes (each slice's residual
    tracks what *its* codes dropped — see ``compressed_slice_sum``).
    """
    def zero(p):
        shape = ((replicas,) if replicas > 1 else ()) + tuple(jnp.shape(p))
        return jnp.zeros(shape, jnp.bfloat16)
    return jax.tree.map(zero, params)


def _last_dim_blocks(x32: jax.Array) -> Tuple[jax.Array, int]:
    """``(..., d)`` -> ``(..., nb, BLOCK)`` with zero tail padding.

    Blocks cut the *last* dim only (unlike :func:`quantize_int8`'s full
    flatten) so a stacked/sharded array keeps its leading dims intact —
    the partitioner never has to reshard to quantize.
    """
    d = x32.shape[-1]
    pad = (-d) % BLOCK
    if pad:
        x32 = jnp.pad(x32, [(0, 0)] * (x32.ndim - 1) + [(0, pad)])
    return x32.reshape(*x32.shape[:-1], -1, BLOCK), pad


def _unblock(blocks: jax.Array, d: int, pad: int) -> jax.Array:
    flat = blocks.reshape(*blocks.shape[:-2], -1)
    return flat[..., :d] if pad else flat


def compressed_psum(x: jax.Array, axis) -> Tuple[jax.Array, jax.Array]:
    """Mean all-reduce of int8-quantized values, for use under shard_map.

    The wire carrier is the int16 *code sum*: every shard quantizes
    against a shared scale (one pmax of the per-block amax), psums the
    codes, and dequantizes the sum — identical in value to averaging the
    dequantized shards, but the gradient-sized collective runs in int16.
    The local quantization error is returned so the caller can feed it
    back (:func:`ef_compress` semantics split across shards).
    Wire-volume: int16 codes + one f32 amax per 128-block on a hop-long
    chain vs 4 bytes/element f32.  Code sums need ``127 * n <= 32767``:
    callers gate the path to reduction degrees <= 256.
    """
    x32 = jnp.asarray(x, jnp.float32)
    d = x32.shape[-1]
    blocks, pad = _last_dim_blocks(x32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(blocks), axis=-1), axis)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127.0, 127.0)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    qsum = jax.lax.psum(q.astype(jnp.int16), axis)
    y = _unblock(qsum.astype(jnp.float32) * scale[..., None], d, pad) / n
    err = x32 - _unblock(q * scale[..., None], d, pad)
    return y.astype(x.dtype), err.astype(x.dtype)


def compressed_slice_sum(stacked: jax.Array
                         ) -> Tuple[jax.Array, jax.Array]:
    """Shared-scale code-sum mean over a stacked ``(r, ...)`` axis.

    The lowered train step's reduction primitive: ``stacked`` holds one
    gradient slice per data-parallel replica on the leading axis (which
    the caller shards over the data axes).  Each slice quantizes against
    the scale shared across *all* slices, the int16 codes are summed
    over the stacked axis — the one gradient-sized cross-data operation,
    which GSPMD lowers as an integer all-reduce — and the sum dequantizes
    to the mean.  Returns ``(mean, err)``: the delivered f32 mean (full
    leaf shape) and the per-slice f32 residual (leading ``(r,)`` kept)
    satisfying ``err[i] == stacked[i] - dequant(codes[i])`` exactly, so
    ``mean + mean_i(err[i]) == mean_i(stacked[i])`` (the telescoping
    identity the trajectory tests pin).
    """
    r = stacked.shape[0]
    a32 = jnp.asarray(stacked, jnp.float32)
    d = a32.shape[-1]
    blocks, pad = _last_dim_blocks(a32)
    amax = jnp.max(jnp.max(jnp.abs(blocks), axis=-1), axis=0)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[None, ..., None]), -127.0, 127.0)
    qsum = jnp.sum(q.astype(jnp.int16), axis=0, dtype=jnp.int16)
    mean = _unblock(qsum.astype(jnp.float32) * scale[..., None],
                    d, pad) / r
    err = a32 - _unblock(q * scale[None, ..., None], d, pad)
    return mean, err
