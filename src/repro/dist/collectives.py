"""Compressed collectives: int8 block quantization + error feedback.

The communication pass turns these on when a gradient reduction is the
step bottleneck (slow DCN "pod" axis, or a collective-bound step on the
ICI mesh).  The math contract, verified by the property tests:

* ``quantize_int8`` is block-wise symmetric: per 128-element block the
  reconstruction error is bounded by ``amax_block / 254`` (half a
  quantization step);
* ``ef_compress`` is *unbiased over time*: the residual carries exactly
  what quantization dropped, so ``sum(delivered) + residual ==
  sum(inputs)`` (telescoping) and the time-averaged delivered gradient
  converges to the true gradient;
* ``compressed_psum`` is a mean-reduction (gradient-averaging semantics)
  of the *dequantized* values, returning the local residual for feedback.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

#: quantization block: one f32 scale per 128 values (~3% volume overhead)
BLOCK = 128


def quantize_int8(x: jax.Array, block: int = BLOCK
                  ) -> Tuple[jax.Array, jax.Array, int]:
    """Block-wise symmetric int8 quantization of an arbitrary-shape array.

    Returns ``(q, scales, pad)``: int8 codes of shape
    ``(nblocks, block)``, one f32 scale per block, and the number of
    zero-padded tail elements (non-multiple shapes pad up).
    """
    flat = jnp.asarray(x).astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], pad


def dequantize_int8(q: jax.Array, scales: jax.Array, pad: int,
                    shape: Tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`quantize_int8` (f32 output of ``shape``)."""
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_compress(g: jax.Array, err: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compression of one gradient leaf.

    ``ghat = Q(g + err)``; the new residual ``(g + err) - ghat`` is what
    the quantizer dropped this step and is re-injected next step, making
    the compression unbiased over time.  ``err=None`` starts a fresh
    residual; otherwise the residual keeps its storage dtype (the plan
    stores it bf16 — half the optimizer-state cost of an f32 residual).
    """
    g32 = g.astype(jnp.float32)
    acc = g32 if err is None else g32 + err.astype(jnp.float32)
    q, scales, pad = quantize_int8(acc)
    ghat = dequantize_int8(q, scales, pad, g.shape)
    new_err = (acc - ghat).astype(jnp.float32 if err is None else err.dtype)
    return ghat.astype(g.dtype), new_err


def ef_state(params) -> dict:
    """Zero-initialized error-feedback residuals, one per parameter leaf.

    bf16 storage: the residual is bounded by half a quantization step, so
    bf16's ~3 significant digits lose <0.5% of an already-small term.
    """
    return jax.tree.map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.bfloat16), params)


def compressed_psum(x: jax.Array, axis) -> Tuple[jax.Array, jax.Array]:
    """Mean all-reduce of int8-quantized values, for use under shard_map.

    Each shard quantizes locally, the *dequantized* values are averaged
    over ``axis``, and the local quantization error is returned so the
    caller can feed it back (:func:`ef_compress` semantics split across
    shards).  Wire-volume model: int8 codes + one f32 scale per block =
    ~``(bits/8 + 4/128)`` bytes/element vs 2 (bf16) or 4 (f32).
    """
    q, scales, pad = quantize_int8(x)
    xq = dequantize_int8(q, scales, pad, jnp.shape(x))
    err = (jnp.asarray(x, jnp.float32) - xq).astype(x.dtype)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    y = jax.lax.psum(xq, axis) / n
    return y.astype(x.dtype), err
