"""repro.dist — the distributed-performance layer.

The paper's final specialization level configures how data moves between
chips; this package is that level's runtime library:

* :mod:`repro.dist.collectives` — int8 block-quantized gradient
  compression with error feedback, and ``compressed_psum`` for use under
  ``jax.shard_map`` (the template's ``special.compress`` function);
* :mod:`repro.dist.sharding`    — PartitionSpec resolution with the same
  divisibility repair the data-organization pass applies to the IR;
* :mod:`repro.dist.flash_decode`— shard_map flash-decode over a
  seq-sharded KV cache (local append + 3-term online-softmax combine).

Everything here is plan-driven: the passes decide *whether* these paths
run; this package only implements *how*.
"""

from __future__ import annotations

# installs the jax.shard_map alias on jax < 0.5 (tests call it directly)
from repro import compat as _compat  # noqa: F401

from repro.dist.collectives import (  # noqa: E402,F401
    compressed_psum,
    compressed_slice_sum,
    dequantize_int8,
    ef_compress,
    ef_state,
    quantize_int8,
)
from repro.dist.sharding import (  # noqa: E402,F401
    cache_pspecs,
    mesh_sizes,
    resolve_pspec,
    tree_shardings,
)
