"""Sharding resolution: logical axis rules -> PartitionSpecs.

This is the runtime mirror of ``DataOrganizationPass._resolve``: the pass
repairs the *IR*'s placements; these helpers apply the same two rules to
*runtime* pytrees (params, inputs, caches) whose shapes may differ from
the IR (padded heads/vocab, reduced smoke configs):

1. divisibility repair — an assignment that does not divide the dim is
   dropped (the tensor stays replicated on that dim);
2. uniqueness — a mesh axis may shard at most one dim of a tensor (first
   dim wins, matching the pass).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def mesh_sizes(mesh: Any) -> Dict[str, int]:
    """``{axis_name: size}`` for a jax Mesh, a MeshModel, or a dict."""
    if isinstance(mesh, Mapping):
        return dict(mesh)
    shape = getattr(mesh, "shape", None)
    if isinstance(shape, Mapping):          # jax.sharding.Mesh
        return dict(shape)
    axes = getattr(mesh, "axes", None) or getattr(mesh, "axis_names", None)
    if axes is None or shape is None:
        raise TypeError(
            f"mesh_sizes: unsupported mesh-like object "
            f"{type(mesh).__name__!r}: expected a Mapping "
            "{axis: size}, a jax.sharding.Mesh (`.shape` mapping), or a "
            "MeshModel-like object with `.axes`/`.axis_names` and a "
            f"`.shape` tuple (got axes={axes!r}, shape={shape!r})")
    axes, shape = tuple(axes), tuple(shape)
    if len(axes) != len(shape):
        raise TypeError(
            f"mesh_sizes: {type(mesh).__name__!r} has {len(axes)} axis "
            f"names {axes} but a {len(shape)}-entry shape {shape}")
    return dict(zip(axes, shape))


def _names(assign: Any) -> Tuple[str, ...]:
    if assign is None:
        return ()
    if isinstance(assign, str):
        return (assign,)
    return tuple(assign)


def resolve_pspec(rules: Mapping[str, Any], shape: Sequence[int],
                  axes: Sequence[Optional[str]],
                  sizes: Mapping[str, int]) -> P:
    """Resolve one tensor's logical axes through the plan's axis rules.

    ``rules`` maps logical axis -> mesh assignment (name, tuple of names,
    or None); ``axes`` names each dim of ``shape`` (None = never sharded);
    ``sizes`` is the mesh's ``{axis: size}``.  Divisibility repair and
    mesh-axis uniqueness are applied exactly as the data-organization
    pass does for IR tensors.
    """
    entries = []
    for dim, ax in zip(shape, axes):
        assign = rules.get(ax) if ax is not None else None
        names = tuple(n for n in _names(assign) if n in sizes)
        if not names:
            entries.append(None)
            continue
        factor = math.prod(sizes[n] for n in names)
        entries.append(names if factor and dim % factor == 0 else None)
    seen: set = set()
    out = []
    for e in entries:
        if e is None:
            out.append(None)
            continue
        keep = tuple(n for n in e if n not in seen)
        seen.update(keep)
        out.append(keep[0] if len(keep) == 1 else (keep or None))
    return P(*out)


def tree_shardings(mesh: jax.sharding.Mesh, pspecs: Any) -> Any:
    """Map a pytree of PartitionSpecs to NamedShardings on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


#: runtime cache pytree -> logical axes (matches core.describe's decls)
CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "pos": ("batch",),                  # per-slot (B,) decode offsets
    "k": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
    "ssm": ("layers", "batch", "ssm_heads", None, None),
    "conv": ("layers", "batch", None, "ssm_inner"),
}

#: paged residency: k/v are block pools (no batch/seq dims — the pool
#: dim is the unit of placement) and the block table rides the batch dim
PAGED_CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "pos": ("batch",),
    "k": ("layers", "kv_blocks", None, "kv_heads", "head_dim"),
    "v": ("layers", "kv_blocks", None, "kv_heads", "head_dim"),
    "block_tbl": ("batch", None),
    "ssm": ("layers", "batch", "ssm_heads", None, None),
    "conv": ("layers", "batch", None, "ssm_inner"),
}


def cache_pspecs(plan, arch, cache_shapes: Mapping[str, Any],
                 sizes: Mapping[str, int]) -> Dict[str, P]:
    """PartitionSpecs for the session-cache pytree.

    Starts from the plan's axis rules and overlays the per-tensor
    placement the data-organization pass decided for ``cache.*`` (that is
    where the seq-vs-head_dim spill for flash-decode lives), then
    re-applies divisibility repair against the *runtime* shapes (padded
    kv/ssm heads may differ from the IR).

    A paged cache (marked by its ``block_tbl`` entry) resolves through
    :data:`PAGED_CACHE_AXES`: the IR placement's seq-dim spill translates
    to the pool dim (``seq_kv -> kv_blocks`` — the paged analogue the
    :func:`repro.dist.flash_decode.flash_decode_paged` combine serves).
    When that combine will run its 2-D path (data degree divides both
    the batch and, jointly with the model degree, the pool —
    :func:`repro.dist.flash_decode.pool_sharding_kind` is the shared
    predicate), the pool dim shards data-major over ``(data..., model)``
    so the placement matches the shard_map's in_specs instead of
    resharding every tick.
    """
    paged = "block_tbl" in cache_shapes
    axes_map = PAGED_CACHE_AXES if paged else CACHE_AXES
    pool_2d = None
    if paged and "k" in cache_shapes:
        from repro.dist.flash_decode import pool_sharding_kind
        dnames = tuple(a for a in plan.mesh_axes
                       if a != "model" and a in sizes)
        n_blocks = cache_shapes["k"].shape[1]
        batch = cache_shapes["block_tbl"].shape[0]
        if pool_sharding_kind(dict(sizes), n_blocks, batch,
                              data_axes=dnames) == "2d":
            pool_2d = dnames + (("model",) if "model" in sizes else ())
    out: Dict[str, P] = {}
    for key, sds in cache_shapes.items():
        axes = axes_map.get(key, tuple(None for _ in sds.shape))
        rules = dict(plan.axis_rules)
        rules.setdefault("kv_blocks", None)
        placed = plan.placements.get(f"cache.{key}")
        if placed is not None and placed.spec:
            ir_axes = CACHE_AXES.get(key, axes)   # placements follow the IR
            for ax, assign in zip(ir_axes, placed.spec):
                if ax == "seq_kv" and paged:
                    ax = "kv_blocks"
                if ax is not None:
                    rules[ax] = assign
        if pool_2d is not None and key in ("k", "v"):
            rules["kv_blocks"] = pool_2d
        out[key] = resolve_pspec(rules, sds.shape, axes, sizes)
    return out
