"""shard_map flash-decode over a sequence-sharded KV cache.

The data-organization pass spills the cache's *seq* dim onto the model
axis when kv_heads are not shardable (GQA kv=8 on a 16-wide TP axis).
Decode then needs two things XLA's automatic partitioner does badly on a
seq-sharded cache:

1. the one-token append — a dynamic-update-slice at a runtime offset on
   a sharded dim lowers to a gather; here only the *owning* shard writes,
   locally;
2. the attention reduction — each shard computes a partial online
   softmax ``(m, l, acc)`` over its seq slice and the three terms are
   combined across the model axis (one pmax + two psums of tiny
   per-query tensors instead of gathering the cache).

Semantics match :func:`repro.kernels.ref.decode_attention_ref` with
``cache_len = pos + 1``; ``pos`` and ``window`` may be traced scalars.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat as _compat  # noqa: F401  (jax.shard_map alias)
from repro.dist.sharding import mesh_sizes

NEG_INF = -1e30


def _append(cache: jax.Array, new: jax.Array, idx: jax.Array,
            in_range) -> jax.Array:
    """Write ``new`` at seq offset ``idx`` iff ``in_range`` (else no-op)."""
    upd = jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), idx, axis=1)
    return jnp.where(in_range, upd, cache)


def _partial_attend(q: jax.Array, kc: jax.Array, vc: jax.Array,
                    kpos: jax.Array, pos: jax.Array, window: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax partial terms (m, l, acc) over one seq slice.

    ``kpos`` holds the slice's *global* positions, so the causal/window
    mask is exact on every shard; fully-masked shards contribute weight
    ``exp(NEG_INF - m_global) == 0`` in the combine.
    """
    B, _, H, D = q.shape
    K = kc.shape[2]
    G = H // K
    qh = q[:, 0].reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, kc.astype(jnp.float32))
    valid = kpos <= pos
    valid &= jnp.where(window > 0, (pos - kpos) < window, True)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
    return m, l, acc


def _finish(q: jax.Array, l: jax.Array, acc: jax.Array) -> jax.Array:
    B, _, H, D = q.shape
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]
    return ctx.reshape(B, H, D)[:, None].astype(q.dtype)


def flash_decode(q: jax.Array,            # (B, 1, H, D)
                 k_new: jax.Array,        # (B, 1, K, D)
                 v_new: jax.Array,        # (B, 1, K, D)
                 k_cache: jax.Array,      # (B, S, K, D)
                 v_cache: jax.Array,      # (B, S, K, D)
                 pos,                     # scalar int: append offset
                 window=0,                # scalar int: 0 = full attention
                 *,
                 mesh: jax.sharding.Mesh,
                 data_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model",
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a (batch, seq)-sharded cache.

    Returns ``(ctx, k_cache', v_cache')`` with ``ctx`` of shape
    ``(B, 1, H, D)``.  Falls back to an unsharded single-shard path when
    the model axis cannot shard the seq dim (size 1 or non-divisible).
    """
    pos = jnp.asarray(pos, jnp.int32)
    window = jnp.asarray(window, jnp.int32)
    sizes = mesh_sizes(mesh)
    msize = sizes.get(model_axis, 1)
    B, S = k_cache.shape[0], k_cache.shape[1]

    if msize <= 1 or S % msize != 0:
        kc = _append(k_cache, k_new, pos, True)
        vc = _append(v_cache, v_new, pos, True)
        m, l, acc = _partial_attend(q, kc, vc, jnp.arange(S), pos, window)
        return _finish(q, l, acc), kc, vc

    dnames = tuple(a for a in data_axes if a in sizes)
    import math
    dsize = math.prod(sizes[a] for a in dnames)
    bspec = None
    if dsize > 1 and B % dsize == 0:
        bspec = dnames[0] if len(dnames) == 1 else dnames

    def local_fn(q, kn, vn, kc, vc, pos, window):
        Sl = kc.shape[1]
        start = jax.lax.axis_index(model_axis).astype(jnp.int32) * Sl
        lp = pos - start
        in_range = (lp >= 0) & (lp < Sl)
        kc = _append(kc, kn, jnp.clip(lp, 0, Sl - 1), in_range)
        vc = _append(vc, vn, jnp.clip(lp, 0, Sl - 1), in_range)
        kpos = start + jnp.arange(Sl)
        m, l, acc = _partial_attend(q, kc, vc, kpos, pos, window)
        m_glob = jax.lax.pmax(m, model_axis)
        coef = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * coef, model_axis)
        acc_glob = jax.lax.psum(acc * coef[..., None], model_axis)
        return _finish(q, l_glob, acc_glob), kc, vc

    rep = P(bspec, None, None, None)
    shd = P(bspec, model_axis, None, None)
    # check_vma=False: the combine provably replicates ctx across the
    # model axis (psum/pmax), no need for the static replication checker
    # (repro.compat translates the kwarg for jax < 0.5)
    fn = jax.shard_map(local_fn, mesh=mesh,
                       in_specs=(rep, rep, rep, shd, shd, P(), P()),
                       out_specs=(rep, shd, shd), check_vma=False)
    return fn(q, k_new, v_new, k_cache, v_cache, pos, window)
