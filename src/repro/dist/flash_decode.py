"""shard_map flash-decode over a sequence-sharded KV cache.

The data-organization pass spills the cache's *seq* dim onto the model
axis when kv_heads are not shardable (GQA kv=8 on a 16-wide TP axis).
Decode then needs two things XLA's automatic partitioner does badly on a
seq-sharded cache:

1. the one-token append — a dynamic-update-slice at a runtime offset on
   a sharded dim lowers to a gather; here only the *owning* shard writes,
   locally;
2. the attention reduction — each shard computes a partial online
   softmax ``(m, l, acc)`` over its seq slice and the three terms are
   combined across the model axis (one pmax + two psums of tiny
   per-query tensors instead of gathering the cache).

Semantics match :func:`repro.kernels.ref.decode_attention_ref` with
``cache_len = pos + 1``; ``pos`` is a *per-slot* ``(B,)`` vector (a
scalar is broadcast) so a continuous batch of mixed prompt lengths
appends and masks each slot at its own offset; ``window`` may be a
traced scalar.

:func:`flash_decode_paged` is the paged-residency twin: the cache is a
block pool + per-slot block table, the *pool* dim takes the mesh axes
(there is no contiguous seq dim to shard), and the same 3-term combine
runs over each shard's owned blocks.  On a data×model mesh the pool is
sharded over BOTH axes (2-D pool sharding): the block dim splits
data-major into one sub-pool per data shard, batch slots are
*partitioned* — not replicated — across data, each (data, model) shard
appends and attends only the blocks it owns, and the combine psums
across the model axis alone (a data shard's slots never need another
data shard's blocks, so no data-axis collective exists in the step).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat as _compat  # noqa: F401  (jax.shard_map alias)
from repro.dist.sharding import mesh_sizes

NEG_INF = -1e30


def uses_seq_sharding(mesh, seq_len: int, model_axis: str = "model") -> bool:
    """Whether :func:`flash_decode` will actually run the seq-sharded
    shard_map path (vs its in-process single-shard combine).  The single
    source of truth for that dispatch — consumers reporting the decode
    path (``ServeEngine.decode_path``) must agree with it."""
    msize = mesh_sizes(mesh).get(model_axis, 1)
    return msize > 1 and seq_len % msize == 0


def combine_topology(mesh, *, model_axis: str = "model",
                     override=None) -> str:
    """Which model-axis softmax-combine topology a decode step runs —
    the single dispatch predicate shared by :func:`flash_decode`,
    :func:`flash_decode_paged` and ``ServeEngine.decode_path``
    (mirroring :func:`uses_seq_sharding` / :func:`pool_sharding_kind`).

    ``override`` is a plan- or caller-pinned topology ("flat" | "ring" |
    "bidir"); without one the cost model's calibrated thresholds choose.
    A degenerate model axis (degree <= 1) has no cross-shard combine, so
    it reports "flat" regardless of the override.
    """
    from repro.core.costmodel import (COMBINE_TOPOLOGIES,
                                      choose_combine_topology)
    msize = mesh_sizes(mesh).get(model_axis, 1)
    if msize <= 1:
        return "flat"
    if override is not None:
        if override not in COMBINE_TOPOLOGIES:
            raise ValueError(f"unknown combine topology {override!r}; "
                             f"expected one of {COMBINE_TOPOLOGIES}")
        return override
    return choose_combine_topology(msize)


def _ring_allgather(v: jax.Array, axis: str, n: int,
                    bidir: bool = False) -> jax.Array:
    """All-gather ``v`` into an ``(n, ...)`` source-indexed buffer via
    neighbor ppermutes: ``out[j]`` holds shard ``j``'s value on every
    shard.  ``bidir`` splits the walk across both ring directions —
    ``ceil((n-1)/2)`` forward + ``floor((n-1)/2)`` backward hops instead
    of ``n-1`` (the arms fill disjoint source slots: a collision would
    need ``t_fwd + t_bwd == n``, and the arms sum to at most ``n-1``).
    """
    idx = jax.lax.axis_index(axis)
    out = jnp.zeros((n,) + v.shape, v.dtype).at[idx].set(v)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    w = v
    for t in range(1, (n // 2 if bidir else n - 1) + 1):
        w = jax.lax.ppermute(w, axis, fwd)
        out = out.at[(idx - t) % n].set(w)
    if bidir:
        w = v
        for t in range(1, (n - 1) // 2 + 1):
            w = jax.lax.ppermute(w, axis, bwd)
            out = out.at[(idx + t) % n].set(w)
    return out


def _combine(m: jax.Array, l: jax.Array, acc: jax.Array,
             model_axis: str, msize: int, topology: str
             ) -> Tuple[jax.Array, jax.Array]:
    """Cross-shard online-softmax combine of partial ``(m, l, acc)``.

    * ``flat``  — pmax + two psums (three launches XLA fuses at small
      model degrees);
    * ``ring`` / ``bidir`` — ONE packed all-gather of the concatenated
      ``(m, l, acc)`` payload around the ring, then a local reduction.

    The local reduction folds sources *sequentially in source order* —
    the same order a host all-reduce applies — so ring and bidir are
    bitwise-identical to each other (same gathered buffer, same fold)
    and match flat to the last ulp (XLA fuses flat's reduce computation
    with the surrounding exp/mul, which can re-round one step; the
    multidevice oracle matrix pins both properties).
    """
    if topology == "flat":
        m_glob = jax.lax.pmax(m, model_axis)
        coef = jnp.exp(m - m_glob)
        return (jax.lax.psum(l * coef, model_axis),
                jax.lax.psum(acc * coef[..., None], model_axis))
    packed = jnp.concatenate([m[..., None], l[..., None], acc], axis=-1)
    g = _ring_allgather(packed, model_axis, msize,
                        bidir=(topology == "bidir"))
    ms, ls, accs = g[..., 0], g[..., 1], g[..., 2:]
    m_glob = ms[0]
    for i in range(1, msize):
        m_glob = jnp.maximum(m_glob, ms[i])
    coef = jnp.exp(ms - m_glob[None])
    lw, aw = ls * coef, accs * coef[..., None]
    l_glob, acc_glob = lw[0], aw[0]
    for i in range(1, msize):
        l_glob = l_glob + lw[i]
        acc_glob = acc_glob + aw[i]
    return l_glob, acc_glob


def _append(cache: jax.Array, new: jax.Array, idx: jax.Array,
            in_range: jax.Array) -> jax.Array:
    """Per-slot write of ``new[b]`` at seq offset ``idx[b]`` iff
    ``in_range[b]`` (else that slot is a no-op)."""
    def one(c, n, i, ok):
        upd = jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), i, axis=0)
        return jnp.where(ok, upd, c)
    return jax.vmap(one)(cache, new, idx, in_range)


def _partial_attend(q: jax.Array, kc: jax.Array, vc: jax.Array,
                    kpos: jax.Array, pos: jax.Array, window: jax.Array,
                    extra_mask: jax.Array = None,
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax partial terms (m, l, acc) over one seq slice.

    ``kpos`` holds the slice's *global* positions — ``(Sl,)`` shared, or
    ``(B, Sl)`` per slot (the paged path's compacted views differ per
    slot) — and ``pos`` the per-slot ``(B,)`` decode offsets, so the
    causal/window mask is exact per slot on every shard; fully-masked
    shards contribute weight ``exp(NEG_INF - m_global) == 0`` in the
    combine.  ``extra_mask`` (``(B, Sl)`` bool) additionally invalidates
    rows — the paged path's not-owned/unassigned blocks.
    """
    B, _, H, D = q.shape
    K = kc.shape[2]
    G = H // K
    qh = q[:, 0].reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, kc.astype(jnp.float32))
    kpos = kpos if kpos.ndim == 2 else kpos[None, :]            # (B|1, Sl)
    valid = kpos <= pos[:, None]                                # (B, Sl)
    valid &= jnp.where(window > 0, (pos[:, None] - kpos) < window, True)
    if extra_mask is not None:
        valid &= extra_mask
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
    return m, l, acc


def _finish(q: jax.Array, l: jax.Array, acc: jax.Array) -> jax.Array:
    B, _, H, D = q.shape
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]
    return ctx.reshape(B, H, D)[:, None].astype(q.dtype)


def flash_decode(q: jax.Array,            # (B, 1, H, D)
                 k_new: jax.Array,        # (B, 1, K, D)
                 v_new: jax.Array,        # (B, 1, K, D)
                 k_cache: jax.Array,      # (B, S, K, D)
                 v_cache: jax.Array,      # (B, S, K, D)
                 pos,                     # (B,) int per-slot offsets (scalar broadcast)
                 window=0,                # scalar int: 0 = full attention
                 *,
                 mesh: jax.sharding.Mesh,
                 data_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model",
                 combine=None,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a (batch, seq)-sharded cache.

    Returns ``(ctx, k_cache', v_cache')`` with ``ctx`` of shape
    ``(B, 1, H, D)``.  Falls back to an unsharded single-shard path when
    the model axis cannot shard the seq dim (size 1 or non-divisible).
    ``combine`` pins the cross-shard softmax-combine topology (a plan's
    recorded ``comm.combine_topology``); ``None`` asks the shared
    :func:`combine_topology` predicate.
    """
    pos = jnp.asarray(pos, jnp.int32)
    window = jnp.asarray(window, jnp.int32)
    sizes = mesh_sizes(mesh)
    B, S = k_cache.shape[0], k_cache.shape[1]
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)

    if not uses_seq_sharding(mesh, S, model_axis):
        always = jnp.ones((B,), bool)
        kc = _append(k_cache, k_new, pos, always)
        vc = _append(v_cache, v_new, pos, always)
        m, l, acc = _partial_attend(q, kc, vc, jnp.arange(S), pos, window)
        return _finish(q, l, acc), kc, vc

    dnames = tuple(a for a in data_axes if a in sizes)
    import math
    dsize = math.prod(sizes[a] for a in dnames)
    bspec = None
    if dsize > 1 and B % dsize == 0:
        bspec = dnames[0] if len(dnames) == 1 else dnames
    msize = sizes[model_axis]
    topology = combine_topology(mesh, model_axis=model_axis,
                                override=combine)

    def local_fn(q, kn, vn, kc, vc, pos, window):
        Sl = kc.shape[1]
        start = jax.lax.axis_index(model_axis).astype(jnp.int32) * Sl
        lp = pos - start                  # (B,) per-slot local offsets
        in_range = (lp >= 0) & (lp < Sl)  # only the owning shard writes
        kc = _append(kc, kn, jnp.clip(lp, 0, Sl - 1), in_range)
        vc = _append(vc, vn, jnp.clip(lp, 0, Sl - 1), in_range)
        kpos = start + jnp.arange(Sl)
        m, l, acc = _partial_attend(q, kc, vc, kpos, pos, window)
        l_glob, acc_glob = _combine(m, l, acc, model_axis, msize, topology)
        return _finish(q, l_glob, acc_glob), kc, vc

    rep = P(bspec, None, None, None)
    shd = P(bspec, model_axis, None, None)
    # check_vma=False: the combine provably replicates ctx across the
    # model axis (psum/pmax), no need for the static replication checker
    # (repro.compat translates the kwarg for jax < 0.5)
    fn = jax.shard_map(local_fn, mesh=mesh,
                       in_specs=(rep, rep, rep, shd, shd, P(bspec), P()),
                       out_specs=(rep, shd, shd), check_vma=False)
    return fn(q, k_new, v_new, k_cache, v_cache, pos, window)


# =====================================================================
# paged residency: the pool dim takes the model axis
# =====================================================================

def uses_pool_sharding(mesh, n_blocks: int, model_axis: str = "model") -> bool:
    """Whether :func:`flash_decode_paged` can run a pool-sharded
    shard_map path on the model axis alone (the 1-D predicate; see
    :func:`pool_sharding_kind` for the full data×model dispatch)."""
    msize = mesh_sizes(mesh).get(model_axis, 1)
    return msize > 1 and n_blocks % msize == 0


def pool_sharding_kind(mesh, n_blocks: int, batch: int,
                       data_axes: Tuple[str, ...] = ("data",),
                       model_axis: str = "model") -> str:
    """Which pool-sharded path :func:`flash_decode_paged` runs — the
    single dispatch predicate ``ServeEngine.decode_path`` (and its
    sub-pool block allocator) shares, mirroring
    :func:`uses_seq_sharding` for dense caches.

    ``"2d"``  — block dim sharded data-major over (data..., model) and
    the batch partitioned across data: needs a >1 data degree that
    divides both the batch (slots must be ownable per data shard) and,
    jointly with the model degree, the pool.
    ``"1d"``  — model-axis pool sharding only (the pool replicates over
    any data axes and the batch stays replicated with it).
    ``"none"`` — the in-process single-shard combine.
    """
    import math
    sizes = mesh_sizes(mesh)
    msize = sizes.get(model_axis, 1)
    dnames = tuple(a for a in data_axes if a in sizes)
    dsize = math.prod(sizes[a] for a in dnames) if dnames else 1
    if dsize > 1 and batch % dsize == 0 \
            and n_blocks and n_blocks % (dsize * msize) == 0:
        return "2d"
    if msize > 1 and n_blocks % msize == 0:
        return "1d"
    return "none"


def _partial_attend_paged(q, kp, vp, tbl, pos, window, start=0):
    """Partial (m, l, acc) over the blocks this shard owns.

    A slot can own at most ``min(nb, Nl)`` blocks on this shard, so the
    table is first *compacted* (owned entries sorted to the front) and
    only that many blocks are gathered and attended — per-shard reads
    and FLOPs stay ``~1/msize`` of the cache like the dense seq-sharded
    path, instead of every shard scoring the full masked view.
    Not-owned/unassigned rows are masked and contribute
    ``exp(NEG_INF - m_glob) == 0`` in the combine.
    """
    Nl, bl = kp.shape[0], kp.shape[1]
    B, nb = tbl.shape
    loc = tbl - start
    owned = (tbl >= 0) & (loc >= 0) & (loc < Nl)                # (B, nb)
    cols = min(nb, Nl)
    if cols < nb:
        # owned-first stable permutation of each slot's table columns;
        # the surviving column index still encodes the block's dense-
        # view position, so kpos rides along per slot
        order = jnp.argsort(jnp.where(owned, 0, 1), axis=1,
                            stable=True)[:, :cols]              # (B, cols)
        loc = jnp.take_along_axis(loc, order, axis=1)
        owned = jnp.take_along_axis(owned, order, axis=1)
        blk_pos = order                                         # (B, cols)
    else:
        blk_pos = jnp.broadcast_to(jnp.arange(nb), (B, nb))
    safe = jnp.clip(loc, 0, Nl - 1)
    kd = kp[safe].reshape(B, cols * bl, *kp.shape[2:])
    vd = vp[safe].reshape(B, cols * bl, *vp.shape[2:])
    kpos = (blk_pos[:, :, None] * bl
            + jnp.arange(bl)[None, None, :]).reshape(B, cols * bl)
    extra = jnp.repeat(owned, bl, axis=1)
    return _partial_attend(q, kd, vd, kpos, pos, window, extra_mask=extra)


def flash_decode_paged(q: jax.Array,       # (B, 1, H, D)
                       k_new: jax.Array,   # (B, 1, K, D)
                       v_new: jax.Array,   # (B, 1, K, D)
                       k_pool: jax.Array,  # (N, bl, K, D) block pool
                       v_pool: jax.Array,  # (N, bl, K, D)
                       block_tbl: jax.Array,  # (B, nb) ids; -1 unassigned
                       pos,                # (B,) per-slot offsets (scalar ok)
                       window=0,
                       *,
                       mesh: jax.sharding.Mesh,
                       data_axes: Tuple[str, ...] = ("data",),
                       model_axis: str = "model",
                       combine=None,
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a block-pool cache sharded on the *pool*
    dim (a paged cache has no contiguous seq dim to shard — the pool is
    the unit of placement, so each shard owns its slice of blocks and
    only the owner writes or attends over a block).

    Returns ``(ctx, k_pool', v_pool')`` with ``ctx`` ``(B, 1, H, D)``.
    Dispatch is :func:`pool_sharding_kind`:

    * ``"2d"`` — the block dim shards data-major over ``(data...,
      model)`` and the batch partitions across data.  Contract: every
      slot's table entries must point into the sub-pool of the data
      shard hosting that slot (``ServeEngine``'s allocator guarantees
      it) — a foreign-sub-pool block is owned by no shard in the slot's
      data row and is masked out of the combine.  Appends land on the
      one (data, model) shard owning the block; the softmax combine
      psums across model only.
    * ``"1d"`` — model-axis sharding only.  The pool *replicates* over
      any data axes (no batch dim to shard), so the batch stays
      replicated with it — batch-sharded appends would make each data
      replica append only its own slots' rows and silently diverge.
    * ``"none"`` — the unsharded single-shard combine.

    ``combine`` pins the model-axis softmax-combine topology (see
    :func:`combine_topology`); it changes the wire pattern of the
    combine, never its value.

    Semantics match :func:`repro.kernels.ref.paged_decode_attention_ref`
    over the appended pool with ``cache_len = pos + 1``.
    """
    pos = jnp.asarray(pos, jnp.int32)
    window = jnp.asarray(window, jnp.int32)
    B, N = block_tbl.shape[0], k_pool.shape[0]
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)

    from repro.models.lm import append_kv_paged

    kind = pool_sharding_kind(mesh, N, B, data_axes, model_axis)
    if kind == "none":
        kp = append_kv_paged(k_pool, k_new, pos, block_tbl)
        vp = append_kv_paged(v_pool, v_new, pos, block_tbl)
        m, l, acc = _partial_attend_paged(q, kp, vp, block_tbl, pos, window)
        return _finish(q, l, acc), kp, vp

    sizes = mesh_sizes(mesh)
    msize = sizes.get(model_axis, 1)
    topology = combine_topology(mesh, model_axis=model_axis,
                                override=combine)
    dnames = tuple(a for a in data_axes if a in sizes)
    if kind == "2d":
        bspec = dnames[0] if len(dnames) == 1 else dnames
        pool_assign = dnames + ((model_axis,) if model_axis in sizes else ())
    else:
        bspec = None
        pool_assign = (model_axis,)

    def local_fn(q, kn, vn, kp, vp, tbl, pos, window):
        Nl = kp.shape[0]
        # this shard's first global block id: data-major linearization of
        # its (data..., model) coordinates, matching the pool dim's
        # data-major PartitionSpec layout
        shard = jnp.zeros((), jnp.int32)
        if kind == "2d":
            for a in dnames:
                shard = shard * sizes[a] + jax.lax.axis_index(a)
        if model_axis in sizes:
            shard = shard * msize + jax.lax.axis_index(model_axis)
        start = shard.astype(jnp.int32) * Nl
        kp = append_kv_paged(kp, kn, pos, tbl, start)
        vp = append_kv_paged(vp, vn, pos, tbl, start)
        m, l, acc = _partial_attend_paged(q, kp, vp, tbl, pos, window, start)
        if msize > 1:
            l, acc = _combine(m, l, acc, model_axis, msize, topology)
        return _finish(q, l, acc), kp, vp

    rep = P(bspec, None, None, None)
    shd = P(pool_assign if len(pool_assign) > 1 else pool_assign[0],
            None, None, None)
    fn = jax.shard_map(local_fn, mesh=mesh,
                       in_specs=(rep, rep, rep, shd, shd,
                                 P(bspec, None), P(bspec), P()),
                       out_specs=(rep, shd, shd), check_vma=False)
    return fn(q, k_new, v_new, k_pool, v_pool, block_tbl, pos, window)
