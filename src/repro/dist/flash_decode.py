"""shard_map flash-decode over a sequence-sharded KV cache.

The data-organization pass spills the cache's *seq* dim onto the model
axis when kv_heads are not shardable (GQA kv=8 on a 16-wide TP axis).
Decode then needs two things XLA's automatic partitioner does badly on a
seq-sharded cache:

1. the one-token append — a dynamic-update-slice at a runtime offset on
   a sharded dim lowers to a gather; here only the *owning* shard writes,
   locally;
2. the attention reduction — each shard computes a partial online
   softmax ``(m, l, acc)`` over its seq slice and the three terms are
   combined across the model axis (one pmax + two psums of tiny
   per-query tensors instead of gathering the cache).

Semantics match :func:`repro.kernels.ref.decode_attention_ref` with
``cache_len = pos + 1``; ``pos`` is a *per-slot* ``(B,)`` vector (a
scalar is broadcast) so a continuous batch of mixed prompt lengths
appends and masks each slot at its own offset; ``window`` may be a
traced scalar.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat as _compat  # noqa: F401  (jax.shard_map alias)
from repro.dist.sharding import mesh_sizes

NEG_INF = -1e30


def uses_seq_sharding(mesh, seq_len: int, model_axis: str = "model") -> bool:
    """Whether :func:`flash_decode` will actually run the seq-sharded
    shard_map path (vs its in-process single-shard combine).  The single
    source of truth for that dispatch — consumers reporting the decode
    path (``ServeEngine.decode_path``) must agree with it."""
    msize = mesh_sizes(mesh).get(model_axis, 1)
    return msize > 1 and seq_len % msize == 0


def _append(cache: jax.Array, new: jax.Array, idx: jax.Array,
            in_range: jax.Array) -> jax.Array:
    """Per-slot write of ``new[b]`` at seq offset ``idx[b]`` iff
    ``in_range[b]`` (else that slot is a no-op)."""
    def one(c, n, i, ok):
        upd = jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), i, axis=0)
        return jnp.where(ok, upd, c)
    return jax.vmap(one)(cache, new, idx, in_range)


def _partial_attend(q: jax.Array, kc: jax.Array, vc: jax.Array,
                    kpos: jax.Array, pos: jax.Array, window: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax partial terms (m, l, acc) over one seq slice.

    ``kpos`` holds the slice's *global* positions and ``pos`` the
    per-slot ``(B,)`` decode offsets, so the causal/window mask is exact
    per slot on every shard; fully-masked shards contribute weight
    ``exp(NEG_INF - m_global) == 0`` in the combine.
    """
    B, _, H, D = q.shape
    K = kc.shape[2]
    G = H // K
    qh = q[:, 0].reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, kc.astype(jnp.float32))
    valid = kpos[None, :] <= pos[:, None]                       # (B, Sl)
    valid &= jnp.where(window > 0,
                       (pos[:, None] - kpos[None, :]) < window, True)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, vc.astype(jnp.float32))
    return m, l, acc


def _finish(q: jax.Array, l: jax.Array, acc: jax.Array) -> jax.Array:
    B, _, H, D = q.shape
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]
    return ctx.reshape(B, H, D)[:, None].astype(q.dtype)


def flash_decode(q: jax.Array,            # (B, 1, H, D)
                 k_new: jax.Array,        # (B, 1, K, D)
                 v_new: jax.Array,        # (B, 1, K, D)
                 k_cache: jax.Array,      # (B, S, K, D)
                 v_cache: jax.Array,      # (B, S, K, D)
                 pos,                     # (B,) int per-slot offsets (scalar broadcast)
                 window=0,                # scalar int: 0 = full attention
                 *,
                 mesh: jax.sharding.Mesh,
                 data_axes: Tuple[str, ...] = ("data",),
                 model_axis: str = "model",
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a (batch, seq)-sharded cache.

    Returns ``(ctx, k_cache', v_cache')`` with ``ctx`` of shape
    ``(B, 1, H, D)``.  Falls back to an unsharded single-shard path when
    the model axis cannot shard the seq dim (size 1 or non-divisible).
    """
    pos = jnp.asarray(pos, jnp.int32)
    window = jnp.asarray(window, jnp.int32)
    sizes = mesh_sizes(mesh)
    B, S = k_cache.shape[0], k_cache.shape[1]
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)

    if not uses_seq_sharding(mesh, S, model_axis):
        always = jnp.ones((B,), bool)
        kc = _append(k_cache, k_new, pos, always)
        vc = _append(v_cache, v_new, pos, always)
        m, l, acc = _partial_attend(q, kc, vc, jnp.arange(S), pos, window)
        return _finish(q, l, acc), kc, vc

    dnames = tuple(a for a in data_axes if a in sizes)
    import math
    dsize = math.prod(sizes[a] for a in dnames)
    bspec = None
    if dsize > 1 and B % dsize == 0:
        bspec = dnames[0] if len(dnames) == 1 else dnames

    def local_fn(q, kn, vn, kc, vc, pos, window):
        Sl = kc.shape[1]
        start = jax.lax.axis_index(model_axis).astype(jnp.int32) * Sl
        lp = pos - start                  # (B,) per-slot local offsets
        in_range = (lp >= 0) & (lp < Sl)  # only the owning shard writes
        kc = _append(kc, kn, jnp.clip(lp, 0, Sl - 1), in_range)
        vc = _append(vc, vn, jnp.clip(lp, 0, Sl - 1), in_range)
        kpos = start + jnp.arange(Sl)
        m, l, acc = _partial_attend(q, kc, vc, kpos, pos, window)
        m_glob = jax.lax.pmax(m, model_axis)
        coef = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * coef, model_axis)
        acc_glob = jax.lax.psum(acc * coef[..., None], model_axis)
        return _finish(q, l_glob, acc_glob), kc, vc

    rep = P(bspec, None, None, None)
    shd = P(bspec, model_axis, None, None)
    # check_vma=False: the combine provably replicates ctx across the
    # model axis (psum/pmax), no need for the static replication checker
    # (repro.compat translates the kwarg for jax < 0.5)
    fn = jax.shard_map(local_fn, mesh=mesh,
                       in_specs=(rep, rep, rep, shd, shd, P(bspec), P()),
                       out_specs=(rep, shd, shd), check_vma=False)
    return fn(q, k_new, v_new, k_cache, v_cache, pos, window)
