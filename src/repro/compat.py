"""jax version-compatibility shims (import for side effects).

The tree targets the stable ``jax.shard_map`` spelling with the
``check_vma`` kwarg; on jax < 0.5 that API lives under
``jax.experimental.shard_map`` and the kwarg is named ``check_rep``.
Importing this module installs a translating alias once.
"""

from __future__ import annotations

import jax

if not hasattr(jax, "shard_map"):  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, *args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, *args, **kwargs)

    jax.shard_map = _compat_shard_map
