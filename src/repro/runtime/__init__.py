from repro.runtime.fault import HealthMonitor, RestartPolicy, StepGuard, elastic_mesh
from repro.runtime.straggler import DeadlineSkipper, StepTimer
__all__ = ["HealthMonitor", "RestartPolicy", "StepGuard", "elastic_mesh",
           "DeadlineSkipper", "StepTimer"]
