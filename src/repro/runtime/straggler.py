"""Straggler mitigation.

SPMD collectives make every step as slow as the slowest chip, so
mitigation happens at the edges of the SPMD region:

* :class:`StepTimer`     — EWMA + deviation of step times; flags hosts
  whose input pipeline (the non-SPMD part) lags.
* :class:`DeadlineSkipper` — if a host's batch misses the deadline, the
  step runs with the *previous* prefetched batch for that host (data
  reordering, not a step stall).  Bounded by ``max_skips``.
* For in-SPMD stragglers (a slow chip), the remedy is the elastic re-mesh
  in :mod:`repro.runtime.fault` — documented SPMD limit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class StepTimer:
    alpha: float = 0.1
    mean_s: float = 0.0
    var_s: float = 0.0
    n: int = 0

    def observe(self, dt: float) -> None:
        if self.n == 0:
            self.mean_s = dt
        delta = dt - self.mean_s
        self.mean_s += self.alpha * delta
        self.var_s = (1 - self.alpha) * (self.var_s + self.alpha * delta * delta)
        self.n += 1

    def is_straggler(self, dt: float, k: float = 3.0) -> bool:
        if self.n < 8:
            return False
        return dt > self.mean_s + k * max(self.var_s ** 0.5,
                                          0.05 * self.mean_s)


@dataclasses.dataclass
class DeadlineSkipper:
    deadline_factor: float = 2.0     # x mean step time
    max_skips: int = 10
    skips: int = 0
    skipped_steps: List[int] = dataclasses.field(default_factory=list)

    def should_skip(self, step: int, waited_s: float, timer: StepTimer) -> bool:
        if timer.n < 8 or self.skips >= self.max_skips:
            return False
        if waited_s > self.deadline_factor * timer.mean_s:
            self.skips += 1
            self.skipped_steps.append(step)
            return True
        return False
