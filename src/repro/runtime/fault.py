"""Fault tolerance: failure detection, restart policy, elastic re-mesh.

At 1000+ nodes the mean time between node failures drops below the job
length; the runtime must (a) detect, (b) checkpoint-restart, (c) continue
on a *different* device count when replacements lag.  The pieces:

* :class:`HealthMonitor`   — heartbeat table + deadline detection.
* :class:`RestartPolicy`   — exponential backoff, max-restarts budget.
* :func:`elastic_mesh`     — largest (data', model) mesh that fits the
  surviving devices while preserving the model axis (TP must not shrink
  below what the weights were planned for; data/pod axes absorb losses).
* :class:`StepGuard`       — wraps the train step: on any device error it
  restores from the last checkpoint and replays the data stream (the
  pipeline is seeded per (host, step), so replay is bit-exact).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax


@dataclasses.dataclass
class HealthMonitor:
    """Heartbeat bookkeeping (transport-agnostic: callers feed beats).

    Hosts must be *registered* with :meth:`expect` before they are
    trusted to beat: a worker that dies between spawn and its first
    heartbeat never enters ``beats``, and a monitor that only scans
    ``beats`` reports it healthy forever.  ``expect`` starts the
    deadline clock at registration time, so dead-on-arrival hosts show
    up in :meth:`dead_hosts` after the same ``timeout_s`` as a host
    that beat once and went silent.
    """

    timeout_s: float = 60.0
    beats: Dict[int, float] = dataclasses.field(default_factory=dict)
    expected: Dict[int, float] = dataclasses.field(default_factory=dict)

    def expect(self, host_ids, t: Optional[float] = None) -> None:
        """Register hosts that *should* beat; resets their deadline clock
        (re-registering a respawned host id restarts its grace window)."""
        now = t if t is not None else time.time()
        for h in host_ids:
            self.expected[h] = now
            self.beats.pop(h, None)

    def forget(self, host_id: int) -> None:
        """Deregister a host (retired/shut down on purpose)."""
        self.expected.pop(host_id, None)
        self.beats.pop(host_id, None)

    def beat(self, host_id: int, t: Optional[float] = None) -> None:
        self.beats[host_id] = t if t is not None else time.time()

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        dead = {h for h, t in self.beats.items() if now - t > self.timeout_s}
        # dead-on-arrival: expected, never beat, grace window elapsed
        dead |= {h for h, t0 in self.expected.items()
                 if h not in self.beats and now - t0 > self.timeout_s}
        return sorted(dead)

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 100
    backoff_base_s: float = 5.0
    backoff_cap_s: float = 300.0
    restarts: int = 0

    def next_delay(self) -> float:
        if self.restarts >= self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted ({self.max_restarts})")
        d = min(self.backoff_base_s * (2 ** min(self.restarts, 10)),
                self.backoff_cap_s)
        self.restarts += 1
        return d


def elastic_mesh(n_devices: int, model_parallel: int,
                 axis_names: Tuple[str, ...] = ("data", "model")):
    """Largest (data', model) mesh on the surviving devices.

    The model axis is preserved (the memory plan's TP sharding of the
    weights is only valid at that width); whole TP groups that lost a
    member are dropped, so data parallelism absorbs the failure.
    """
    data = n_devices // model_parallel
    if data < 1:
        raise RuntimeError(
            f"{n_devices} devices cannot host model_parallel="
            f"{model_parallel}")
    usable = data * model_parallel
    devices = jax.devices()[:usable]
    if len(devices) < usable:
        raise RuntimeError(
            f"only {len(devices)} device(s) visible; need {usable} "
            f"(data={data} x model_parallel={model_parallel}) — "
            f"shrink n_devices to what actually survived")
    import numpy as np
    arr = np.array(devices).reshape(data, model_parallel)
    return jax.sharding.Mesh(arr, axis_names)


class StepGuard:
    """Run steps; on device failure restore + replay.

    ``make_step(mesh) -> (step_fn, state)`` rebuilds the jitted step for a
    (possibly smaller) mesh; ``restore(mesh) -> (state, step)`` reloads
    the latest checkpoint resharded for it.
    """

    def __init__(self, make_step: Callable, restore: Callable,
                 policy: Optional[RestartPolicy] = None,
                 model_parallel: int = 1):
        self.make_step = make_step
        self.restore = restore
        self.policy = policy or RestartPolicy()
        self.model_parallel = model_parallel
        self.events: List[Dict] = []

    def run(self, state, batches, n_steps: int, start_step: int = 0,
            fail_injector: Optional[Callable[[int], None]] = None):
        """Drive n_steps; inject failures in tests via fail_injector."""
        mesh = elastic_mesh(len(jax.devices()), self.model_parallel)
        step_fn = self.make_step(mesh)
        step = start_step
        metrics = None
        while step < n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                batch = batches(step)
                state, metrics = step_fn(state, batch)
                step += 1
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                delay = self.policy.next_delay()
                self.events.append({"step": step, "error": str(e)[:200],
                                    "backoff_s": delay})
                # (in production: sleep(delay); wait for healthy quorum)
                mesh = elastic_mesh(len(jax.devices()), self.model_parallel)
                step_fn = self.make_step(mesh)
                state, step = self.restore(mesh)
        return state, step, metrics
