"""HLO-text cost analysis with while-loop trip-count handling.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (XLA's
HloCostAnalysis does not multiply by trip count), which silently drops
~L× of the FLOPs/bytes/collectives of a scan-over-layers model.  This
module re-derives the three roofline inputs from ``compiled.as_text()``:

* FLOPs      — every ``dot``/``convolution``, 2·|out|·K, ×trip-count
* HBM bytes  — Σ (operand + output bytes) over top-level instructions
               (post-fusion boundaries ≈ HBM-crossing traffic), ×trip
* collective bytes — per collective kind, ring-model per-device bytes,
               ×trip, with DCN/ICI attribution where derivable

The parser is deliberately tolerant: unknown constructs contribute zero
rather than raising, and the raw ``cost_analysis`` numbers are reported
alongside for cross-checking.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_WHILE_RE = re.compile(r"body=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\'"]?\s*:\s*\{\s*[\'"]n[\'"]\s*:\s*[\'"]?(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of the FIRST shape in a type string (handles tuples by
    summing all member shapes)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def first_shape(type_str: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_bytes_dcn: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # (callee, multiplier, materializes): fusion-interior computations do
    # NOT materialize their instructions (no HBM traffic), while bodies do
    calls: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)
    # byte events: (op, out_bytes, [operand_bytes], callee_or_None)
    byte_events: List[Tuple[str, int, List[int], Optional[str]]] = \
        dataclasses.field(default_factory=list)
    root_kind: str = ""          # op kind of the ROOT instruction


def _group_size(line: str, default: int) -> Tuple[int, bool]:
    """(group size, crosses_pod_boundary) from replica_groups."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, gsize, total = map(int, m.groups())
        # iota with transpose reorders ranks; a plain iota groups contiguous
        # ids.  Crossing the 256-chip pod boundary with contiguous ids means
        # the group spans pods.
        crosses = total > 256 and gsize > 256
        if "T(" in line and total > 256:
            # transposed iota: strided groups; the pod stride is 256
            crosses = True
        return max(gsize, 1), crosses
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
        crosses = (max(ids) // 256) != (min(ids) // 256) if ids else False
        return max(len(ids), 1), crosses
    return default, False


def _collective_bytes(kind: str, out_bytes: int, in_bytes: int,
                      g: int) -> float:
    """Ring-model per-device bytes on the wire."""
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * in_bytes * (g - 1) / g
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return in_bytes * (g - 1) / g
    if kind == "all-to-all":
        return in_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(in_bytes)
    return 0.0


_SKIP_BYTES_OPS = (
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
)


def parse_hlo(text: str, n_devices: int) -> Dict[str, float]:
    """Analyze one (SPMD, per-device) HLO module's text."""
    comps: Dict[str, CompStats] = {}
    shapes: Dict[str, str] = {}      # per-computation symbol table
    cur: Optional[CompStats] = None
    cur_name = ""
    entry = ""

    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        # computation header: "%name (args) -> type {" or "ENTRY %name ..."
        if line.endswith("{") and ("(" in line) and ("=" not in line.split("(")[0]):
            name_m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if name_m:
                cur_name = name_m.group(1)
                cur = CompStats()
                comps[cur_name] = cur
                shapes = {}
                if line.startswith("ENTRY"):
                    entry = cur_name
                # record parameter shapes from the signature
                sig = line[line.find("(") + 1:line.rfind("->")]
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      sig):
                    shapes[pm.group(1)] = pm.group(2)
            continue
        if line == "}" or cur is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # output type is the prefix of rhs up to the op name
        type_str = rhs.split(" ")[0]
        shapes[name] = type_str
        out_bytes = shape_bytes(type_str)

        # op kind: token right after the type
        rest = rhs[len(type_str):].strip()
        op = rest.split("(")[0].strip().split(" ")[-1] if "(" in rest else rest
        opnds = _OPND_RE.findall(rest[rest.find("("):] if "(" in rest else "")
        opnd_bytes = [shape_bytes(shapes.get(o, "")) for o in opnds]
        in_bytes = sum(opnd_bytes)
        if line.lstrip().startswith("ROOT"):
            cur.root_kind = op

        # ---- FLOPs ----
        if op == "dot":
            _, out_dims = first_shape(type_str)
            k = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
            if cm and opnds:
                _, lhs_dims = first_shape(shapes.get(opnds[0], ""))
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
            cur.flops += 2.0 * math.prod(out_dims or [0]) * k
        elif op == "convolution":
            _, out_dims = first_shape(type_str)
            _, rhs_dims = first_shape(shapes.get(opnds[1], "")) if len(opnds) > 1 else (None, [])
            # 2 * out * (kernel spatial x in-features) approx
            k = math.prod(rhs_dims[:-1]) if rhs_dims else 1
            cur.flops += 2.0 * math.prod(out_dims or [0]) * k

        # ---- collectives ----
        matched_coll = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start"):
                matched_coll = c
                break
        if matched_coll:
            g, crosses = _group_size(line, n_devices)
            b = _collective_bytes(matched_coll, out_bytes, in_bytes, g)
            cur.coll_bytes += b
            cur.coll_by_kind[matched_coll] += b
            if crosses:
                cur.coll_bytes_dcn += b

        # ---- HBM bytes (fusion-boundary traffic; resolved in 2nd pass) ----
        if op not in _SKIP_BYTES_OPS and not op.startswith("while"):
            callee_m = _CALLS_RE.search(rest) if op.startswith("fusion") else None
            cur.byte_events.append(
                (op, out_bytes, opnd_bytes,
                 callee_m.group(1) if callee_m else None))

        # ---- call graph ----
        if op.startswith("while"):
            bm = _WHILE_RE.search(rest)
            tm = _TRIP_RE.search(rest)
            trip = float(tm.group(1)) if tm else 1.0
            if bm:
                cur.calls.append((bm.group(1), trip, True))
        else:
            # fusion interiors don't materialize; call/async wrappers do
            materializes = not op.startswith("fusion")
            for cm2 in _CALLS_RE.finditer(rest):
                cur.calls.append((cm2.group(1), 1.0, materializes))
        if op in ("conditional",):
            for br in re.finditer(r"(?:true_computation|false_computation|"
                                  r"branch_computations)=\{?%([\w\.\-]+)", rest):
                cur.calls.append((br.group(1), 1.0, True))

    # ---- second pass: resolve byte events now all roots are known ------
    # TPU-fusion byte model: the CPU backend materializes every elementwise
    # chain (and stores bf16 as f32), which wildly overstates HBM traffic
    # for the TPU target.  Count only ops that MUST cross HBM under TPU
    # XLA fusion: matmuls/convs (operands+outputs), reductions, gathers/
    # scatters, data movement slices, sorts.  Elementwise/transpose/convert
    # chains are assumed fused into their consumers (TPU behavior).
    _COUNTED = ("dot", "convolution", "reduce", "reduce-window", "sort",
                "gather", "scatter", "select-and-scatter", "concatenate",
                "cholesky", "triangular-solve", "fft", "rng")

    def event_bytes(op: str, out_b: int, opnd_b: List[int],
                    callee: Optional[str]) -> float:
        root = comps[callee].root_kind if callee in comps else ""
        kind = op if not op.startswith("fusion") else (root or "fusion")
        if kind.startswith("dynamic-update-slice"):
            # in-place update: traffic = the update slice (r+w), not the
            # full buffer (which aliases the output)
            big = max(opnd_b) if opnd_b else 0
            rest = sum(opnd_b) - big
            return max(0.0, out_b - big) + 2.0 * rest
        if kind.startswith("dynamic-slice"):
            return 2.0 * out_b
        if any(kind.startswith(c) for c in _COUNTED):
            return float(out_b + sum(opnd_b))
        return 0.0

    comp_bytes: Dict[str, float] = {
        name: sum(event_bytes(*ev) for ev in c.byte_events)
        for name, c in comps.items()
    }

    # ---- accumulate through the call graph (memoized) ----
    memo: Dict[Tuple[str, bool], Tuple] = {}

    def total(name: str, mat: bool, depth=0):
        key = (name, mat)
        if key in memo:
            return memo[key]
        if name not in comps or depth > 64:
            return (0.0, 0.0, 0.0, 0.0, {})
        c = comps[name]
        f = c.flops
        b = comp_bytes[name] if mat else 0.0
        cb, cd = c.coll_bytes, c.coll_bytes_dcn
        kinds = dict(c.coll_by_kind)
        memo[key] = (f, b, cb, cd, kinds)  # break cycles conservatively
        for callee, mult, child_mat in c.calls:
            cf, cbts, ccb, ccd, ck = total(callee, mat and child_mat,
                                           depth + 1)
            f += mult * cf
            b += mult * cbts
            cb += mult * ccb
            cd += mult * ccd
            for k, v in ck.items():
                kinds[k] = kinds.get(k, 0.0) + mult * v
        memo[key] = (f, b, cb, cd, kinds)
        return memo[key]

    f, b, cb, cd, kinds = total(entry, True) if entry else (0, 0, 0, 0, {})
    return {
        "flops": f,
        "hbm_bytes": b,
        "collective_bytes": cb,
        "collective_bytes_dcn": cd,
        "collective_by_kind": kinds,
        "n_computations": len(comps),
    }
