"""Three-term roofline analysis from the compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

FLOPs/bytes come from the trip-count-corrected HLO walk
(:mod:`repro.analysis.hlo_stats`), cross-checked against
``compiled.cost_analysis()`` (which undercounts loop bodies); collective
bytes are parsed from the HLO (they are absent from cost_analysis).

All quantities in this module are PER-DEVICE (the compiled module is the
SPMD per-device program), so "/(chips x ...)" is already folded in.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.analysis.hlo_stats import parse_hlo
from repro.configs.base import ArchConfig, ShapeConfig
from repro.hw.tpu import TpuTarget, get_target


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str                     # train | decode | prefill | forward
    # per-device quantities
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_bytes_dcn: float
    collective_by_kind: Dict[str, float]
    # raw cost_analysis (uncorrected; for the cross-check column)
    xla_flops_raw: float
    xla_bytes_raw: float
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    # model-level
    model_flops: float            # 6*N*D (dense) / 6*N_active*D per device
    useful_ratio: float           # model_flops / hlo_flops
    bottleneck: str
    step_time_s: float            # max of terms (perfect overlap)
    mfu: float                    # model_flops / (step_time * peak)
    memory_per_device_bytes: float
    notes: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def model_flops_per_step(arch: ArchConfig, shape: ShapeConfig) -> float:
    """6·N·D with N = active params (MoE) and D = tokens this step.

    Training counts fwd+bwd (6ND); inference counts forward only (2ND).
    """
    n = arch.active_param_count()
    toks = shape.tokens
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * toks


def analyze(
    *,
    arch: ArchConfig,
    shape: ShapeConfig,
    kind: str,
    hlo_text: str,
    n_devices: int,
    cost_analysis: Optional[Dict[str, float]] = None,
    memory_stats: Optional[Any] = None,
    mesh_desc: str = "",
    target: str | TpuTarget = "tpu-v5e",
) -> RooflineReport:
    tgt = target if isinstance(target, TpuTarget) else get_target(target)
    stats = parse_hlo(hlo_text, n_devices)
    ca = cost_analysis or {}

    flops = stats["flops"]
    hbm = stats["hbm_bytes"]
    coll = stats["collective_bytes"]
    coll_dcn = stats["collective_bytes_dcn"]

    compute_s = flops / tgt.peak_bf16_flops
    memory_s = hbm / tgt.hbm_bw
    # DCN-crossing bytes ride the slow channel
    collective_s = (coll - coll_dcn) / tgt.ici_link_bw + coll_dcn / tgt.dcn_bw

    mf = model_flops_per_step(arch, shape) / n_devices
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(compute_s, memory_s, collective_s)
    mfu = mf / (step * tgt.peak_bf16_flops) if step > 0 else 0.0

    mem_bytes = 0.0
    if memory_stats is not None:
        mem_bytes = (memory_stats.argument_size_in_bytes
                     + memory_stats.output_size_in_bytes
                     + memory_stats.temp_size_in_bytes
                     - memory_stats.alias_size_in_bytes)

    return RooflineReport(
        arch=arch.name,
        shape=shape.name,
        mesh=mesh_desc,
        kind=kind,
        hlo_flops=flops,
        hlo_bytes=hbm,
        collective_bytes=coll,
        collective_bytes_dcn=coll_dcn,
        collective_by_kind=stats["collective_by_kind"],
        xla_flops_raw=float(ca.get("flops", 0.0)),
        xla_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops=mf,
        useful_ratio=mf / flops if flops else 0.0,
        bottleneck=bottleneck,
        step_time_s=step,
        mfu=mfu,
        memory_per_device_bytes=mem_bytes,
    )
