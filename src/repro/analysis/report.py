"""Generate the EXPERIMENTS.md tables from results/dryrun/*.json.

Run:  PYTHONPATH=src python -m repro.analysis.report
Emits markdown to stdout (pasted/regenerated into EXPERIMENTS.md).
"""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

ARCH_ORDER = [
    "hubert-xlarge", "qwen2-vl-72b", "mamba2-2.7b", "granite-moe-1b-a400m",
    "llama4-maverick-400b-a17b", "qwen3-8b", "deepseek-7b",
    "deepseek-coder-33b", "minitron-8b", "hymba-1.5b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = ""):
    out = {}
    sfx = f"@{tag}" if tag else ""
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            f = RESULTS / f"{a}@{s}@{mesh}{sfx}.json"
            if f.exists():
                out[(a, s)] = json.loads(f.read_text())
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    data = load(mesh)
    lines = [
        f"| arch | shape | kind | compile s | bytes/dev GiB | HLO PFLOPs/dev "
        f"| coll GB/dev | dominant collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, s), d in data.items():
        if not d.get("runnable", True):
            lines.append(f"| {a} | {s} | — | — | — | — | — | "
                         f"skipped: {d['skip_reason']} |")
            continue
        r = d["roofline"]
        kinds = sorted(r["collective_by_kind"].items(),
                       key=lambda kv: -kv[1])[:2]
        kstr = ", ".join(f"{k} {v/1e9:.1f}GB" for k, v in kinds)
        lines.append(
            f"| {a} | {s} | {d['kind']} | {d['compile_s']:.1f} "
            f"| {fmt_bytes(d['memory_analysis']['peak_estimate_per_device'])} "
            f"| {r['hlo_flops']/1e15:.3f} "
            f"| {r['collective_bytes']/1e9:.1f} | {kstr} |")
    return "\n".join(lines)


def roofline_table(mesh: str = "16x16", tag: str = "") -> str:
    data = load(mesh, tag)
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| model TFLOP/dev | useful | MFU (max-term) | fit (≤16 GiB)* |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s), d in data.items():
        if not d.get("runnable", True):
            lines.append(f"| {a} | {s} | — | — | — | skipped | — | — | — | — |")
            continue
        r = d["roofline"]
        mem = d["memory_analysis"]["peak_estimate_per_device"]
        fit = "yes" if mem <= 16 * 2**30 else f"NO ({mem/2**30:.0f}G)"
        lines.append(
            f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['bottleneck']}** "
            f"| {r['model_flops']/1e12:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['mfu']:.3f} | {fit} |")
    return "\n".join(lines)


def compare_table(mesh: str = "16x16") -> str:
    """Baseline (paper-faithful planner, *@base) vs final planner."""
    base = load(mesh, "base")
    final = load(mesh)
    lines = [
        "| arch | shape | base bottleneck | base MFU | final bottleneck "
        "| final MFU | step time: base -> final |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in final:
        if key not in base:
            continue
        b, f = base[key], final[key]
        if not f.get("runnable", True) or "roofline" not in f \
                or "roofline" not in b:
            continue
        rb, rf = b["roofline"], f["roofline"]
        lines.append(
            f"| {key[0]} | {key[1]} | {rb['bottleneck']} | {rb['mfu']:.3f} "
            f"| {rf['bottleneck']} | {rf['mfu']:.3f} "
            f"| {rb['step_time_s']*1e3:.1f} -> {rf['step_time_s']*1e3:.1f} ms |")
    return "\n".join(lines)


def main() -> None:
    print("## Dry-run 16x16 (single pod, 256 chips)\n")
    print(dryrun_table("16x16"))
    print("\n## Dry-run 2x16x16 (two pods, 512 chips)\n")
    print(dryrun_table("2x16x16"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table("16x16"))
    print("\n## Baseline (paper-faithful) vs final planner\n")
    print(compare_table("16x16"))


if __name__ == "__main__":
    main()
