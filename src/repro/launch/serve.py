"""Serving launcher: batched requests through the ServeEngine.

Two modes:

* kwargs mode (default) — single-process engine from a hand RunCfg,
  the quick local smoke path.
* plan mode (``--from-plan``) — run the specialization flow for a
  decode shape, build the engine with
  :meth:`ServeEngine.from_plan(mesh=...)`, and serve through whatever
  decode implementation the plan chose (``shard_map_flash`` drives the
  seq-sharded flash-decode end-to-end; no silent XLA fallback when a
  mesh is given).  ``--mesh DxM`` lays the host's devices out as
  (data, model); ``--coordinator`` enables multi-host serving via
  ``jax.distributed.initialize`` (every process runs the same command
  with its own ``--process-id``).
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--from-plan", action="store_true",
                    help="specialize a decode plan and serve via "
                         "ServeEngine.from_plan")
    ap.add_argument("--mesh", default="",
                    help='"DxM" (data, model) mesh over the visible '
                         "devices, e.g. 1x2; implies --from-plan")
    ap.add_argument("--coordinator", default="",
                    help="host:port for jax.distributed.initialize "
                         "(multi-host serving)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--disagg", type=int, default=0, metavar="N",
                    help="run prefill on N supervised worker processes "
                         "(disaggregated prefill/decode; implies "
                         "--from-plan and forces kv_prefill_mode=disagg)")
    args = ap.parse_args()

    import jax

    if args.coordinator:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes, process_id=args.process_id)

    from repro.configs.base import get_arch
    from repro.models import init_params
    from repro.models.lm import RunCfg
    from repro.serve.engine import ServeEngine

    arch = get_arch(args.arch).reduced()
    if args.from_plan or args.mesh or args.disagg:
        from repro.configs import ShapeConfig
        from repro.core.pipeline import specialize

        if args.mesh:
            d, m = (int(x) for x in args.mesh.lower().split("x"))
        else:
            d, m = len(jax.devices()), 1
        mesh = jax.make_mesh((d, m), ("data", "model"))
        shape = ShapeConfig("serve", "decode", args.max_len, args.max_batch)
        plan = specialize(arch, shape, mesh_axes=("data", "model"),
                          mesh_shape=(d, m))
        params = init_params(arch, jax.random.PRNGKey(0),
                             *plan.padded_sizes())
        engine = ServeEngine.from_plan(
            plan, params, arch=arch, mesh=mesh, seed=args.seed,
            kv_prefill_mode="disagg" if args.disagg else None,
            disagg_workers=args.disagg)
        print(f"plan {plan.content_hash()[:12]} decode_impl="
              f"{plan.estimates.get('decode_impl', 'xla')} "
              f"kv_residency={engine.kv_residency} -> engine "
              f"decode_path={engine.decode_path} "
              f"prefill_mode={engine.prefill_mode} on mesh {d}x{m}")
    else:
        params = init_params(arch, jax.random.PRNGKey(0))
        cfg = RunCfg(block_q=32, ssd_chunk=16)
        engine = ServeEngine(arch, params, cfg, max_batch=args.max_batch,
                             max_len=args.max_len, seed=args.seed)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(8, args.max_len - args.new_tokens - 1))
        engine.submit(rng.integers(0, arch.vocab_size, (plen,)),
                      max_new_tokens=args.new_tokens)
    # disagg ticks mostly sleep while workers compile/prefill off-process;
    # give them a far larger budget than inline's deadlock watchdog
    done = engine.run_until_idle(60000 if args.disagg else 1000)
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  rid={r.rid} ttft={(r.t_first-r.t_submit)*1e3:.0f}ms "
              f"total={(r.t_done-r.t_submit)*1e3:.0f}ms "
              f"tokens={r.out_tokens[:8]}...")
    engine.shutdown()


if __name__ == "__main__":
    main()
