"""Serving launcher: batched requests through the ServeEngine."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    from repro.configs.base import get_arch
    from repro.models import init_params
    from repro.models.lm import RunCfg
    from repro.serve.engine import ServeEngine

    arch = get_arch(args.arch).reduced()
    params = init_params(arch, jax.random.PRNGKey(0))
    cfg = RunCfg(block_q=32, ssd_chunk=16)
    engine = ServeEngine(arch, params, cfg, max_batch=args.max_batch,
                         max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(args.requests):
        plen = int(rng.integers(8, args.max_len - args.new_tokens - 1))
        engine.submit(rng.integers(0, arch.vocab_size, (plen,)),
                      max_new_tokens=args.new_tokens)
    done = engine.run_until_idle()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  rid={r.rid} ttft={(r.t_first-r.t_submit)*1e3:.0f}ms "
              f"total={(r.t_done-r.t_submit)*1e3:.0f}ms "
              f"tokens={r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
