import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be invoked as its own process (``python -m repro.launch.dryrun``):
the first two lines above pin 512 placeholder devices BEFORE any jax
initialization.  Nothing here allocates device memory — inputs are
ShapeDtypeStructs, and compile artifacts are analyzed, not executed.

Per cell it records to results/dryrun/<arch>@<shape>@<mesh>.json:
  * memory_analysis()   (bytes-per-device: proves the plan fits HBM)
  * cost_analysis()     (raw XLA FLOPs/bytes)
  * trip-count-corrected FLOPs / HBM bytes / collective bytes
  * the three roofline terms + bottleneck (single-pod mesh)
  * the specialization plan's decision log

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, tag: str = "") -> dict:
    import jax
    from repro.analysis import roofline
    from repro.configs import applicable, get_arch, get_shape
    from repro.core.passes.lowering import lower_step
    from repro.core.pipeline import specialize
    from repro.launch.mesh import make_production_mesh

    arch = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = applicable(arch, shape)
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    out = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_desc,
        "runnable": ok, "skip_reason": why, "timestamp": time.time(),
    }
    if not ok:
        return out

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = specialize(
        arch, shape,
        mesh_axes=tuple(mesh.axis_names),
        mesh_shape=tuple(mesh.devices.shape),
        **(overrides or {}),
    )
    step = lower_step(plan, mesh)
    lowered = step.lower()
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # jax < 0.5 wraps it per-device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()

    rep = roofline.analyze(
        arch=arch, shape=shape, kind=step.kind, hlo_text=hlo,
        n_devices=mesh.devices.size, cost_analysis=ca, memory_stats=mem,
        mesh_desc=mesh_desc, target=plan.target,
    )
    out.update(
        kind=step.kind,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory_analysis={
            "argument_size": mem.argument_size_in_bytes,
            "output_size": mem.output_size_in_bytes,
            "temp_size": mem.temp_size_in_bytes,
            "alias_size": mem.alias_size_in_bytes,
            "peak_estimate_per_device": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        },
        cost_analysis={k: float(v) for k, v in ca.items()
                       if k in ("flops", "bytes accessed")},
        roofline=rep.to_json(),
        plan_log=[list(e) for e in plan.log],
        plan_estimates=dict(plan.estimates),
        plan_opt=dict(plan.opt),
        plan_hash=plan.content_hash(),
        hlo_sizes={"n_lines": hlo.count(chr(10))},
    )
    return out


def cell_path(arch: str, shape: str, mesh_desc: str, tag: str = "") -> Path:
    sfx = f"@{tag}" if tag else ""
    return RESULTS / f"{arch}@{shape}@{mesh_desc}{sfx}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--overrides", default="",
                    help="JSON dict forwarded to specialize() (perf iters)")
    args = ap.parse_args()

    from repro.configs import all_cells  # late import (after XLA_FLAGS)

    RESULTS.mkdir(parents=True, exist_ok=True)
    overrides = json.loads(args.overrides) if args.overrides else None
    mesh_desc = "2x16x16" if args.multi_pod else "16x16"

    if args.all:
        cells = [(a, s) for a, s, ok, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for a, s in cells:
        path = cell_path(a, s, mesh_desc, args.tag)
        if args.skip_done and path.exists():
            print(f"[skip] {a}@{s}@{mesh_desc}")
            continue
        print(f"[cell] {a}@{s}@{mesh_desc} ...", flush=True)
        try:
            out = run_cell(a, s, args.multi_pod, overrides, args.tag)
        except Exception as e:  # noqa: BLE001 - record and continue
            out = {"arch": a, "shape": s, "mesh": mesh_desc,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
            print(f"  FAILED: {e}", flush=True)
        path.write_text(json.dumps(out, indent=2, default=str))
        if "roofline" in out:
            r = out["roofline"]
            print(f"  ok kind={out['kind']} compile={out['compile_s']}s "
                  f"bottleneck={r['bottleneck']} "
                  f"step={r['step_time_s']*1e3:.1f}ms mfu={r['mfu']:.3f} "
                  f"mem/dev={out['memory_analysis']['peak_estimate_per_device']/2**30:.2f}GiB",
                  flush=True)
        elif out.get("skip_reason"):
            print(f"  skipped: {out['skip_reason']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
