"""Training launcher: ``python -m repro.launch.train --arch qwen3-8b ...``

Runs the full flow: specialize (the paper's compilation passes) → lower
("HLS") → train with checkpointing on whatever mesh this process has.
For the production meshes use the dry-run; this launcher runs reduced
configs end-to-end on local devices.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced (CPU-sized) config")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import ShapeConfig, get_arch
    from repro.core.pipeline import specialize
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import Trainer, TrainerConfig

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    shape = ShapeConfig("cli", "train", args.seq_len, args.batch)
    mesh = make_host_mesh(model=args.model_parallel)
    plan = specialize(arch, shape,
                      mesh_axes=tuple(mesh.axis_names),
                      mesh_shape=tuple(mesh.devices.shape))
    print("plan decisions:")
    for entry in plan.log:
        print("  ", " | ".join(entry))
    trainer = Trainer(plan, mesh, TrainerConfig(
        n_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=max(args.steps // 10, 1)),
        arch=arch, shape=shape)
    state, metrics = trainer.fit()
    print("final:", {k: float(v) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
