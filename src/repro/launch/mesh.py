"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: ``model`` is the fast (ICI ring) axis used for tensor/expert
    parallelism; ``data`` carries FSDP + data parallelism; ``pod`` (DCN)
    only ever sees data-parallel gradient traffic (compressed — see
    CommunicationPass).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many devices this host has (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
