"""``repro plan`` CLI: inspect/diff stored FrozenPlan artifacts by hash.

The content-addressed plan store (:mod:`repro.core.planstore`) is the
deployment artifact shelf — this is the shelf's inspection tool::

    python -m repro.launch.plan list [--plan-dir DIR]
    python -m repro.launch.plan show <hash-prefix> [--log]
    python -m repro.launch.plan diff <hash-prefix> <hash-prefix>

``list`` tabulates every entry (hash, arch, shape, workload dims, key
decisions); ``show`` prints one artifact's summary + decision log;
``diff`` compares two artifacts decision-by-decision
(:func:`repro.core.plan.diff_decision_logs`) — the same diff a resumed
trainer prints on a plan-hash mismatch, available offline.  Hashes may
be abbreviated to any unique prefix.  Loads are hash-verified by the
store; corrupt entries are reported, not silently skipped.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.core import planstore
from repro.core.plan import FrozenPlan, diff_decision_logs


def _entries(plan_dir: Path) -> List[Path]:
    return sorted(plan_dir.glob("*.json"))


def _resolve(plan_dir: Path, prefix: str) -> Path:
    hits = [f for f in _entries(plan_dir) if f.stem.startswith(prefix)]
    if not hits:
        raise SystemExit(f"no stored plan matches {prefix!r} "
                         f"in {plan_dir}")
    if len(hits) > 1:
        names = ", ".join(f.stem[:16] for f in hits)
        raise SystemExit(f"ambiguous prefix {prefix!r}: {names}")
    return hits[0]


def _load(store: planstore.PlanStore, path: Path) -> FrozenPlan:
    plan = store.load(path.stem)
    if plan is None:
        raise SystemExit(f"{path.name}: corrupt or hash-mismatched entry")
    return plan


_DECISION_KEYS = ("strategy", "decode_impl", "kv_residency", "kv_block_len",
                  "kv_n_blocks", "moe_impl", "grad_compression")


def _dims(p: FrozenPlan) -> str:
    return (f"{p.shape_kind or '?'} seq={p.seq_len} batch={p.global_batch} "
            f"mesh={'x'.join(str(s) for s in p.mesh_shape)}")


def cmd_list(plan_dir: Path, store: planstore.PlanStore) -> int:
    entries = _entries(plan_dir)
    if not entries:
        print(f"no stored plans in {plan_dir}")
        return 0
    print(f"{len(entries)} plan(s) in {plan_dir}")
    print(f"{'hash':<14} {'arch':<28} {'shape':<14} {'dims':<36} decisions")
    for f in entries:
        plan = store.load(f.stem)
        if plan is None:
            print(f"{f.stem[:12]:<14} <corrupt or stale-schema entry>")
            continue
        dec = ";".join(f"{k}={plan.estimates[k]}" for k in _DECISION_KEYS
                       if k in plan.estimates)
        print(f"{plan.content_hash()[:12]:<14} {plan.arch:<28} "
              f"{plan.shape:<14} {_dims(plan):<36} {dec}")
    return 0


def cmd_show(plan_dir: Path, store: planstore.PlanStore, prefix: str,
             show_log: bool) -> int:
    plan = _load(store, _resolve(plan_dir, prefix))
    print(f"plan {plan.content_hash()}")
    print(f"  arch={plan.arch} shape={plan.shape} target={plan.target}")
    print(f"  workload: {_dims(plan)}")
    print(f"  use_pallas={plan.use_pallas} "
          f"comm={plan.comm.grad_schedule}"
          f"{'+int8_ef' if plan.comm.compresses_gradients else ''} "
          f"remat={plan.comm.remat_policy}")
    dec = {k: plan.estimates[k] for k in _DECISION_KEYS
           if k in plan.estimates}
    if dec:
        print("  decisions: " + json.dumps(dec, default=str))
    print(f"  placements={len(plan.placements)} "
          f"partitions={sorted(plan.partitions)} "
          f"log_entries={len(plan.log)}")
    if show_log:
        for pass_name, subj, decision, why in plan.log:
            print(f"  [{pass_name}] {subj}: {decision}  ({why})")
    return 0


def cmd_diff(plan_dir: Path, store: planstore.PlanStore,
             a_prefix: str, b_prefix: str) -> int:
    a = _load(store, _resolve(plan_dir, a_prefix))
    b = _load(store, _resolve(plan_dir, b_prefix))
    if a.content_hash() == b.content_hash():
        print(f"identical: {a.content_hash()[:12]}")
        return 0
    print(f"--- {a.content_hash()[:12]} ({a.arch}@{a.shape}, {_dims(a)})")
    print(f"+++ {b.content_hash()[:12]} ({b.arch}@{b.shape}, {_dims(b)})")
    lines = diff_decision_logs(a.log, b.log)
    for line in lines:
        print(line)
    if not lines:
        print("(decision logs identical — dims/estimates differ)")
    return 1


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.plan",
        description="inspect/diff stored plan artifacts by content hash")
    ap.add_argument("--plan-dir", default="",
                    help="store directory (default $REPRO_PLAN_DIR or "
                         "~/.cache/repro/plans)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="tabulate stored artifacts")
    p_show = sub.add_parser("show", help="one artifact's summary")
    p_show.add_argument("hash", help="content hash (unique prefix ok)")
    p_show.add_argument("--log", action="store_true",
                        help="also print the full decision log")
    p_diff = sub.add_parser("diff", help="decision-log diff of two artifacts")
    p_diff.add_argument("hash_a")
    p_diff.add_argument("hash_b")
    args = ap.parse_args(argv)

    store = planstore.get_store(args.plan_dir or None)
    plan_dir = store.plan_dir
    if args.cmd == "list":
        return cmd_list(plan_dir, store)
    if args.cmd == "show":
        return cmd_show(plan_dir, store, args.hash, args.log)
    return cmd_diff(plan_dir, store, args.hash_a, args.hash_b)


if __name__ == "__main__":
    sys.exit(main())
