"""``repro plan`` CLI: inspect/diff stored FrozenPlan artifacts by hash.

The content-addressed plan store (:mod:`repro.core.planstore`) is the
deployment artifact shelf — this is the shelf's inspection tool::

    python -m repro.launch.plan list [--plan-dir DIR]
    python -m repro.launch.plan show <hash-prefix> [--log]
    python -m repro.launch.plan diff <hash-prefix> <hash-prefix>
    python -m repro.launch.plan verify
    python -m repro.launch.plan gc [--max-entries N]

``list`` tabulates every entry (hash, arch, shape, workload dims, key
decisions); ``show`` prints one artifact's summary + decision log;
``diff`` compares two artifacts decision-by-decision
(:func:`repro.core.plan.diff_decision_logs`) — the same diff a resumed
trainer prints on a plan-hash mismatch, available offline.  ``verify``
re-hashes every stored artifact and reports corrupt / stale-schema
entries and dangling ``by_key`` refs (exit 1 when any defect is found);
``gc`` runs the store's eviction manually (stale-schema first, then
LRU past the cap).  Hashes may be abbreviated to any unique prefix.
Loads are hash-verified by the store; corrupt entries are reported,
not silently skipped.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.core import planstore
from repro.core.plan import FrozenPlan, diff_decision_logs


def _entries(plan_dir: Path) -> List[Path]:
    return sorted(plan_dir.glob("*.json"))


def _resolve(plan_dir: Path, prefix: str) -> Path:
    hits = [f for f in _entries(plan_dir) if f.stem.startswith(prefix)]
    if not hits:
        raise SystemExit(f"no stored plan matches {prefix!r} "
                         f"in {plan_dir}")
    if len(hits) > 1:
        names = ", ".join(f.stem[:16] for f in hits)
        raise SystemExit(f"ambiguous prefix {prefix!r}: {names}")
    return hits[0]


def _load(store: planstore.PlanStore, path: Path) -> FrozenPlan:
    plan = store.load(path.stem)
    if plan is None:
        raise SystemExit(f"{path.name}: corrupt or hash-mismatched entry")
    return plan


_DECISION_KEYS = ("strategy", "decode_impl", "kv_residency", "kv_block_len",
                  "kv_n_blocks", "kv_admission", "kv_preempt_headroom",
                  "kv_prefix_reuse", "kv_prefix_hit_headroom",
                  "kv_tier_split", "kv_host_blocks", "kv_prefetch",
                  "kv_prefill_mode", "kv_prefill_chunk",
                  "moe_impl", "grad_compress", "grad_compress_lowered",
                  "combine_topology")


def _decisions(plan: FrozenPlan) -> dict:
    """Decision summary, schema-tolerant across artifact generations.

    Plans stored before the multi-tier refactor never recorded a
    ``kv_tier_split`` — their paged pools *were* single-tier, so render
    them as ``hbm-only`` instead of dropping the field (or raising on a
    reader that assumes it exists).  Likewise plans from before the
    disaggregated-prefill split never recorded a ``kv_prefill_mode`` —
    their prefills all ran in-process, so render ``inline``.  Plans from
    before the combine-topology split ran every shard_map decode combine
    as flat psums — render ``flat``; compressed plans from before the
    wire lowering only modeled the cut post-reduce — render
    ``post-reduce``."""
    dec = {k: plan.estimates[k] for k in _DECISION_KEYS
           if k in plan.estimates}
    if dec.get("kv_residency") == "paged":
        if "kv_tier_split" not in dec:
            dec["kv_tier_split"] = "hbm-only"
        if "kv_prefill_mode" not in dec:
            dec["kv_prefill_mode"] = "inline"
    if str(dec.get("decode_impl", "")).startswith("shard_map") \
            and "combine_topology" not in dec:
        dec["combine_topology"] = "flat"
    if dec.get("grad_compress") and "grad_compress_lowered" not in dec:
        dec["grad_compress_lowered"] = "post-reduce"
    return dec


def _dims(p: FrozenPlan) -> str:
    return (f"{p.shape_kind or '?'} seq={p.seq_len} batch={p.global_batch} "
            f"mesh={'x'.join(str(s) for s in p.mesh_shape)}")


def cmd_list(plan_dir: Path, store: planstore.PlanStore) -> int:
    entries = _entries(plan_dir)
    if not entries:
        print(f"no stored plans in {plan_dir}")
        return 0
    print(f"{len(entries)} plan(s) in {plan_dir}")
    print(f"{'hash':<14} {'arch':<28} {'shape':<14} {'dims':<36} decisions")
    for f in entries:
        plan = store.load(f.stem)
        if plan is None:
            print(f"{f.stem[:12]:<14} <corrupt or stale-schema entry>")
            continue
        dec = ";".join(f"{k}={v}" for k, v in _decisions(plan).items())
        print(f"{plan.content_hash()[:12]:<14} {plan.arch:<28} "
              f"{plan.shape:<14} {_dims(plan):<36} {dec}")
    return 0


def cmd_show(plan_dir: Path, store: planstore.PlanStore, prefix: str,
             show_log: bool) -> int:
    plan = _load(store, _resolve(plan_dir, prefix))
    print(f"plan {plan.content_hash()}")
    print(f"  arch={plan.arch} shape={plan.shape} target={plan.target}")
    print(f"  workload: {_dims(plan)}")
    print(f"  use_pallas={plan.use_pallas} "
          f"comm={plan.comm.grad_schedule}"
          f"{'+int8_ef' if plan.comm.compresses_gradients else ''} "
          f"remat={plan.comm.remat_policy}")
    dec = _decisions(plan)
    if dec:
        print("  decisions: " + json.dumps(dec, default=str))
    print(f"  placements={len(plan.placements)} "
          f"partitions={sorted(plan.partitions)} "
          f"log_entries={len(plan.log)}")
    if show_log:
        for pass_name, subj, decision, why in plan.log:
            print(f"  [{pass_name}] {subj}: {decision}  ({why})")
    return 0


def cmd_diff(plan_dir: Path, store: planstore.PlanStore,
             a_prefix: str, b_prefix: str) -> int:
    a = _load(store, _resolve(plan_dir, a_prefix))
    b = _load(store, _resolve(plan_dir, b_prefix))
    if a.content_hash() == b.content_hash():
        print(f"identical: {a.content_hash()[:12]}")
        return 0
    print(f"--- {a.content_hash()[:12]} ({a.arch}@{a.shape}, {_dims(a)})")
    print(f"+++ {b.content_hash()[:12]} ({b.arch}@{b.shape}, {_dims(b)})")
    lines = diff_decision_logs(a.log, b.log)
    for line in lines:
        print(line)
    if not lines:
        print("(decision logs identical — dims/estimates differ)")
    return 1


def cmd_verify(plan_dir: Path, store: planstore.PlanStore) -> int:
    """Re-hash every stored artifact; report anything unservable.

    The health check is :meth:`planstore.PlanStore.verify_entry` — the
    same recipe ``_read_entry`` loads through, so this report can never
    diverge from what the store actually accepts."""
    entries = _entries(plan_dir)
    bad = 0
    for f in entries:
        status = store.verify_entry(f)
        if status != "ok":
            bad += 1
            print(f"{f.stem[:16]:<18} {status}")
    dangling = 0
    by_key = plan_dir / "by_key"
    if by_key.is_dir():
        for ref in sorted(by_key.iterdir()):
            try:
                h = ref.read_text().strip()
            except OSError:
                h = ""
            if not h or not (plan_dir / f"{h}.json").exists():
                dangling += 1
                print(f"by_key/{ref.name[:16]:<10} dangling ref "
                      f"-> {h[:12] or '<empty>'}")
    print(f"{len(entries)} entr{'y' if len(entries) == 1 else 'ies'} "
          f"verified: {len(entries) - bad} ok, {bad} bad, "
          f"{dangling} dangling ref(s)")
    return 1 if bad or dangling else 0


def cmd_gc(store: planstore.PlanStore,
           max_entries: Optional[int]) -> int:
    """Manual eviction: stale-schema entries first, then LRU past the
    cap (the same policy lazy GC applies on over-cap puts)."""
    removed = store.gc(max_entries)
    stats = store.stats()
    print(f"gc removed {removed} entr{'y' if removed == 1 else 'ies'}; "
          f"{stats['disk_size']} left "
          f"({stats['disk_bytes'] / 2**20:.2f} MiB)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.plan",
        description="inspect/diff stored plan artifacts by content hash")
    ap.add_argument("--plan-dir", default="",
                    help="store directory (default $REPRO_PLAN_DIR or "
                         "~/.cache/repro/plans)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="tabulate stored artifacts")
    p_show = sub.add_parser("show", help="one artifact's summary")
    p_show.add_argument("hash", help="content hash (unique prefix ok)")
    p_show.add_argument("--log", action="store_true",
                        help="also print the full decision log")
    p_diff = sub.add_parser("diff", help="decision-log diff of two artifacts")
    p_diff.add_argument("hash_a")
    p_diff.add_argument("hash_b")
    sub.add_parser("verify",
                   help="re-hash every artifact, report corrupt/stale")
    p_gc = sub.add_parser("gc", help="manual eviction (stale-first, LRU)")
    p_gc.add_argument("--max-entries", type=int, default=None,
                      help="entry cap to shrink to (default: store cap)")
    args = ap.parse_args(argv)

    store = planstore.get_store(args.plan_dir or None)
    plan_dir = store.plan_dir
    if args.cmd == "list":
        return cmd_list(plan_dir, store)
    if args.cmd == "show":
        return cmd_show(plan_dir, store, args.hash, args.log)
    if args.cmd == "verify":
        return cmd_verify(plan_dir, store)
    if args.cmd == "gc":
        return cmd_gc(store, args.max_entries)
    return cmd_diff(plan_dir, store, args.hash_a, args.hash_b)


if __name__ == "__main__":
    sys.exit(main())
