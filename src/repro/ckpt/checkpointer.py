"""Fault-tolerant checkpointing: async, sharded, atomic.

Layout (one directory per step):
    <dir>/step_000123.tmp/     — in-flight writes
        shard_<host>.npz       — this host's param/opt shards (flat keys)
        manifest.json          — pytree structure + shapes + plan hash
    <dir>/step_000123/         — atomically renamed when complete
    <dir>/LATEST               — text file with the newest complete step

Guarantees:
* a crash mid-write never corrupts the latest checkpoint (tmp + rename);
* saves run on a background thread (training continues; the next save
  joins the previous one);
* restore validates the manifest against the current plan/arch and
  re-shards onto whatever mesh the restarted job has (elastic restart —
  device counts may differ across restarts).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


class Checkpointer:
    def __init__(self, directory: str | Path, host_id: int = 0,
                 n_hosts: int = 1, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.host_id, self.n_hosts = host_id, n_hosts
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Async save; snapshots to host memory synchronously (so training
        can mutate the donated buffers), writes on a background thread."""
        flat = _flatten(state)
        host = {}
        self._bf16_keys = set()
        for k, v in flat.items():
            a = np.asarray(v)
            if a.dtype == ml_dtypes.bfloat16:
                # npz cannot store bf16: persist the raw bits as uint16
                a = a.view(np.uint16)
                self._bf16_keys.add(k)
            host[k] = a
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray],
               meta: Dict) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"shard_{self.host_id}.npz", **host)
        bf16 = getattr(self, "_bf16_keys", set())
        manifest = {
            "step": step,
            "n_hosts": self.n_hosts,
            "keys": {k: {"shape": list(v.shape),
                         "dtype": "bfloat16" if k in bf16 else str(v.dtype)}
                     for k, v in host.items()},
            "meta": meta,
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # barrier point in multi-host: host 0 renames once all shards exist
        if self.host_id == 0:
            deadline = time.time() + 300
            while len(list(tmp.glob("shard_*.npz"))) < self.n_hosts:
                if time.time() > deadline:
                    raise TimeoutError("checkpoint shards missing")
                time.sleep(0.05)
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            (self.dir / "LATEST").write_text(str(step))
            self._gc()

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def manifest(self, step: int) -> Dict[str, Any]:
        """The manifest of one complete checkpoint (empty dict if absent)."""
        f = self.dir / f"step_{step:08d}" / "manifest.json"
        try:
            return json.loads(f.read_text())
        except (OSError, ValueError):
            return {}

    def plan_hash(self, step: Optional[int] = None) -> str:
        """The plan content hash stamped on a checkpoint ("" if unstamped).

        The hash identifies the frozen plan artifact the step function
        was lowered from; a restart resolves it against the plan store
        (``<ckpt_dir>/plans``) to skip the specialization flow entirely.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            return ""
        return str(self.manifest(step).get("meta", {}).get("plan_hash", ""))

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if not f.exists():
            return None
        step = int(f.read_text().strip())
        if not (self.dir / f"step_{step:08d}" / "manifest.json").exists():
            return None
        return step

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load state; re-shards onto the current mesh if shardings given
        (elastic restart: the device count may have changed)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat: Dict[str, Any] = {}
        keys = manifest["keys"]
        with np.load(d / f"shard_{self.host_id}.npz") as z:
            for k in z.files:
                a = z[k]
                if keys.get(k, {}).get("dtype") == "bfloat16":
                    a = a.view(ml_dtypes.bfloat16)
                flat[k] = a
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                for k, v in _flatten(tree).items()
            })
        return tree, manifest

    def validate(self, step: int) -> bool:
        d = self.dir / f"step_{step:08d}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
            with np.load(d / f"shard_{self.host_id}.npz") as z:
                return set(z.files) == set(manifest["keys"])
        except Exception:
            return False
