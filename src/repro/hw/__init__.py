from repro.hw.tpu import TpuTarget, get_target, KiB, MiB, GiB

__all__ = ["TpuTarget", "get_target", "KiB", "MiB", "GiB"]
