"""TPU hardware target specification.

The paper sizes *physical* memories for an FPGA; on TPU the memories are
fixed, so the target spec is the set of capacities/bandwidths the passes
budget against.  All roofline math in :mod:`repro.analysis.roofline` reads
these numbers, so there is a single source of truth for the hardware model.

Numbers for the default target (TPU v5e) follow the task specification:
197 TFLOP/s bf16 per chip, 819 GB/s HBM bandwidth, ~50 GB/s per ICI link.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB


@dataclasses.dataclass(frozen=True)
class TpuTarget:
    """Capability/capacity model of one TPU chip + its interconnect."""

    name: str = "tpu-v5e"
    # --- compute ---
    peak_bf16_flops: float = 197e12  # FLOP/s per chip (MXU, bf16)
    peak_f32_flops: float = 98.5e12  # ~half rate for fp32 accumulate paths
    mxu_dim: int = 128               # systolic array edge -> matmul tile quantum
    vpu_lanes: Tuple[int, int] = (8, 128)  # (sublane, lane) tiling quantum

    # --- memories (the "template components" with fixed size on TPU) ---
    hbm_bytes: int = 16 * GiB
    hbm_bw: float = 819e9            # bytes/s
    vmem_bytes: int = 64 * MiB       # usable VMEM planning budget per core
    smem_bytes: int = 1 * MiB        # scalar memory (for scalar prefetch)

    # --- interconnect ("channels" in the paper's template) ---
    ici_link_bw: float = 50e9        # bytes/s per ICI link, per direction
    ici_links_per_chip: int = 4      # 2D torus on v5e: 4 links
    dcn_bw: float = 6.25e9           # bytes/s per host NIC (pod axis, 50 Gb/s)

    # --- host tier (the DRAM behind the PCIe attach) ---
    # The serving engine can spill cold KV blocks to pinned host memory
    # and stream them back ahead of their decode tick; these two numbers
    # size that tier.  ``host_bytes_per_chip`` is each chip's share of
    # the host's DRAM (a v5e host serves 8 chips), ``pcie_bw`` the
    # per-chip host<->HBM DMA bandwidth the stream-back must fit in.
    pcie_bw: float = 16e9            # bytes/s per chip (PCIe Gen3 x16 class)
    host_bytes_per_chip: int = 48 * GiB

    # --- derived helpers -------------------------------------------------
    def matmul_time(self, flops: float, dtype_bytes: int = 2) -> float:
        peak = self.peak_bf16_flops if dtype_bytes <= 2 else self.peak_f32_flops
        return flops / peak

    def hbm_time(self, nbytes: float) -> float:
        return nbytes / self.hbm_bw

    def ici_time(self, nbytes: float) -> float:
        """Time to move nbytes across one ICI link."""
        return nbytes / self.ici_link_bw

    def pcie_time(self, nbytes: float) -> float:
        """Time to stream nbytes between host DRAM and HBM."""
        return nbytes / self.pcie_bw

    def align_up(self, n: int, q: int | None = None) -> int:
        q = q or self.mxu_dim
        return ((n + q - 1) // q) * q

    def vmem_fit(self, *tile_bytes: int, buffers: int = 2) -> bool:
        """Does a working set (with ``buffers``-way banking) fit in VMEM?

        ``buffers=2`` models the double-buffered pipeline (the paper's
        multi-bank PLM: one bank is filled by DMA while the other is read
        by the datapath).
        """
        return buffers * sum(tile_bytes) <= self.vmem_bytes


# Registry so configs can say ``target="tpu-v5e"``.
_TARGETS = {
    "tpu-v5e": TpuTarget(),
    "tpu-v5p": TpuTarget(
        name="tpu-v5p",
        peak_bf16_flops=459e12,
        peak_f32_flops=229.5e12,
        hbm_bytes=95 * GiB,
        hbm_bw=2765e9,
        ici_link_bw=100e9,
        ici_links_per_chip=6,  # 3D torus
        vmem_bytes=128 * MiB,
        pcie_bw=32e9,
        host_bytes_per_chip=96 * GiB,
    ),
}


def get_target(name: str = "tpu-v5e") -> TpuTarget:
    try:
        return _TARGETS[name]
    except KeyError as e:
        raise KeyError(f"unknown TPU target {name!r}; have {sorted(_TARGETS)}") from e
