"""The domain-specific memory template (paper §3).

A reusable graph of memory primitives that the compilation flow
*specializes* per application: components can be parameterized or removed
("if the data resides entirely on-chip, the prefetcher can be removed; if
there is only a single memory, the multi-channel controller can be
simplified").

On TPU the primitives map to (see DESIGN.md §2):

==================  =====================================================
paper component     TPU analogue parameterized by the passes
==================  =====================================================
PLM (multi-bank)    Pallas VMEM tiles: block shapes × n buffers
cache               KV-cache (serving) with residency management
DMA engine          pallas_call HBM→VMEM pipeline / async collectives
prefetcher          pipeline lookahead + host data-pipeline prefetch depth
multi-channel ctrl  mesh axes: ICI ("data","model") + DCN ("pod") channels
special functions   layout transforms fused by the layout pass
==================  =====================================================
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional

from repro.hw.tpu import TpuTarget, get_target


class ComponentKind(enum.Enum):
    PLM = "plm"
    CACHE = "cache"
    DMA = "dma"
    PREFETCHER = "prefetcher"
    CHANNEL = "channel"
    SPECIAL = "special"


@dataclasses.dataclass
class Component:
    """One template component; passes set ``params`` or ``enabled=False``."""

    name: str
    kind: ComponentKind
    enabled: bool = True
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # Which pass last touched it — the provenance trail the paper's
    # progressive-refinement story needs.
    refined_by: List[str] = dataclasses.field(default_factory=list)

    def refine(self, pass_name: str, **params: Any) -> None:
        self.params.update(params)
        self.refined_by.append(pass_name)

    def remove(self, pass_name: str, reason: str) -> None:
        self.enabled = False
        self.params["removed_reason"] = reason
        self.refined_by.append(pass_name)


@dataclasses.dataclass
class MemoryTemplate:
    """The generic (un-specialized) template: paper Figure 1, lower half."""

    target: TpuTarget
    components: Dict[str, Component] = dataclasses.field(default_factory=dict)

    @classmethod
    def default(cls, target: str | TpuTarget = "tpu-v5e") -> "MemoryTemplate":
        tgt = target if isinstance(target, TpuTarget) else get_target(target)
        t = cls(target=tgt)
        add = lambda n, k: t.components.__setitem__(n, Component(n, k))
        add("plm.attention", ComponentKind.PLM)       # attention VMEM tiles
        add("plm.matmul", ComponentKind.PLM)          # matmul VMEM tiles
        add("plm.scan", ComponentKind.PLM)            # SSD scan VMEM tiles
        add("cache.kv", ComponentKind.CACHE)          # serving KV cache
        add("dma.hbm", ComponentKind.DMA)             # HBM<->VMEM pipeline
        add("prefetch.grid", ComponentKind.PREFETCHER)  # pallas lookahead
        add("prefetch.host", ComponentKind.PREFETCHER)  # input pipeline depth
        add("channel.ici", ComponentKind.CHANNEL)     # intra-pod collectives
        add("channel.dcn", ComponentKind.CHANNEL)     # pod axis collectives
        add("special.layout", ComponentKind.SPECIAL)  # fused transposes/padding
        add("special.compress", ComponentKind.SPECIAL)  # grad compression
        return t

    def __getitem__(self, name: str) -> Component:
        return self.components[name]

    def enabled(self) -> List[str]:
        return sorted(n for n, c in self.components.items() if c.enabled)

    def summary(self) -> Dict[str, Any]:
        return {
            "target": self.target.name,
            "components": {
                n: {
                    "kind": c.kind.value,
                    "enabled": c.enabled,
                    "params": c.params,
                    "refined_by": c.refined_by,
                }
                for n, c in sorted(self.components.items())
            },
        }
