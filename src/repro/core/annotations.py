"""Domain-specific annotations (paper §1: "use domain-specific annotations
to pass useful information to the compiler").

Model code does not build :class:`~repro.core.ir.TensorDecl` objects by
hand; it calls the helpers below, which encode the *domain knowledge* of
LM workloads (weights are broadcast-read + high reuse, activations are
streamed, KV caches are session-lived + random-read at decode, ...).

These are the same defaults a designer would attach with ``#pragma``-style
annotations in the paper's C/MLIR flow.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.core.ir import (
    AccessPattern,
    Lifetime,
    Reuse,
    Role,
    TensorDecl,
)


def weight(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "bfloat16",
    expert: bool = False,
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.EXPERT_PARAM if expert else Role.PARAM,
        logical_axes=axes,
        access=AccessPattern.BROADCAST,
        reuse=Reuse.HIGH,
        lifetime=Lifetime.PERSISTENT,
        annotations=ann,
    )


def activation(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "bfloat16",
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.ACTIVATION,
        logical_axes=axes,
        access=AccessPattern.SEQUENTIAL,
        reuse=Reuse.NONE,
        lifetime=Lifetime.EPHEMERAL,
        annotations=ann,
    )


def model_input(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "int32",
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.INPUT,
        logical_axes=axes,
        access=AccessPattern.SEQUENTIAL,
        reuse=Reuse.NONE,
        lifetime=Lifetime.STEP,
        annotations=ann,
    )


def kv_cache(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "bfloat16",
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.KV_CACHE,
        logical_axes=axes,
        # decode reads the whole cache every step: streamed, high reuse
        access=AccessPattern.SEQUENTIAL,
        reuse=Reuse.HIGH,
        lifetime=Lifetime.SESSION,
        annotations=ann,
    )


def ssm_state(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "float32",
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.SSM_STATE,
        logical_axes=axes,
        access=AccessPattern.SEQUENTIAL,
        reuse=Reuse.HIGH,
        lifetime=Lifetime.SESSION,
        annotations=ann,
    )


def opt_state(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "float32",
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.OPT_STATE,
        logical_axes=axes,
        access=AccessPattern.SEQUENTIAL,
        reuse=Reuse.LOW,
        lifetime=Lifetime.PERSISTENT,
        annotations=ann,
    )


def gradient(
    name: str,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: str = "bfloat16",
    **ann: Any,
) -> TensorDecl:
    return TensorDecl(
        name=name,
        shape=shape,
        dtype=dtype,
        role=Role.GRAD,
        logical_axes=axes,
        access=AccessPattern.REDUCTION,
        reuse=Reuse.LOW,
        lifetime=Lifetime.STEP,
        annotations=ann,
    )
