"""PassPipeline — the multi-level compilation flow (paper Figure 1).

``specialize()`` is the public entry point: it builds the Memory IR for an
(arch × shape), instantiates the generic template, runs the passes in the
paper's order, and returns the fully-refined :class:`MemoryPlan`.

The final phase — lowering to an executable step ("HLS" in the paper) —
lives in :mod:`repro.core.passes.lowering` and consumes only the plan.
"""

from __future__ import annotations

import copy
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple, Type

from repro.configs.base import ArchConfig, ShapeConfig, get_arch, get_shape
from repro.core.costmodel import MeshModel
from repro.core.describe import describe_program
from repro.core.ir import ProgramIR
from repro.core.passes import DEFAULT_PASSES, Pass, PassContext
from repro.core.plan import MemoryPlan
from repro.core.template import MemoryTemplate


class PassPipeline:
    def __init__(self, passes: Sequence[Type[Pass]] = DEFAULT_PASSES):
        self.passes = [p() for p in passes]

    def run(self, ctx: PassContext) -> MemoryPlan:
        for p in self.passes:
            p.run(ctx)
            ctx.ir.phase = p.name
        ctx.plan.template_summary = ctx.template.summary()
        return ctx.plan


# ---------------------------------------------------------------------
# plan cache: the flow is deterministic in (arch, shape, mesh, target,
# passes, options), so repeated callers (benchmarks, serve engine,
# trainer restarts) can skip redundant pipeline runs.  Entries and hits
# are deep-copied: returned plans are caller-owned and mutation-safe.
# ---------------------------------------------------------------------

_PLAN_CACHE: Dict[Any, MemoryPlan] = {}
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _PLAN_CACHE_STATS.update(hits=0, misses=0)


def plan_cache_stats() -> Dict[str, int]:
    return {**_PLAN_CACHE_STATS, "size": len(_PLAN_CACHE)}


def specialize(
    arch: str | ArchConfig,
    shape: str | ShapeConfig,
    mesh_axes: Tuple[str, ...] = ("data", "model"),
    mesh_shape: Tuple[int, ...] = (16, 16),
    target: str = "tpu-v5e",
    passes: Optional[Sequence[Type[Pass]]] = None,
    use_pallas: str = "auto",
    cache: bool = True,
    **options,
) -> MemoryPlan:
    """Run the full specialization flow; returns the MemoryPlan.

    Memoized on the full argument tuple (``cache=False`` bypasses both
    lookup and insertion — e.g. when benchmarking the flow itself).
    """
    arch_cfg = get_arch(arch) if isinstance(arch, str) else arch
    shape_cfg = get_shape(shape) if isinstance(shape, str) else shape
    key = None
    if cache:
        key = (arch_cfg, shape_cfg, tuple(mesh_axes), tuple(mesh_shape),
               target, None if passes is None else tuple(passes), use_pallas,
               tuple(sorted((k, repr(v)) for k, v in options.items())))
        hit = _PLAN_CACHE.get(key)
        if hit is not None:
            _PLAN_CACHE_STATS["hits"] += 1
            return copy.deepcopy(hit)
        _PLAN_CACHE_STATS["misses"] += 1
    ir = describe_program(arch_cfg, shape_cfg)
    mesh = MeshModel(axes=tuple(mesh_axes), shape=tuple(mesh_shape))
    template = MemoryTemplate.default(target)
    plan = MemoryPlan(
        arch=arch_cfg.name,
        shape=shape_cfg.name,
        mesh_axes=tuple(mesh_axes),
        mesh_shape=tuple(mesh_shape),
        target=target,
        use_pallas=use_pallas,
    )
    ctx = PassContext(arch=arch_cfg, shape=shape_cfg, ir=ir, mesh=mesh,
                      template=template, plan=plan, options=dict(options))
    pipeline = PassPipeline(passes if passes is not None else DEFAULT_PASSES)
    result = pipeline.run(ctx)
    if key is not None:
        _PLAN_CACHE[key] = copy.deepcopy(result)
    return result
