"""PassPipeline — the multi-level compilation flow (paper Figure 1).

``specialize()`` is the public entry point: it builds the Memory IR for an
(arch × shape), instantiates the generic template, runs the passes in the
paper's order, and returns the fully-refined :class:`MemoryPlan`.

The final phase — lowering to an executable step ("HLS" in the paper) —
lives in :mod:`repro.core.passes.lowering` and consumes only the plan.
"""

from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple, Type

from repro.configs.base import ArchConfig, ShapeConfig, get_arch, get_shape
import repro.core.planstore as planstore
from repro.core.costmodel import MeshModel
from repro.core.describe import describe_program
from repro.core.ir import ProgramIR
from repro.core.passes import DEFAULT_PASSES, Pass, PassContext
from repro.core.plan import FrozenPlan, MemoryPlan
from repro.core.template import MemoryTemplate


class PassPipeline:
    def __init__(self, passes: Sequence[Type[Pass]] = DEFAULT_PASSES):
        self.passes = [p() for p in passes]

    def run(self, ctx: PassContext) -> MemoryPlan:
        for p in self.passes:
            p.run(ctx)
            ctx.ir.phase = p.name
        ctx.plan.template_summary = ctx.template.summary()
        return ctx.plan


# ---------------------------------------------------------------------
# plan store: the flow is deterministic in (arch, shape, mesh, target,
# passes, options), so repeated callers (benchmarks, serve engine,
# trainer restarts) skip redundant pipeline runs.  Hits return the
# *same immutable FrozenPlan object* — zero-copy, O(1) — backed by the
# content-addressed on-disk store (repro.core.planstore) that survives
# process restarts.
# ---------------------------------------------------------------------


def clear_plan_cache(disk: bool = False) -> None:
    """Drop the memory tier of every store this process created
    (including ``plan_dir=`` overrides), optionally the disk entries of
    the default store too."""
    for store in planstore.all_stores():
        store.clear(disk=False)
    if disk:
        planstore.get_store().clear(disk=True)


def plan_cache_stats() -> Dict[str, int]:
    """Counters of the *default* store (``$REPRO_PLAN_DIR`` or
    ``~/.cache/repro/plans``); ``plan_dir=`` stores keep their own —
    read them via ``planstore.get_store(plan_dir).stats()``."""
    return planstore.get_store().stats()


_FLOW_FINGERPRINT: Optional[str] = None


def _flow_fingerprint() -> str:
    """Hash of the compiler's own source files.

    The disk tier outlives the process, so the request key must change
    when the *decision logic* changes — not just the serialized layout
    (which PLAN_SCHEMA_VERSION covers).  Hashing the pass/cost-model
    sources makes any edit a clean cache miss instead of silently
    serving plans compiled by older code.
    """
    global _FLOW_FINGERPRINT
    if _FLOW_FINGERPRINT is None:
        import hashlib
        import repro.core.passes as passes_pkg
        import repro.hw as hw_pkg
        h = hashlib.sha256()
        # passes/ + core/*.py + hw/*.py: the hardware tables (VMEM/HBM
        # budgets, bandwidths) feed the same decisions the passes make
        roots = [Path(passes_pkg.__file__).parent,
                 Path(__file__).parent,
                 Path(hw_pkg.__file__).parent]
        files: list = []
        for root in roots:
            files.extend(root.glob("*.py"))
        for f in sorted(set(files)):
            h.update(f.name.encode())
            h.update(f.read_bytes())
        _FLOW_FINGERPRINT = h.hexdigest()
    return _FLOW_FINGERPRINT


def _request_key(arch_cfg, shape_cfg, mesh_axes, mesh_shape, target,
                 passes, use_pallas, options) -> str:
    pass_names = None if passes is None else tuple(
        f"{p.__module__}.{p.__qualname__}" for p in passes)
    return planstore.request_key(
        _flow_fingerprint(),
        arch_cfg, shape_cfg, tuple(mesh_axes), tuple(mesh_shape), target,
        pass_names, use_pallas,
        tuple(sorted((k, repr(v)) for k, v in options.items())))


def specialize(
    arch: str | ArchConfig,
    shape: str | ShapeConfig,
    mesh_axes: Tuple[str, ...] = ("data", "model"),
    mesh_shape: Tuple[int, ...] = (16, 16),
    target: str = "tpu-v5e",
    passes: Optional[Sequence[Type[Pass]]] = None,
    use_pallas: str = "auto",
    cache: bool = True,
    plan_dir: Optional[str | Path] = None,
    **options,
) -> FrozenPlan:
    """Run the full specialization flow; returns the frozen plan artifact.

    Memoized on the full argument tuple through the two-tier
    :class:`~repro.core.planstore.PlanStore`: warm in-memory hits return
    the same immutable object (no deepcopy); cold processes reload the
    persisted artifact from ``plan_dir`` (default ``$REPRO_PLAN_DIR`` or
    ``~/.cache/repro/plans``).  ``cache=False`` bypasses both lookup and
    insertion — e.g. when benchmarking the flow itself.
    """
    arch_cfg = get_arch(arch) if isinstance(arch, str) else arch
    shape_cfg = get_shape(shape) if isinstance(shape, str) else shape
    store = key = None
    if cache:
        store = planstore.get_store(plan_dir)
        key = _request_key(arch_cfg, shape_cfg, mesh_axes, mesh_shape,
                           target, passes, use_pallas, options)
        hit = store.get(key)
        if hit is not None:
            return hit
    ir = describe_program(arch_cfg, shape_cfg)
    mesh = MeshModel(axes=tuple(mesh_axes), shape=tuple(mesh_shape))
    template = MemoryTemplate.default(target)
    plan = MemoryPlan(
        arch=arch_cfg.name,
        shape=shape_cfg.name,
        mesh_axes=tuple(mesh_axes),
        mesh_shape=tuple(mesh_shape),
        target=target,
        shape_kind=shape_cfg.kind,
        seq_len=shape_cfg.seq_len,
        global_batch=shape_cfg.global_batch,
        use_pallas=use_pallas,
    )
    ctx = PassContext(arch=arch_cfg, shape=shape_cfg, ir=ir, mesh=mesh,
                      template=template, plan=plan, options=dict(options))
    pipeline = PassPipeline(passes if passes is not None else DEFAULT_PASSES)
    result = pipeline.run(ctx).freeze()
    if store is not None:
        store.put(key, result)
    return result
