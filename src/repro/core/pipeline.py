"""PassPipeline — the multi-level compilation flow (paper Figure 1).

``specialize()`` is the public entry point: it builds the Memory IR for an
(arch × shape), instantiates the generic template, runs the passes in the
paper's order, and returns the fully-refined :class:`MemoryPlan`.

The final phase — lowering to an executable step ("HLS" in the paper) —
lives in :mod:`repro.core.passes.lowering` and consumes only the plan.
"""

from __future__ import annotations

from typing import Iterable, Optional, Sequence, Tuple, Type

from repro.configs.base import ArchConfig, ShapeConfig, get_arch, get_shape
from repro.core.costmodel import MeshModel
from repro.core.describe import describe_program
from repro.core.ir import ProgramIR
from repro.core.passes import DEFAULT_PASSES, Pass, PassContext
from repro.core.plan import MemoryPlan
from repro.core.template import MemoryTemplate


class PassPipeline:
    def __init__(self, passes: Sequence[Type[Pass]] = DEFAULT_PASSES):
        self.passes = [p() for p in passes]

    def run(self, ctx: PassContext) -> MemoryPlan:
        for p in self.passes:
            p.run(ctx)
            ctx.ir.phase = p.name
        ctx.plan.template_summary = ctx.template.summary()
        return ctx.plan


def specialize(
    arch: str | ArchConfig,
    shape: str | ShapeConfig,
    mesh_axes: Tuple[str, ...] = ("data", "model"),
    mesh_shape: Tuple[int, ...] = (16, 16),
    target: str = "tpu-v5e",
    passes: Optional[Sequence[Type[Pass]]] = None,
    use_pallas: str = "auto",
    **options,
) -> MemoryPlan:
    """Run the full specialization flow; returns the MemoryPlan."""
    arch_cfg = get_arch(arch) if isinstance(arch, str) else arch
    shape_cfg = get_shape(shape) if isinstance(shape, str) else shape
    ir = describe_program(arch_cfg, shape_cfg)
    mesh = MeshModel(axes=tuple(mesh_axes), shape=tuple(mesh_shape))
    template = MemoryTemplate.default(target)
    plan = MemoryPlan(
        arch=arch_cfg.name,
        shape=shape_cfg.name,
        mesh_axes=tuple(mesh_axes),
        mesh_shape=tuple(mesh_shape),
        target=target,
        use_pallas=use_pallas,
    )
    ctx = PassContext(arch=arch_cfg, shape=shape_cfg, ir=ir, mesh=mesh,
                      template=template, plan=plan, options=dict(options))
    pipeline = PassPipeline(passes if passes is not None else DEFAULT_PASSES)
    return pipeline.run(ctx)
