"""Napkin-math cost model used by the passes.

The paper's passes size memories and configure prefetchers from static
analysis of the IR; this module is that analysis.  Everything here is a
*model* (no execution): bytes per chip under a sharding, minimum HBM
traffic of a step, collective volumes for a given schedule, VMEM fit of a
tile configuration.  The roofline report in
:mod:`repro.analysis.roofline` cross-checks these numbers against the
compiled artifact (`cost_analysis()` + HLO collective parse).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core.ir import ProgramIR, Role, TensorDecl
from repro.hw.tpu import TpuTarget


@dataclasses.dataclass(frozen=True)
class MeshModel:
    """Static view of the device mesh (no jax imports — usable pre-init)."""

    axes: Tuple[str, ...]
    shape: Tuple[int, ...]

    @property
    def n_devices(self) -> int:
        return int(math.prod(self.shape))

    def axis_size(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        return self.shape[self.axes.index(name)]


def shard_factor(
    decl: TensorDecl,
    axis_map: Mapping[str, Optional[str]],
    mesh: MeshModel,
) -> int:
    """Total number of shards a tensor is split into under an axis mapping."""
    f = 1
    seen = set()
    for logical in decl.logical_axes:
        if logical is None:
            continue
        mesh_axes = axis_map.get(logical)
        if mesh_axes is None:
            continue
        names = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        for m in names:
            if m in seen:  # a mesh axis can only shard one dim
                continue
            seen.add(m)
            f *= mesh.axis_size(m)
    return f


def bytes_per_device(
    decl: TensorDecl,
    axis_map: Mapping[str, Optional[str]],
    mesh: MeshModel,
) -> int:
    return decl.nbytes // shard_factor(decl, axis_map, mesh)


def program_bytes_per_device(
    ir: ProgramIR,
    axis_map: Mapping[str, Optional[str]],
    mesh: MeshModel,
    roles: Sequence[Role] = (Role.PARAM, Role.EXPERT_PARAM, Role.OPT_STATE),
) -> int:
    return sum(
        bytes_per_device(t, axis_map, mesh) for t in ir.by_role(*roles)
    )


# ---------------------------------------------------------------------------
# Collective volume models (communication pass + roofline cross-check)
# ---------------------------------------------------------------------------

def allreduce_bytes(nbytes: int, n: int) -> float:
    """Per-device bytes moved by a ring all-reduce of an nbytes buffer."""
    if n <= 1:
        return 0.0
    return 2.0 * nbytes * (n - 1) / n


def reduce_scatter_bytes(nbytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    return nbytes * (n - 1) / n


def allgather_bytes(nbytes: int, n: int) -> float:
    """nbytes = size of the *gathered* (full) buffer."""
    if n <= 1:
        return 0.0
    return nbytes * (n - 1) / n


def all_to_all_bytes(nbytes_local: int, n: int) -> float:
    if n <= 1:
        return 0.0
    return nbytes_local * (n - 1) / n


def compressed_ratio(bits: int = 8, dtype_bytes: int = 2,
                     block: int = 128) -> float:
    """Wire-volume ratio of block-quantized vs raw gradient collectives.

    int8 codes plus one f32 scale per ``block`` values: for bf16 grads
    (the IR's gradient dtype) int8 halves the volume; for f32 it is ~4x.
    Matches :func:`repro.dist.collectives.quantize_int8`'s layout.
    """
    return (bits / 8.0 + 4.0 / block) / dtype_bytes


@dataclasses.dataclass
class StepCost:
    """Three-term roofline estimate for one step on one device."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_overlap(self) -> float:
        """Perfect-overlap model: max of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_time_serial(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s


def estimate_step(
    ir: ProgramIR,
    axis_map: Mapping[str, Optional[str]],
    mesh: MeshModel,
    target: TpuTarget,
    training: bool = True,
    grad_schedule: str = "reduce_scatter",
    dp_axes: Sequence[str] = ("data",),
    grad_bits: Optional[int] = None,
) -> StepCost:
    """Static three-term estimate of one train/serve step.

    Used by the communication pass to choose between candidate schedules
    *before* lowering (the paper's passes make decisions from the IR, not
    from profiles).
    """
    n_dev = mesh.n_devices
    fwd_flops = ir.total_flops()
    flops = fwd_flops * (3.0 if training else 1.0)  # fwd + 2x bwd
    compute_s = flops / n_dev / target.peak_bf16_flops

    # Minimum HBM traffic: every persistent byte read once, activations
    # read+written once (very coarse; the compiled artifact refines this).
    persist = program_bytes_per_device(ir, axis_map, mesh)
    act = sum(
        bytes_per_device(t, axis_map, mesh)
        for t in ir.by_role(Role.ACTIVATION, Role.INPUT, Role.KV_CACHE,
                            Role.SSM_STATE)
    )
    mem_bytes = persist * (3 if training else 1) + 2 * act
    memory_s = mem_bytes / target.hbm_bw

    # Collectives: data-parallel grad reduction over dp axes (training),
    # TP activation collectives folded into a fudge on activations.
    coll_bytes = 0.0
    if training:
        grad_bytes = sum(
            bytes_per_device(t, axis_map, mesh)
            for t in ir.by_role(Role.PARAM, Role.EXPERT_PARAM)
        )
        dp = 1
        for a in dp_axes:
            if a in mesh.axes:
                dp *= mesh.axis_size(a)
        if grad_schedule == "all_reduce":
            coll_bytes += allreduce_bytes(grad_bytes, dp)
        else:
            coll_bytes += reduce_scatter_bytes(grad_bytes, dp) + allgather_bytes(
                grad_bytes, dp
            )
        if grad_bits:  # int8(+scales) compression of the grad reduction
            coll_bytes *= compressed_ratio(grad_bits)
    collective_s = coll_bytes / target.ici_link_bw

    return StepCost(compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s)


# ---------------------------------------------------------------------------
# KV residency model (data-organization pass, serving shapes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVBlockGeometry:
    """Plan-chosen block-pool geometry for the paged KV template.

    The serving KV cache is the template's biggest memory consumer; the
    data-organization pass sizes it like any other memory: a block pool
    of ``n_blocks`` blocks of ``block_len`` cache rows each, shared by
    all layers (one block id indexes every layer's pool), with a per-slot
    block table mapping sequence positions to blocks.
    """

    block_len: int                 # cache rows per block
    blocks_per_seq: int            # ceil(seq_len / block_len)
    n_blocks: int                  # pool capacity (global, all sub-pools)
    dense_bytes: int               # B x seq_len stripe footprint (k+v, all layers)
    paged_bytes: int               # pool footprint at this capacity
    data_degree: int = 1           # sub-pools the block dim splits into
    model_degree: int = 1          # model shards per sub-pool
    admission: str = "reserve"     # "reserve" (worst-case up front) | "grant"
    headroom_blocks: int = 0       # per-sub-pool free blocks past one max seq
    prefix_reuse: str = "on"       # cross-request prefix KV sharing
    # assumed shared-prefix fraction of serving traffic the reuse model
    # is evaluated at (system prompts + session history dominate
    # production feeds; 0.5 is the model's deliberately conservative
    # default — the engine reports the *measured* rate at runtime)
    assumed_hit_rate: float = 0.5

    @property
    def table_cols(self) -> int:
        return self.blocks_per_seq

    @property
    def sub_pool_blocks(self) -> int:
        """Blocks each data shard's sub-pool owns (2-D pool sharding:
        the block dim is split data-major into ``data_degree`` sub-pools,
        each serving the batch slots that data shard hosts)."""
        return self.n_blocks // max(1, self.data_degree)

    def prefix_capacity_factor(self, residents: int,
                               hit_rate: Optional[float] = None) -> float:
        """Effective capacity multiplier of prefix sharing: with
        ``residents`` concurrent sequences each sharing a ``hit_rate``
        fraction of their blocks, the shared run is pinned once instead
        of ``residents`` times — ``r / (h + r*(1-h))``, approaching
        ``1/(1-h)`` as residency grows.  1.0 when reuse is off."""
        if self.prefix_reuse != "on" or residents <= 1:
            return 1.0
        h = self.assumed_hit_rate if hit_rate is None else hit_rate
        h = min(max(h, 0.0), 1.0)
        return residents / (h + residents * (1.0 - h))

    def prefix_hit_headroom(self, residents: int,
                            hit_rate: Optional[float] = None) -> int:
        """Expected per-sub-pool blocks *freed* by sharing at the
        assumed hit rate: every resident past the first aliases the
        shared-prefix blocks instead of pinning private copies —
        ``(residents - 1) * floor(h * blocks_per_seq)``, capped at the
        sub-pool.  This is headroom the admission ladder gets back
        before it ever migrates or preempts."""
        if self.prefix_reuse != "on" or residents <= 1:
            return 0
        h = self.assumed_hit_rate if hit_rate is None else hit_rate
        shared = int(min(max(h, 0.0), 1.0) * self.blocks_per_seq)
        return min((residents - 1) * shared, self.sub_pool_blocks)


def kv_block_len(seq_len: int, min_block: int = 16,
                 max_block: int = 512) -> int:
    """Block length for a ``seq_len``-deep cache: the largest power of
    two in [min_block, max_block] that still leaves >= 8 blocks per
    sequence (reclamation granularity), floored at ``min_block``.

    Powers of two keep the in-block offset a cheap mask and the block
    row count a multiple of the TPU sublane tile.
    """
    bl = min_block
    while bl * 2 <= max_block and bl * 2 * 8 <= seq_len:
        bl *= 2
    return bl


def kv_block_geometry(
    seq_len: int,
    batch: int,
    n_layers: int,
    kv_heads: int,
    head_dim: int,
    dtype_bytes: int = 2,
    budget_bytes: Optional[float] = None,
    data_shards: int = 1,
    align: int = 1,
) -> KVBlockGeometry:
    """Choose the paged-pool geometry for a decode workload.

    2-D pool sharding: the block dim is split data-major into
    ``data_shards`` sub-pools (one per data shard, serving the batch
    slots that shard hosts) and each sub-pool shards over the ``align``
    model-axis degree — so unlike the pre-2-D pool the capacity
    *shards* over the data axis instead of replicating there.
    ``data_shards`` still divides the worst-case capacity: the pool
    covers ``1/data_shards`` of the all-slots-at-max footprint, which
    is the reclamation bet — churn keeps the sub-pools fed — and what
    puts per-chip paged bytes *below* the dense stripes it replaces.
    A ``budget_bytes`` cap (the *global* HBM left for the cache across
    every chip the pool spans) shrinks it further — never below one
    full sequence per sub-pool, the minimum each data shard's slots
    need to make progress.  Every sub-pool is rounded to an ``align``
    multiple: a non-divisible sub-pool would silently *replicate* per
    model shard instead, blowing the very budget this sizing validated.

    The geometry also fixes the **admission mode** the serving engine
    must run: when the pool covers every slot's worst case
    (``n_blocks >= batch * blocks_per_seq``) admission can safely
    ``reserve`` the full budget up front — grants never fail, no
    preemption machinery ever engages.  When the pool is *smaller* than
    worst case (the 1/data_shards reclamation bet, or a budget cap),
    worst-case reservation would refuse requests the pool can in fact
    serve — so admission must be ``grant`` (grow-on-demand per block
    boundary) with preemption as the backstop.  ``headroom_blocks``
    records the per-sub-pool slack past one maximum sequence — the
    cost model's estimate of how much concurrent growth a sub-pool
    absorbs before the engine starts walking the migrate/preempt
    ladder (0 means any second resident sequence rides entirely on
    reclamation).
    """
    bl = kv_block_len(seq_len)
    per_seq = -(-seq_len // bl)
    want = max(1, batch) * per_seq
    block_bytes = 2 * n_layers * bl * kv_heads * head_dim * dtype_bytes
    d = max(1, data_shards)
    n = max(per_seq, want // d)
    if budget_bytes is not None and block_bytes > 0:
        cap = int(budget_bytes // block_bytes)
        n = max(per_seq, min(n, cap))
    # per-sub-pool floor + alignment: each data shard owns n/d blocks,
    # shardable by the model axis and >= one full sequence (rounding the
    # floor UP when needed — slightly over budget beats a pool that
    # silently replicates per model shard)
    sub = n // d
    if align > 1:
        sub = align * (sub // align)
    sub = max(sub, align * (-(-per_seq // align)) if align > 1 else per_seq)
    n = d * sub
    return KVBlockGeometry(
        admission="reserve" if n >= want else "grant",
        headroom_blocks=max(0, sub - per_seq),
        block_len=bl,
        blocks_per_seq=per_seq,
        n_blocks=n,
        dense_bytes=2 * n_layers * max(1, batch) * seq_len
        * kv_heads * head_dim * dtype_bytes,
        paged_bytes=n * block_bytes,
        data_degree=d,
        model_degree=max(1, align),
    )


@dataclasses.dataclass(frozen=True)
class KVTierSplit:
    """Two-tier residency split for the paged KV pool.

    The paper's template is *multi-level*: a specialized memory is not
    one pool but a hierarchy sized per tier.  For the serving KV cache
    the tiers are the HBM block pool (the :class:`KVBlockGeometry` the
    pass already sized from HBM headroom) plus a **host-DRAM spill
    pool** behind it, sized here from the host pin budget.  Cold blocks
    (parked sessions, evicted prefix-trie tails) move to the host tier
    and stream back over PCIe ahead of their decode tick.

    ``prefetch_feasible`` is the stream-back-latency check: a decoding
    slot crosses a block boundary once every ``block_len`` ticks, so a
    one-block-lookahead prefetch hides the PCIe transfer exactly when
    one block streams in less than ``lookahead_ticks`` decode ticks.
    Infeasible does not disable the tier — parked sessions still resume
    from host — it means a resume may stall a tick on the transfer.
    """

    hbm_blocks: int                # HBM pool capacity (== geometry n_blocks)
    host_blocks: int               # host spill pool capacity (0 = hbm-only)
    block_bytes: int               # one block, k+v, all layers
    pcie_bw: float                 # host<->HBM stream bandwidth (bytes/s)
    decode_tick_s: float           # modeled steady-state decode tick
    lookahead_ticks: int           # ticks between one slot's boundary crossings

    @property
    def stream_block_s(self) -> float:
        """PCIe time to move one block (k+v rows, every layer)."""
        if self.pcie_bw <= 0:
            return float("inf")
        return self.block_bytes / self.pcie_bw

    @property
    def prefetch_feasible(self) -> bool:
        return self.stream_block_s <= self.lookahead_ticks * self.decode_tick_s

    @property
    def host_bytes(self) -> int:
        return self.host_blocks * self.block_bytes

    @property
    def tier_name(self) -> str:
        return "hbm+host" if self.host_blocks else "hbm-only"


def kv_tier_split(
    geo: KVBlockGeometry,
    host_budget_bytes: float,
    pcie_bw: float,
    decode_tick_s: float,
    max_park_factor: int = 8,
) -> KVTierSplit:
    """Size the host-DRAM spill tier behind an already-sized HBM pool.

    ``geo`` carries the HBM side of the split (sized from HBM headroom
    by :func:`kv_block_geometry`); this sizes the host side from the
    pin budget (the host DRAM the deployment may pin for DMA), capped
    at ``max_park_factor`` times the HBM pool — parking depth beyond a
    few full pools buys nothing but pinned pages the OS cannot reclaim.
    A host pool too small to park even one full sequence is reported as
    0 (hbm-only): spilling a session you can never fully park only
    fragments the tier.
    """
    block_bytes = geo.paged_bytes // max(1, geo.n_blocks)
    host = 0
    if block_bytes > 0 and host_budget_bytes > 0:
        host = int(host_budget_bytes // block_bytes)
        host = min(host, max_park_factor * geo.n_blocks)
    if host < geo.blocks_per_seq:
        host = 0
    return KVTierSplit(
        hbm_blocks=geo.n_blocks,
        host_blocks=host,
        block_bytes=block_bytes,
        pcie_bw=pcie_bw,
        decode_tick_s=decode_tick_s,
        lookahead_ticks=geo.block_len,
    )


@dataclasses.dataclass(frozen=True)
class KVPrefillSplit:
    """Inline-vs-disaggregated prefill decision for the serve engine.

    The paper's flow specializes one memory template per *role*; prefill
    and decode are different roles with opposite profiles — prefill is a
    flops-bound burst over the whole prompt, decode a bandwidth-bound
    tick over one token.  Run inline, a worst-case prompt's prefill
    steals ``stall_ticks`` consecutive decode ticks from every other
    slot (head-of-line blocking).  Past a few ticks of stall the plan
    flips to ``disagg``: prefill moves to supervised worker processes
    that stream ``chunk_len``-sized pool-block-shaped KV chunks back to
    the decode engine (``serve/disagg.py``), and decode never waits.
    """

    prefill_flops: float           # worst-case full-prompt prefill, one chip
    peak_flops: float              # chip peak (bf16)
    decode_tick_s: float           # modeled steady-state decode tick
    chunk_len: int                 # disagg streaming granule (== block_len)
    threshold_ticks: float = 8.0   # stall tolerated before flipping

    @property
    def prefill_s(self) -> float:
        if self.peak_flops <= 0:
            return 0.0
        return self.prefill_flops / self.peak_flops

    @property
    def stall_ticks(self) -> float:
        """Decode ticks an inline worst-case prefill steals in one gulp."""
        if self.decode_tick_s <= 0:
            return 0.0
        return self.prefill_s / self.decode_tick_s

    @property
    def mode(self) -> str:
        return "disagg" if self.stall_ticks > self.threshold_ticks \
            else "inline"


def kv_prefill_split(
    seq_len: int,
    persistent_bytes: float,
    peak_flops: float,
    decode_tick_s: float,
    chunk_len: int,
    threshold_ticks: float = 8.0,
) -> KVPrefillSplit:
    """Decide inline vs disaggregated prefill from the interference model.

    The forward cost of one prefill token is ~2 flops per resident
    parameter; with bf16 params ``persistent_bytes`` *is* the per-chip
    flops/token (2 flops x bytes/2 params), so the worst-case prompt
    (the shape's full ``seq_len``) costs ``seq_len * persistent_bytes``
    flops on each chip — tensor parallelism scales both sides of the
    ratio identically.  Compare that burst against the decode tick the
    tier split already modeled: more than ``threshold_ticks`` ticks of
    head-of-line stall flips the plan to ``disagg`` with ``chunk_len``
    (the pool block length) as the streaming granule, so every shipped
    chunk is exactly one pool block.
    """
    return KVPrefillSplit(
        prefill_flops=float(seq_len) * max(0.0, persistent_bytes),
        peak_flops=peak_flops,
        decode_tick_s=decode_tick_s,
        chunk_len=chunk_len,
        threshold_ticks=threshold_ticks,
    )


# ---------------------------------------------------------------------------
# decode combine topology (communication pass)
# ---------------------------------------------------------------------------

#: legal values of ``comm.combine_topology`` (and the kernels' ``combine=``)
COMBINE_TOPOLOGIES = ("flat", "ring", "bidir")

#: flat < ring < bidir — the chosen topology's rank is monotone
#: nondecreasing in the model degree (the property tests pin this)
COMBINE_TOPOLOGY_RANK = {"flat": 0, "ring": 1, "bidir": 2}

#: calibrated crossover degrees.  These are thresholds, not derivations:
#: all three latency chains below are linear in n, and two lines cross
#: exactly once — a linear model alone can never produce the observed
#: flat -> ring -> bidir progression.  What the chains miss is that XLA
#: fuses the flat combine's three tiny collectives into one launch at
#: small n (so flat wins there despite the worse chain), while past
#: ~one ring's worth of hops the fused launch stops amortizing and the
#: explicit rings win on chain length.  The degrees encode where those
#: regimes flip on the reference ICI mesh.
COMBINE_RING_DEGREE = 8          # flat while model degree <= this
COMBINE_BIDIR_DEGREE = 16       # ring while model degree <= this


def choose_combine_topology(model_degree: int) -> str:
    """Pick the model-axis softmax-combine topology for a decode step.

    A degenerate model axis (degree <= 1) has no cross-shard combine at
    all — "flat" by definition, whatever the overrides say.  Otherwise
    the calibrated thresholds above apply.
    """
    n = int(model_degree)
    if n <= COMBINE_RING_DEGREE:
        return "flat"
    if n <= COMBINE_BIDIR_DEGREE:
        return "ring"
    return "bidir"


def combine_hops(model_degree: int, topology: str) -> int:
    """Latency-chain length (dependent neighbor hops) of one combine.

    * ``flat``  — pmax + two psums, each a 2(n-1)-hop ring all-reduce:
      ``6(n-1)`` chained hops before fusion.
    * ``ring``  — one packed (m, l, acc) all-gather around the ring:
      ``n-1`` hops.
    * ``bidir`` — the same gather split across both ring directions:
      ``ceil((n-1)/2)`` hops on the longer arm.

    Hop *count* is the narrative number the decision log reports; the
    crossovers themselves are the calibrated degrees above.
    """
    n = int(model_degree)
    if n <= 1:
        return 0
    if topology == "flat":
        return 6 * (n - 1)
    if topology == "ring":
        return n - 1
    if topology == "bidir":
        return (n - 1 + 1) // 2
    raise ValueError(f"unknown combine topology {topology!r}; "
                     f"expected one of {COMBINE_TOPOLOGIES}")


# ---------------------------------------------------------------------------
# VMEM tiling model (local partitioning pass)
# ---------------------------------------------------------------------------

def tile_bytes(shape: Sequence[int], dtype_bytes: int = 2) -> int:
    return int(math.prod(shape)) * dtype_bytes


def attention_tile_bytes(
    block_q: int, block_kv: int, head_dim: int, dtype_bytes: int = 2
) -> int:
    """VMEM working set of one flash-attention grid step (per head)."""
    q = block_q * head_dim
    k = block_kv * head_dim
    v = block_kv * head_dim
    s = block_q * block_kv          # scores tile (fp32) — count at 4B
    o = block_q * head_dim
    acc = block_q * (head_dim + 2)  # running max / denom
    return (q + k + v + o) * dtype_bytes + (s + acc) * 4


def matmul_tile_bytes(bm: int, bk: int, bn: int, dtype_bytes: int = 2) -> int:
    return (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4  # fp32 acc
