"""Layout pass (paper §4, second level).

Paper: "reorganizes the computation to better exploit local memories."

TPU re-targeting: physical layout choices that make the MXU/VPU (and the
collectives) see well-shaped data:

* pad matmul-visible dims (vocab above all) to MXU multiples × TP width;
* fuse the QKV projection into one matmul when head counts allow it;
* pick the KV-cache layout (seq-major for append-heavy decode);
* assign compute dtypes (bf16 streams, fp32 softmax/router/logits).

These are the paper's "special functions" (e.g. transposition) folded
into the plan instead of bolted onto a datapath.
"""

from __future__ import annotations

import math

from repro.core.ir import Role
from repro.core.passes import Pass, PassContext


def pad_up(n: int, q: int) -> int:
    return ((n + q - 1) // q) * q


class LayoutPass(Pass):
    name = "layout"

    def run(self, ctx: PassContext) -> None:
        plan, arch, mesh = ctx.plan, ctx.arch, ctx.mesh
        tgt = ctx.target
        tp = mesh.axis_size("model")

        # ---- vocab padding (embed table + lm head + logits) -------------
        quantum = tgt.mxu_dim * tp if arch.vocab_size >= tgt.mxu_dim * tp \
            else tgt.vpu_lanes[1]
        vpad = pad_up(arch.vocab_size, quantum)
        plan.estimates["vocab_padded"] = float(vpad)
        if vpad != arch.vocab_size:
            for name in ("embed", "lm_head"):
                if name in plan.placements:
                    p = plan.placements[name]
                    p.layout["vocab_padded"] = vpad
                    p.decided_by.append(self.name)
            self.record(ctx, "vocab", f"{arch.vocab_size} -> {vpad}",
                        f"pad to mxu({tgt.mxu_dim}) x TP({tp}) so the logits "
                        "matmul tiles cleanly and shards evenly")

        # ---- QKV projection layout ---------------------------------------
        tp_heads = plan.axis_rules.get("heads") == "model"
        if arch.has_attention and not tp_heads:
            # fsdp_dp strategy: heads unsharded -> no padding, no constraint
            plan.estimates["heads_padded"] = float(arch.n_heads)
            plan.estimates["kv_heads_padded"] = float(arch.n_kv_heads)
            plan.estimates["kv_heads_sharded"] = 1.0
            self.record(ctx, "heads", "unsharded (fsdp_dp)",
                        "batch carries the model axis; head dims stay whole")
        if arch.has_attention and tp_heads:
            # split projections: fused-QKV section boundaries almost never
            # align with TP shard boundaries -> GSPMD collective-permute halos
            plan.estimates["fuse_qkv"] = 0.0
            self.record(ctx, "qkv", "split",
                        "fused QKV split points land mid-shard under "
                        f"TP={tp}; split projections shard cleanly")

            # pad head counts to make the (tokens, H, hd) reshape
            # GSPMD-expressible: Hp % TP == 0 (sharding) AND Hp % Kp == 0
            # (GQA grouping).  Joint search over (Hp, Kp) minimizes the
            # padding waste — e.g. hymba 25q/5kv -> 32q/8kv (1.28x) instead
            # of 80q/5kv (3.2x).
            H, K = arch.n_heads, arch.n_kv_heads
            best = None
            for Kp in range(K, 4 * K + 1):
                m = math.lcm(tp, Kp) if H % tp else Kp
                Hp = pad_up(H, m)
                if Hp % Kp == 0 and (best is None or Hp < best[0]
                                     or (Hp == best[0] and Kp < best[1])):
                    best = (Hp, Kp)
            Hp, Kp = best
            plan.estimates["heads_padded"] = float(Hp)
            plan.estimates["kv_heads_padded"] = float(Kp)
            if (Hp, Kp) != (H, K):
                self.record(ctx, "heads", f"q {H}->{Hp}, kv {K}->{Kp}",
                            f"head counts not TP({tp})/GQA-expressible: pad "
                            "with dead (zero-init) heads; +"
                            f"{100*(Hp-H)/H:.0f}% attention FLOPs beats "
                            "replicated attention (the useful-FLOP ratio in "
                            "§Roofline accounts for the waste)")
            # kv heads: shard when divisible, else replicate the (small)
            # k/v activations across the model axis
            kv_sharded = Kp % tp == 0
            plan.estimates["kv_heads_sharded"] = float(kv_sharded)
            if not kv_sharded:
                self.record(ctx, "kv_heads", "replicated over model axis",
                            f"{Kp} kv heads < TP={tp}: replicating k/v "
                            "activations (B,S,K,hd is small) avoids "
                            "inexpressible shardings; the KV cache shards "
                            "its head_dim instead (data_organization)")

        # ---- SSM head padding ---------------------------------------------
        if arch.has_ssm and plan.axis_rules.get("ssm_inner") != "model":
            plan.estimates["ssm_heads_padded"] = float(arch.ssm_heads)
        elif arch.has_ssm:
            Hs = arch.ssm_heads
            Hsp = pad_up(Hs, tp) if Hs % tp else Hs
            plan.estimates["ssm_heads_padded"] = float(Hsp)
            if Hsp != Hs:
                self.record(ctx, "ssm_heads", f"{Hs} -> {Hsp}",
                            f"d_inner/head reshape not TP({tp})-expressible "
                            "otherwise; padded heads are dead at init")

        # ---- KV cache layout ----------------------------------------------
        for t in ctx.ir.by_role(Role.KV_CACHE):
            p = plan.placements[t.name]
            p.layout["order"] = "seq_major"   # (L, 2, B, S, K, hd)
            p.layout["append"] = "dynamic_update_slice"
            p.decided_by.append(self.name)
            self.record(ctx, t.name, "seq-major",
                        "decode appends one token/step: seq-major makes the "
                        "append a contiguous DMA and the decode read a stream")

        # ---- dtype assignments -------------------------------------------
        for t in ctx.ir.by_role(Role.PARAM, Role.EXPERT_PARAM):
            plan.placements[t.name].dtype = arch.dtype
        for t in ctx.ir.by_role(Role.OPT_STATE):
            plan.placements[t.name].dtype = "float32"
        plan.estimates["softmax_dtype_f32"] = 1.0
        self.record(ctx, "dtypes", "bf16 streams, fp32 softmax/router/adam",
                    "MXU-native bf16; numerically-sensitive reductions in fp32")

        # ---- MXU alignment notes for projections --------------------------
        for t in ctx.ir.by_role(Role.PARAM, Role.EXPERT_PARAM):
            last = t.shape[-1]
            if last % tgt.mxu_dim != 0:
                p = plan.placements[t.name]
                p.pad_to = tuple(
                    pad_up(s, tgt.mxu_dim) if i == len(t.shape) - 1 else s
                    for i, s in enumerate(t.shape)
                )
                p.decided_by.append(self.name)
