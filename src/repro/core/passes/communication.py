"""Communication pass (paper §4, third level).

Paper: "the prefetcher is configured to hide transfer latency based on
the data access patterns."

TPU re-targeting — everything that moves bytes between memories/chips:

* gradient reduction schedule (all-reduce vs reduce-scatter+all-gather),
  chosen from the static cost model;
* gradient compression on the *slow channel* (the DCN "pod" axis) with
  int8 + error feedback — the template's ``special.compress`` function;
* microbatching (grad accumulation) so collectives overlap compute;
* host input-pipeline prefetch depth (the literal prefetcher);
* remat policy — recompute-vs-refetch is a transfer-hiding decision too:
  it trades HBM traffic for FLOPs when activations overflow the budget.
"""

from __future__ import annotations

from repro.core.costmodel import compressed_ratio, estimate_step
from repro.core.ir import Role
from repro.core.passes import Pass, PassContext


class CommunicationPass(Pass):
    name = "communication"

    act_budget_frac: float = 0.25     # activations may use this HBM share

    def run(self, ctx: PassContext) -> None:
        plan, mesh, tgt = ctx.plan, ctx.mesh, ctx.target
        comm = plan.comm
        training = ctx.training
        axis_map = plan.axis_rules

        if training:
            # ---- grad schedule: model both, pick the cheaper -------------
            ar = estimate_step(ctx.ir, axis_map, mesh, tgt, training=True,
                               grad_schedule="all_reduce")
            rs = estimate_step(ctx.ir, axis_map, mesh, tgt, training=True,
                               grad_schedule="reduce_scatter")
            fsdp = any("fsdp" in d for p in plan.placements.values()
                       for d in p.decided_by)
            if fsdp:
                comm.grad_schedule = "reduce_scatter"
                why = "FSDP shards params: reduce-scatter matches shard layout"
            else:
                comm.grad_schedule = (
                    "reduce_scatter" if rs.collective_s <= ar.collective_s
                    else "all_reduce")
                why = (f"modelled collective time rs={rs.collective_s*1e3:.2f}ms "
                       f"vs ar={ar.collective_s*1e3:.2f}ms")
            self.record(ctx, "grad_schedule", comm.grad_schedule, why)

            # ---- slow-channel compression --------------------------------
            if "pod" in mesh.axes and mesh.axis_size("pod") > 1:
                comm.compress_pod_grads = True
                comm.compress_bits = 8
                ctx.template["special.compress"].refine(
                    self.name, bits=8, axis="pod", error_feedback=True)
                self.record(ctx, "pod_grads", "int8 + error feedback",
                            f"DCN bw {tgt.dcn_bw/1e9:.1f} GB/s << ICI "
                            f"{tgt.ici_link_bw/1e9:.0f} GB/s: 4x volume cut "
                            "on the slow channel dominates the quantization "
                            "noise (error feedback keeps it unbiased)")
            else:
                ctx.template["special.compress"].remove(
                    self.name, "single-pod mesh: ICI fast enough")

            # ---- ICI-wide compressed reduction (collective-bound steps) ---
            # The paper's "technology requirements" knob as a measurable
            # perf decision: when the modeled step is bound by the gradient
            # collective, switch the whole DP reduction (not just the pod
            # channel) to int8 + error feedback and book the volume cut.
            raw = rs if comm.grad_schedule == "reduce_scatter" else ar
            ratio = compressed_ratio(bits=8)
            plan.estimates["est_collective_s_raw"] = raw.collective_s
            plan.estimates["est_collective_s_int8"] = raw.collective_s * ratio
            collective_bound = raw.collective_s > 0 and \
                raw.collective_s >= max(raw.compute_s, raw.memory_s)
            forced_gc = ctx.options.get("grad_compression")
            if forced_gc is not None:
                collective_bound = forced_gc == "on"
            if collective_bound:
                comm.compress_grads = True
                comm.compress_bits = 8
                comp = ctx.template["special.compress"]
                comp.enabled = True           # may have been removed above
                comp.params.pop("removed_reason", None)
                comp.refine(
                    self.name, bits=8, axis="+".join(self._dp_axes(ctx)),
                    error_feedback=True)
                self.record(
                    ctx, "grad_compression", "int8 + error feedback (ICI)",
                    "forced by options" if forced_gc is not None else
                    f"step is collective-bound "
                    f"(coll {raw.collective_s*1e3:.2f}ms >= compute "
                    f"{raw.compute_s*1e3:.2f}ms, mem {raw.memory_s*1e3:.2f}ms"
                    f"): int8 codes + per-128 scales cut the reduction to "
                    f"{ratio:.2f}x = {raw.collective_s*ratio*1e3:.2f}ms; "
                    "error feedback keeps it unbiased over steps")
            else:
                self.record(
                    ctx, "grad_compression", "off",
                    "forced by options" if forced_gc is not None else
                    f"step not collective-bound (coll "
                    f"{raw.collective_s*1e3:.2f}ms < max(compute "
                    f"{raw.compute_s*1e3:.2f}ms, mem {raw.memory_s*1e3:.2f}"
                    "ms)): full-precision reduction overlaps for free; "
                    "compression would only add quantization noise")
            plan.estimates["grad_compress"] = float(comm.compress_grads)

            # ---- lowering verdict: do codes actually cross the wire? ------
            # The modeled volume cut only becomes real if the train step
            # can replace its f32 reduction with the int16 code sum; the
            # shared wire_compression predicate decides, and the artifact
            # records the verdict so `plan show` never claims a cut the
            # lowered step does not deliver.
            if comm.compress_grads:
                from repro.core.passes.lowering import wire_compression
                dp = wire_compression(plan, None, ctx.arch)
                comm.compress_lowered = dp > 0
                if dp:
                    # key presence == lowered: gate-refused plans render
                    # through the same "post-reduce" fallback as
                    # artifacts stored before the wire lowering existed
                    plan.estimates["grad_compress_lowered"] = float(dp)
                    self.record(
                        ctx, "grad_compress_lowering",
                        f"int16 code sum on the wire (dp={dp})",
                        "vmap-sliced grads quantize against a shared scale "
                        "and the per-slice int8 codes sum across the data "
                        f"axes in int16 ({dp} * 127 = {dp * 127} <= 32767): "
                        "the step's only gradient-sized cross-data "
                        "collective runs in integer dtype")
                else:
                    self.record(
                        ctx, "grad_compress_lowering", "post-reduce EF",
                        "wire gate failed (FSDP shard layout, batch not "
                        "divisible by dp x microbatches, dp > 256, or "
                        "shard_map MoE dispatch): EF still models the "
                        "compression but the reduction stays full-precision")

            # ---- microbatching: activation budget + comm overlap ----------
            est = estimate_step(ctx.ir, axis_map, mesh, tgt, training=True,
                                grad_schedule=comm.grad_schedule,
                                grad_bits=8 if comm.compress_grads else None)
            budget = self.act_budget_frac * tgt.hbm_bytes
            # hard floor on saved memory: the per-layer scan carry
            # (L x tokens_local x d_model, bf16) cannot be rematted away
            carry = self._carry_bytes(ctx, microbatches=1)
            nmicro = 1
            dp = self._dp(ctx)
            batch_local = max(ctx.shape.global_batch // dp, 1)
            while carry / nmicro > budget and nmicro < batch_local:
                nmicro *= 2
            if est.collective_s > 0.25 * est.compute_s and nmicro < 2:
                nmicro = min(2, batch_local)
                self.record(ctx, "microbatches", str(nmicro),
                            f"collective {est.collective_s*1e3:.1f}ms vs "
                            f"compute {est.compute_s*1e3:.1f}ms: pipeline grad "
                            "reduction behind the next microbatch's backward")
            comm.microbatches = nmicro
            if nmicro > 1:
                self.record(
                    ctx, "microbatches", str(nmicro),
                    f"layer-carry activations {carry/2**30:.1f} GiB/chip vs "
                    f"budget {budget/2**30:.1f} GiB -> split the step into "
                    f"{nmicro} microbatches ({carry/nmicro/2**30:.1f} GiB each)")
            plan.estimates.update(
                est_compute_s=est.compute_s, est_memory_s=est.memory_s,
                est_collective_s=est.collective_s,
                carry_bytes_per_dev=carry / nmicro)

            # ---- remat policy ---------------------------------------------
            act_bytes = self._activation_bytes(ctx)
            if carry / nmicro + act_bytes / nmicro > budget:
                comm.remat_policy = "full"
                self.record(ctx, "remat", "full",
                            f"intra-layer activations {act_bytes/nmicro/2**30:.1f}"
                            f" GiB/chip on top of carries "
                            f"{carry/nmicro/2**30:.1f} GiB exceed budget "
                            f"{budget/2**30:.1f} GiB: save only layer inputs, "
                            "recompute the block in backward")
            elif act_bytes > budget or nmicro > 1:
                comm.remat_policy = "dots_saveable"
                self.record(ctx, "remat", "dots_saveable",
                            f"activations {act_bytes/2**30:.1f} GiB/chip "
                            f"(budget {budget/2**30:.1f} GiB, {nmicro} micro): "
                            "recompute element-wise ops, keep matmul outputs")
            else:
                comm.remat_policy = "none"
                self.record(ctx, "remat", "none",
                            f"activations {act_bytes/2**30:.2f} GiB/chip fit")
        else:
            comm.grad_schedule = "none"
            comm.remat_policy = "none"

        # ---- prefetcher (host pipeline + pallas lookahead) ---------------
        comm.prefetch_depth = 2 if ctx.shape.kind != "decode" else 4
        ctx.template["prefetch.host"].refine(self.name, depth=comm.prefetch_depth)
        ctx.template["prefetch.grid"].refine(self.name, lookahead=1)
        if ctx.shape.kind == "decode" and not ctx.arch.has_attention:
            # all state on-chip & constant-size: the paper's removal rule
            ctx.template["prefetch.host"].remove(
                self.name, "decode with on-chip constant state only")
        self.record(ctx, "prefetch_depth", str(comm.prefetch_depth),
                    "hide host->HBM latency behind step compute")

        # ---- decode combine topology -------------------------------------
        # The flash-decode softmax combine crosses the model axis every
        # tick; its wire pattern (flat psums vs a packed ring gather) is
        # a per-mesh-geometry choice the plan records like kv_residency,
        # so every consumer (kernels, engine, benchmarks) dispatches the
        # same way.
        if ctx.shape.kind == "decode" and ctx.arch.has_attention:
            from repro.core.costmodel import (choose_combine_topology,
                                              combine_hops)
            msize = mesh.axis_size("model") if "model" in mesh.axes else 1
            forced_ct = ctx.options.get("combine_topology")
            if msize <= 1:
                topo = "flat"
                why = "model degree 1: no cross-shard combine exists"
            elif forced_ct is not None:
                topo = forced_ct
                why = "forced by options"
            else:
                topo = choose_combine_topology(msize)
                hops = {t: combine_hops(msize, t)
                        for t in ("flat", "ring", "bidir")}
                why = (f"model degree {msize}: latency chains flat="
                       f"{hops['flat']} hops (3 collectives XLA fuses at "
                       f"small degrees), ring={hops['ring']}, bidir="
                       f"{hops['bidir']} -> {topo} at the calibrated "
                       "crossover degrees (8/16)")
            comm.combine_topology = topo
            plan.estimates["combine_topology"] = topo
            plan.estimates["combine_hops"] = float(combine_hops(msize, topo))
            self.record(ctx, "combine_topology", topo, why)

        # ---- channel configuration ---------------------------------------
        ctx.template["channel.ici"].refine(
            self.name, axes=[a for a in mesh.axes if a != "pod"],
            collectives=comm.grad_schedule)
        if "pod" in mesh.axes:
            ctx.template["channel.dcn"].refine(
                self.name, axes=["pod"],
                compressed=comm.compress_pod_grads)
        else:
            ctx.template["channel.dcn"].remove(self.name, "single-pod mesh")

        # ---- MoE execution strategy ---------------------------------------
        if ctx.arch.is_moe:
            a = ctx.arch
            ff = a.moe_d_ff or a.d_ff
            k, cf, E = a.experts_per_token, a.capacity_factor, a.n_experts
            # per-token-per-d FLOPs:
            #   dense:    every expert's FFN                6*E*ff
            #   dispatch: routed FFN 6*k*ff + two one-hot dispatch/combine
            #             matmuls at 4*k*cf*T_group (quadratic in the
            #             routing group size!)
            t_group = ctx.shape.seq_len     # route() groups per sequence
            dense_cost = 6.0 * E * ff
            disp_cost = 6.0 * k * ff + 4.0 * k * cf * t_group
            impl = ("dense_einsum" if dense_cost <= disp_cost
                    else "gshard_einsum")
            plan.estimates["moe_impl"] = impl
            self.record(
                ctx, "moe_impl", impl,
                f"per-token-per-layer cost model: dense={dense_cost/1e3:.1f}k "
                f"d-flops vs dispatch={disp_cost/1e3:.1f}k — "
                + ("all-expert dense execution beats the one-hot "
                   "dispatch matmuls (and drops the (T,E,C) tensors + "
                   "all-to-all entirely)" if impl == "dense_einsum" else
                   "capacity dispatch is cheaper at this expert count"))

        comm.donate_state = True
        comm.overlap_collectives = True

    # ------------------------------------------------------------------
    def _dp_axes(self, ctx: PassContext) -> tuple:
        """Mesh axes the batch rule actually uses (the DP reduction set)."""
        assign = ctx.plan.axis_rules.get("batch", "data")
        names = (assign,) if isinstance(assign, str) else tuple(assign)
        return tuple(n for n in names if n in ctx.mesh.axes)

    def _dp(self, ctx: PassContext) -> int:
        """Data-parallel width from the batch axis rule."""
        dp = 1
        for n in self._dp_axes(ctx):
            dp *= ctx.mesh.axis_size(n)
        return max(dp, 1)

    def _carry_bytes(self, ctx: PassContext, microbatches: int = 1) -> float:
        """Per-layer scan-carry saves: L x tokens_local x d_model, bf16."""
        arch, shape = ctx.arch, ctx.shape
        tokens_local = shape.tokens / self._dp(ctx) / max(microbatches, 1)
        return arch.n_layers * tokens_local * arch.d_model * 2

    def _activation_bytes(self, ctx: PassContext) -> float:
        """Live activations per chip for one (micro)batch, no remat."""
        arch, shape, mesh = ctx.arch, ctx.shape, ctx.mesh
        tokens_local = shape.tokens / self._dp(ctx) / \
            max(ctx.plan.comm.microbatches, 1)
        # residual + attn in/out + ffn hidden per layer, bf16
        width = arch.d_model * 3 + (arch.d_ff or arch.d_inner)
        tp = mesh.axis_size("model") \
            if ctx.plan.axis_rules.get("ff") == "model" else 1
        per_layer = tokens_local * width * 2 / tp
        if arch.has_ssm:
            # SSD intra-chunk quadratic tensors (L-matrix, scores, decay):
            # ~(tokens/chunk) x H x chunk x chunk f32 each
            chunk = 256
            per_layer += 3 * tokens_local * chunk * arch.ssm_heads * 4
        if arch.is_moe:
            # GShard dispatch/combine one-hots + expert slot activations:
            # tokens x E x C x (bf16 + f32) per MoE layer — these dominate
            # the per-layer saves if not rematerialized
            E = arch.n_experts
            # route() groups per sequence: capacity from the SEQ length
            cap = ctx.shape.seq_len * arch.experts_per_token * \
                arch.capacity_factor / E
            # dispatch/combine one-hots are TOKEN-sharded (E dim is full
            # on every device) — do NOT divide by the expert-parallel width
            moe_bytes = tokens_local * E * max(cap, 4) * 6
            per_layer = per_layer + moe_bytes / max(arch.moe_interleave, 1)
        return per_layer * arch.n_layers
