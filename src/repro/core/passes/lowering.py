"""Lowering pass — the paper's "HLS" phase (§4, final level).

"With our approach, the accelerator is designed only at the end of the
flow according to the resulting memory organization. [...] The
accelerator is mostly unaware of the data organization and layout since
the IR has been already updated."

Here the accelerator logic is the XLA-compiled step function.  This pass
consumes ONLY the :class:`MemoryPlan` (+ arch/shape configs) and emits:

* ``train_step(state, batch)``  — fwd + bwd + AdamW, microbatched,
  donated, remat-policied, gradient-compressed — all per the plan;
* ``serve_step(state, batch)``  — one decode step against the session
  cache (or an encoder/prefill forward for non-decoding shapes);

together with input ShapeDtypeStructs and NamedShardings, ready for
``jax.jit(...).lower(...).compile()`` (the dry-run) or execution.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, get_arch, get_shape
from repro.core.plan import MemoryPlan
from repro.dist.collectives import compressed_slice_sum, ef_compress, ef_state
from repro.dist.sharding import (
    cache_pspecs,
    mesh_sizes,
    resolve_pspec,
    tree_shardings,
)
from repro.models import frontends
from repro.models import lm
from repro.models.lm import RunCfg
from repro.optim import adamw


@dataclasses.dataclass
class LoweredStep:
    kind: str                    # "train" | "decode" | "forward"
    fn: Callable                 # NOT yet jitted
    in_shapes: Tuple[Any, ...]   # ShapeDtypeStruct pytrees (state, batch)
    in_pspecs: Tuple[Any, ...]
    out_pspecs: Any
    donate_argnums: Tuple[int, ...]
    mesh: Mesh
    plan: MemoryPlan

    def jit(self):
        shardings_in = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.in_pspecs,
            is_leaf=lambda x: isinstance(x, P))
        shardings_out = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.out_pspecs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.jit(self.fn, in_shardings=shardings_in,
                       out_shardings=shardings_out,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.in_shapes)


def build_run_cfg(plan: MemoryPlan, arch: ArchConfig,
                  mesh: Optional[Mesh]) -> RunCfg:
    fa = plan.partitions.get("flash_attention")
    ssd = plan.partitions.get("ssd_scan")
    moe_impl = plan.estimates.get("moe_impl", "gshard_einsum")
    data_axes = tuple(a for a in plan.mesh_axes if a != "model")
    return RunCfg(
        vocab_padded=int(plan.estimates.get("vocab_padded", 0)),
        heads_padded=int(plan.estimates.get("heads_padded", 0)),
        kv_heads_padded=int(plan.estimates.get("kv_heads_padded", 0)),
        ssm_heads_padded=int(plan.estimates.get("ssm_heads_padded", 0)),
        kv_heads_sharded=bool(plan.estimates.get("kv_heads_sharded", 1.0)),
        shard_heads=plan.estimates.get("strategy", "megatron_tp")
        == "megatron_tp",
        batch_spec=(tuple(plan.axis_rules["batch"])
                    if isinstance(plan.axis_rules.get("batch"), (list, tuple))
                    else plan.axis_rules.get("batch"))
        if str(plan.estimates.get("strategy", "")).startswith("fsdp")
        else None,
        block_q=fa.blocks["block_q"] if fa else 512,
        ssd_chunk=ssd.blocks["chunk"] if ssd else 256,
        remat=plan.comm.remat_policy,
        moe_impl=moe_impl if isinstance(moe_impl, str) else "gshard_einsum",
        decode_impl=str(plan.estimates.get("decode_impl", "xla")),
        combine_topology=(str(plan.estimates["combine_topology"])
                          if "combine_topology" in plan.estimates else None),
        mesh=mesh,
        data_axes=data_axes,
        model_axis="model",
    )


def wire_compression(plan: MemoryPlan, mesh: Optional[Mesh] = None,
                     arch: Optional[ArchConfig] = None) -> int:
    """Data-parallel degree of the *lowered* compressed reduction, or 0.

    The single source of truth for whether the train step runs the
    int8+EF collective on the wire (codes crossing the data axis instead
    of f32 gradients): the communication pass records its verdict
    through this predicate and the trainer sizes the EF state by it, so
    the plan artifact and the lowered step can never disagree.  Gates:

    * the plan asked for full-DP compression (``comm.compress_grads``);
    * not an FSDP strategy — there the params themselves shard over the
      data axes and the reduction is a reduce-scatter fused into the
      sharded update, not a standalone all-reduce to replace;
    * a real data degree that divides the global batch (per-slice grads
      come from equal contiguous batch slices) with ``dp * nmicro``
      granularity when microbatched;
    * ``dp <= 256`` — shared-scale int16 code sums overflow past that;
    * not shard_map MoE dispatch (a shard_map inside the vmapped slice
      body would see a batch axis the mesh does not have).
    """
    comm = plan.comm
    if not comm.compress_grads:
        return 0
    if str(plan.estimates.get("strategy", "")).startswith("fsdp"):
        return 0
    sizes = mesh_sizes(mesh) if mesh is not None \
        else dict(zip(plan.mesh_axes, plan.mesh_shape))
    ba = plan.axis_rules.get("batch")
    axes = (ba,) if isinstance(ba, str) else tuple(ba or ())
    dp = 1
    for a in axes:
        dp *= sizes.get(a, 1)
    if dp <= 1 or dp > 256:
        return 0
    nmicro = max(comm.microbatches, 1)
    if int(plan.global_batch) % (dp * nmicro):
        return 0
    if arch is not None and arch.is_moe and \
            str(plan.estimates.get("moe_impl", "")) == "shard_map_alltoall":
        return 0
    return dp


def _dp_entry(plan: MemoryPlan, sizes) -> Any:
    """The batch rule's mesh assignment for the stacked EF/slice axis."""
    ba = plan.axis_rules.get("batch")
    axes = (ba,) if isinstance(ba, str) else tuple(ba or ())
    live = tuple(a for a in axes if sizes.get(a, 1) > 1)
    return live[0] if len(live) == 1 else live


def _padded(plan: MemoryPlan):
    return plan.padded_sizes()


def init_plan_cache(plan: MemoryPlan, arch: ArchConfig, batch: int,
                    seq_len: int, *, ssm_heads: int = 0, kv_heads: int = 0):
    """Materialize the session cache the plan's residency decision asks
    for: a block pool (+ block table) for ``kv_residency == "paged"``,
    dense per-slot stripes otherwise.  The shape every consumer of
    ``lower_serve_step`` must feed it.  ``kv_n_blocks`` is the GLOBAL
    pool capacity: on a data×model mesh the pass sized it as
    ``kv_pool_data_degree`` data-major sub-pools, each divisible by the
    model degree, so ``cache_pspecs`` lands the block dim 2-D-sharded
    and the serve step's paged combine partitions the batch instead of
    replicating it."""
    if str(plan.estimates.get("kv_residency", "dense")) == "paged":
        return lm.init_paged_cache(
            arch, batch, seq_len,
            int(plan.estimates["kv_block_len"]),
            int(plan.estimates["kv_n_blocks"]),
            ssm_heads=ssm_heads, kv_heads=kv_heads)
    return lm.init_cache(arch, batch, seq_len,
                         ssm_heads=ssm_heads, kv_heads=kv_heads)


def param_pspecs(plan: MemoryPlan, arch: ArchConfig, sizes,
                 shapes: Any = None) -> Any:
    """Resolve the plan's axis rules over the parameter pytree.

    ``shapes`` defaults to the plan-padded IR shapes; pass the actual
    runtime pytree (e.g. the arrays a serve engine was handed) to resolve
    against shapes that differ from the IR — divisibility repair then
    applies to what will really be placed.
    """
    axes = lm.param_axes(arch, *_padded(plan))
    if shapes is None:
        shapes = lm.param_shapes(arch, *_padded(plan))
    return jax.tree.map(
        lambda ax, sds: resolve_pspec(plan.axis_rules, sds.shape, ax, sizes),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


_param_pspecs = param_pspecs


def _input_pspecs(plan: MemoryPlan, arch: ArchConfig, shape: ShapeConfig,
                  specs, sizes) -> Dict[str, P]:
    axes = frontends.input_axes(arch, shape)
    return {k: resolve_pspec(plan.axis_rules, specs[k].shape, axes[k], sizes)
            for k in specs}


# =====================================================================
# train step
# =====================================================================

def lower_train_step(plan: MemoryPlan, arch: ArchConfig, shape: ShapeConfig,
                     mesh: Mesh,
                     opt_cfg: Optional[adamw.OptConfig] = None) -> LoweredStep:
    sizes = mesh_sizes(mesh)
    cfg = build_run_cfg(plan, arch, mesh)
    opt_cfg = opt_cfg or adamw.OptConfig.from_plan(plan)
    nmicro = max(plan.comm.microbatches, 1)
    compress = plan.comm.compresses_gradients
    # > 0: the reduction itself is lowered to int16 code sums (the wire
    # path); 0 with compress on: post-reduce EF modeling only
    wire_dp = wire_compression(plan, mesh, arch)

    pshapes = lm.param_shapes(arch, *_padded(plan))
    ppspecs = _param_pspecs(plan, arch, sizes)

    ishapes = frontends.input_specs(arch, shape)
    ipspecs = _input_pspecs(plan, arch, shape, ishapes, sizes)

    mdt = jnp.dtype(plan.opt["moment_dtype"])
    opt_shapes: Dict[str, Any] = {
        "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), pshapes),
        "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), pshapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    opt_pspecs: Dict[str, Any] = {"m": ppspecs, "v": ppspecs, "step": P()}
    if plan.opt["master_weights"]:
        opt_shapes["master"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
        opt_pspecs["master"] = ppspecs
    if compress:
        if wire_dp:
            # one residual per DP slice, stacked on a leading axis the
            # data axes shard (each slice quantizes its own codes)
            dpe = _dp_entry(plan, sizes)
            opt_shapes["ef"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((wire_dp,) + tuple(s.shape),
                                               jnp.bfloat16), pshapes)
            opt_pspecs["ef"] = jax.tree.map(
                lambda p: P(dpe, *tuple(p)), ppspecs,
                is_leaf=lambda x: isinstance(x, P))
        else:
            opt_shapes["ef"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), pshapes)
            opt_pspecs["ef"] = ppspecs

    state_shapes = {"params": pshapes, "opt": opt_shapes}
    state_pspecs = {"params": ppspecs, "opt": opt_pspecs}

    def loss_fn(params, batch):
        loss, metrics = lm.train_loss(arch, params, batch, cfg)
        return loss, metrics

    # which dim of each input is the batch dim (positions: (3,B,S) -> 1)
    batch_dims = {k: (ax.index("batch") if "batch" in ax else None)
                  for k, ax in frontends.input_axes(arch, shape).items()}

    def wire_train_step(state, batch):
        """The lowered compressed reduction: no f32 gradient all-reduce
        exists in this step.  vmap over contiguous per-data-shard batch
        slices yields stacked per-slice grads with NO implicit DP
        reduction; each leaf then quantizes against a shared scale and
        the int16 *code sum* over the stacked axis is the only
        gradient-sized cross-data operation GSPMD emits (wrapping the
        model in shard_map instead is off the table: the layer scan
        inside ``lm.train_loss`` breaks the partial-auto partitioner).
        EF residuals live per slice — ``opt["ef"]`` leaves carry a
        leading ``(dp,)`` axis sharded like the batch."""
        params = state["params"]
        dpe = _dp_entry(plan, sizes)

        def split(x, bd):
            if bd is None:
                return None
            x = jnp.moveaxis(x, bd, 0)
            # contiguous outer split: slice i lands on data shard i, so
            # the stacked axis takes over the batch's data sharding
            x = x.reshape(wire_dp, x.shape[0] // wire_dp, *x.shape[1:])
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dpe, *([None] * (x.ndim - 1)))))

        sliced = {k: split(v, batch_dims[k]) for k, v in batch.items()}
        moving = {k: v for k, v in sliced.items() if v is not None}

        def one(mb):
            b = {k: (jnp.moveaxis(mb[k], 0, batch_dims[k]) if k in mb
                     else batch[k]) for k in batch}
            if nmicro == 1:
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b)
                return l, g
            # grad accumulation within the slice (interleaved inner
            # split, same rationale as the unwired micro path)
            def msplit(x, bd):
                x = jnp.moveaxis(x, bd, 0)
                x = x.reshape(x.shape[0] // nmicro, nmicro, *x.shape[1:])
                x = jnp.moveaxis(x, 1, 0)
                return jnp.moveaxis(x, 1, bd + 1)
            mbs = {k: msplit(v, batch_dims[k])
                   for k, v in b.items() if batch_dims[k] is not None}

            def micro(carry, mb_sliced):
                gsum, lsum = carry
                bb = {k: (mb_sliced[k] if batch_dims[k] is not None
                          else b[k]) for k in b}
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, bb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None
            zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params), jnp.zeros((), jnp.float32))
            (g, lsum), _ = jax.lax.scan(micro, zero, mbs)
            return lsum / nmicro, jax.tree.map(lambda x: x / nmicro, g)

        losses, gsl = jax.vmap(one)(moving)

        opt_state = dict(state["opt"])
        ef = opt_state.pop("ef")
        flat_g, tdef = jax.tree.flatten(gsl)
        flat_e = jax.tree.leaves(ef)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            acc = g.astype(jnp.float32) + e.astype(jnp.float32)
            scalar = acc.ndim == 1          # scalar param leaf: (dp,)
            if scalar:
                acc = acc[:, None]
            gh, err = compressed_slice_sum(acc)
            if scalar:
                gh, err = gh[..., 0], err[..., 0]
            out_g.append(gh.astype(g.dtype))
            out_e.append(err.astype(jnp.bfloat16))
        grads = jax.tree.unflatten(tdef, out_g)
        new_ef = jax.tree.unflatten(tdef, out_e)
        loss = jnp.mean(losses)
        metrics = {"ce_loss": loss, "aux_loss": jnp.zeros(()),
                   "tokens": jnp.asarray(shape.tokens, jnp.float32)}
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        opt_state["ef"] = new_ef
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return {"params": params, "opt": opt_state}, metrics

    def train_step(state, batch):
        params = state["params"]
        if nmicro > 1:
            # grad accumulation: scan over a leading microbatch axis.
            # Splitting the batch dim by reshape (B -> nmicro x B/nmicro)
            # keeps the data sharding on the inner dim; a dynamic-slice on
            # the sharded dim would force GSPMD to replicate the batch.
            def split(x, bd):
                if bd is None:
                    return None
                x = jnp.moveaxis(x, bd, 0)
                # (B, ...) -> (B/nm, nm, ...) -> (nm, B/nm, ...): the batch
                # dim splits on the *inner* position so its data-sharding
                # survives the reshape (interleaved micro assignment)
                x = x.reshape(x.shape[0] // nmicro, nmicro, *x.shape[1:])
                x = jnp.moveaxis(x, 1, 0)
                return jnp.moveaxis(x, 1, bd + 1)
            mbs = {k: split(x, batch_dims[k]) for k, x in batch.items()}

            def micro(carry, mb_sliced):
                gsum, lsum = carry
                mb = {k: (mb_sliced[k] if batch_dims[k] is not None
                          else batch[k]) for k in batch}
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None
            zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params), jnp.zeros((), jnp.float32))
            (grads, lsum), _ = jax.lax.scan(
                micro, zero, {k: v for k, v in mbs.items() if v is not None})
            grads = jax.tree.map(lambda g: g / nmicro, grads)
            loss = lsum / nmicro
            metrics = {"ce_loss": loss, "aux_loss": jnp.zeros(()),
                       "tokens": jnp.asarray(shape.tokens, jnp.float32)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        opt_state = dict(state["opt"])
        if compress:
            ef = opt_state.pop("ef")
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(ef)
            out_g, out_e = [], []
            for g, e in zip(flat_g, flat_e):
                gh, eh = ef_compress(g, e)
                out_g.append(gh)
                out_e.append(eh)
            grads = jax.tree.unflatten(tdef, out_g)
            new_ef = jax.tree.unflatten(tdef, out_e)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        if compress:
            opt_state["ef"] = new_ef
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return {"params": params, "opt": opt_state}, metrics

    return LoweredStep(
        kind="train",
        fn=wire_train_step if wire_dp else train_step,
        in_shapes=(state_shapes, ishapes),
        in_pspecs=(state_pspecs, ipspecs),
        out_pspecs=(state_pspecs,
                    jax.tree.map(lambda _: P(),
                                 {"ce_loss": 0, "aux_loss": 0, "tokens": 0,
                                  "grad_norm": 0, "lr": 0, "loss": 0})),
        donate_argnums=(0,),
        mesh=mesh,
        plan=plan,
    )


# =====================================================================
# serve step (decode) / forward (prefill & encoder)
# =====================================================================

def lower_serve_step(plan: MemoryPlan, arch: ArchConfig, shape: ShapeConfig,
                     mesh: Mesh) -> LoweredStep:
    sizes = mesh_sizes(mesh)
    cfg = build_run_cfg(plan, arch, mesh)
    pshapes = lm.param_shapes(arch, *_padded(plan))
    ppspecs = _param_pspecs(plan, arch, sizes)
    ishapes = frontends.input_specs(arch, shape)
    ipspecs = _input_pspecs(plan, arch, shape, ishapes, sizes)

    B = shape.global_batch
    Vp = int(plan.estimates.get("vocab_padded", 0)) or arch.vocab_size
    logits_spec = resolve_pspec(plan.axis_rules, (B, Vp),
                                ("batch", "vocab"), sizes)

    if shape.kind == "decode":
        # the serve step runs against whatever residency the plan chose
        # (paged block pool vs dense per-slot stripes)
        cache_shapes = jax.eval_shape(
            lambda: init_plan_cache(plan, arch, shape.global_batch,
                                    shape.seq_len,
                                    ssm_heads=cfg.ssm_heads_padded,
                                    kv_heads=cfg.kv_heads_padded))
        cpspecs = cache_pspecs(plan, arch, cache_shapes, sizes)

        def serve_step(params, cache, batch):
            logits, new_cache = lm.decode_step(arch, params, cache, batch, cfg)
            return logits, new_cache

        return LoweredStep(
            kind="decode",
            fn=serve_step,
            in_shapes=(pshapes, cache_shapes, ishapes),
            in_pspecs=(ppspecs, cpspecs, ipspecs),
            out_pspecs=(logits_spec, cpspecs),
            donate_argnums=(1,),
            mesh=mesh,
            plan=plan,
        )

    if arch.is_encoder:
        # encoder "prefill" = full-sequence forward (no cache exists)
        def fwd_step(params, batch):
            x, _ = lm.forward(arch, params, batch, cfg)
            return lm._logits(arch, params, x, cfg)

        out_spec = resolve_pspec(plan.axis_rules, (B, shape.seq_len, Vp),
                                 ("batch", "seq", "vocab"), sizes)
        return LoweredStep(
            kind="forward",
            fn=fwd_step,
            in_shapes=(pshapes, ishapes),
            in_pspecs=(ppspecs, ipspecs),
            out_pspecs=out_spec,
            donate_argnums=(),
            mesh=mesh,
            plan=plan,
        )

    # decoder prefill: build the session cache + last-token logits
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(arch, B, shape.seq_len,
                              ssm_heads=cfg.ssm_heads_padded,
                              kv_heads=cfg.kv_heads_padded))
    cpspecs = cache_pspecs(plan, arch, cache_shapes, sizes)

    def prefill_step(params, batch):
        return lm.prefill(arch, params, batch, cfg, max_len=shape.seq_len)

    return LoweredStep(
        kind="prefill",
        fn=prefill_step,
        in_shapes=(pshapes, ishapes),
        in_pspecs=(ppspecs, ipspecs),
        out_pspecs=(logits_spec, cpspecs),
        donate_argnums=(),
        mesh=mesh,
        plan=plan,
    )


def lower_step(plan: MemoryPlan, mesh: Mesh,
               opt_cfg: Optional[adamw.OptConfig] = None) -> LoweredStep:
    """Dispatch on the shape kind (the dry-run entry point)."""
    arch = get_arch(plan.arch)
    shape = get_shape(plan.shape)
    if shape.kind == "train":
        return lower_train_step(plan, arch, shape, mesh, opt_cfg)
    return lower_serve_step(plan, arch, shape, mesh)
