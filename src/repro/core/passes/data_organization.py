"""Data-organization pass (paper §4, highest abstraction level).

Paper: "analyzes the data representations to determine the coarse memory
structure, i.e. deciding which data are stored off-chip or on-chip."

TPU re-targeting: *on-chip* for a given chip means "the shard of the
tensor this chip owns".  The pass therefore decides, per logical tensor:

* the mesh sharding (which logical axes map to which mesh axes), and
* the residency class (HBM / HOST / REMOTE),

under a per-chip HBM byte budget — the paper's "given area constraints".
The outputs are the plan's ``axis_rules`` plus per-tensor placement specs
with divisibility validated against real dims.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

from repro.core.costmodel import (MeshModel, bytes_per_device,
                                  kv_block_geometry, kv_prefill_split,
                                  kv_tier_split, shard_factor)
from repro.core.ir import MemorySpace, Role, TensorDecl
from repro.core.passes import Pass, PassContext


class DataOrganizationPass(Pass):
    name = "data_organization"

    #: fraction of HBM the persistent state (params + opt + caches) may use
    hbm_budget_frac: float = 0.70

    def run(self, ctx: PassContext) -> None:
        mesh = ctx.mesh
        plan = ctx.plan
        has_pod = "pod" in mesh.axes

        # ---- sharding strategy: Megatron-TP vs FSDP-DP -------------------
        # TP moves activation bytes per layer (2 all-reduces x token bytes);
        # FSDP-DP moves weight bytes per layer (all-gather fwd+bwd).  Pick
        # whichever moves fewer bytes for this (arch x shape) — the paper's
        # data-organization phase deciding placement from static analysis.
        strategy = self._pick_strategy(ctx)
        batch_axes: Tuple[str, ...] = (("pod", "data") if has_pod else ("data",))
        if strategy.startswith("fsdp"):
            full_dp = batch_axes if strategy == "fsdp_hybrid" \
                else batch_axes + ("model",)
            if strategy == "fsdp_dp_data":
                embed_assign = ("data",)
            else:
                embed_assign = ("data", "model")
            rules: Dict[str, Optional[object]] = {
                "batch": full_dp,
                "seq": None,
                "act_embed": None,
                "act_heads": None,
                "act_ff": None,
                "act_experts": None,
                "layers": None,
                "embed": embed_assign,           # ZeRO-3 over the fast axes
                "heads": None,
                "kv_heads": None,
                "head_dim": None,
                "ff": None,
                "vocab": None,
                "experts": None,
                "ssm_inner": None,
                "seq_kv": None,
                "ssm_heads": None,
                "flat_params": embed_assign,
            }
            self.record(
                ctx, "strategy", strategy,
                "per-layer weight all-gather moves fewer bytes than TP "
                "activation all-reduces for this workload "
                "(hybrid: batch over pod+data only — global batch smaller "
                "than the device count)" if strategy == "fsdp_hybrid" else
                "per-layer weight all-gather moves fewer bytes than TP "
                "activation all-reduces (see est_* in estimates)")
        else:
            rules = {
                # activations
                "batch": batch_axes if len(batch_axes) > 1 else batch_axes[0],
                "seq": None,
                "act_embed": None,
                "act_heads": "model",
                "act_ff": "model",
                "act_experts": None,
                # params (tensor-parallel axes)
                "layers": None,
                "embed": None,
                "heads": "model",
                "kv_heads": "model",
                "head_dim": None,
                "ff": "model",
                "vocab": "model",
                "experts": "model",
                "ssm_inner": "model",
                "seq_kv": None,
                "ssm_heads": "model",
                "flat_params": "model",
            }
            self.record(ctx, "strategy", "megatron_tp",
                        "TP activation traffic below FSDP weight traffic "
                        "(or inference shape: keep weights TP-resident)")
        plan.estimates["strategy"] = strategy
        plan.axis_rules = dict(rules)
        self.record(ctx, "axis_rules",
                    f"{strategy}, DP=" + "+".join(batch_axes),
                    "template channel assignment (ICI fast axes)")

        # ---- per-tensor placements with divisibility repair --------------
        for t in ctx.ir.tensors.values():
            spec = self._resolve(ctx, t)
            p = plan.placement(t.name)
            p.spec = spec
            p.residency = MemorySpace.HBM.value
            p.decided_by.append(self.name)

        # inputs stream from the host pipeline (off-chip analogue)
        for t in ctx.ir.by_role(Role.INPUT):
            plan.placement(t.name).residency = MemorySpace.HOST.value
            self.record(ctx, t.name, "HOST->HBM streamed",
                        "step inputs are produced by the host pipeline")

        # ---- HBM budget check → FSDP spill (the paper's on/off-chip split)
        budget = self.hbm_budget_frac * ctx.target.hbm_bytes
        persistent = self._persistent_bytes_per_dev(ctx)
        if persistent > budget:
            self._enable_fsdp(ctx)
            persistent2 = self._persistent_bytes_per_dev(ctx)
            self.record(
                ctx, "fsdp", "enabled",
                f"persistent state {persistent/2**30:.1f} GiB/chip exceeds "
                f"budget {budget/2**30:.1f} GiB; FSDP over data axis brings "
                f"it to {persistent2/2**30:.1f} GiB",
            )
            persistent = persistent2
            # next rungs of the ladder: optimizer-state precision
            # (the paper's "technology requirements" dimension)
            if persistent > budget:
                plan.opt["moment_dtype"] = "bfloat16"
                for t in ctx.ir.by_role(Role.OPT_STATE):
                    if t.name in ("adam_m", "adam_v"):
                        t.dtype = "bfloat16"
                persistent = self._persistent_bytes_per_dev(ctx)
                self.record(ctx, "opt_moments", "bfloat16",
                            f"still over budget: Adam moments to bf16 -> "
                            f"{persistent/2**30:.1f} GiB/chip")
            if persistent > budget:
                plan.opt["master_weights"] = False
                for t in ctx.ir.by_role(Role.OPT_STATE):
                    if t.name == "master":
                        t.annotations["folded"] = True  # no separate fp32 copy
                persistent = self._persistent_bytes_per_dev(ctx)
                self.record(ctx, "master_weights", "dropped",
                            "bf16 params updated with stochastic rounding "
                            f"(no fp32 copy) -> {persistent/2**30:.1f} GiB/chip")
        else:
            self.record(
                ctx, "fsdp", "disabled",
                f"persistent state {persistent/2**30:.1f} GiB/chip fits "
                f"budget {budget/2**30:.1f} GiB — keep weights TP-only "
                "(no per-layer all-gather needed)",
            )
        plan.estimates["persistent_bytes_per_dev"] = float(persistent)

        # KV cache placement sanity (decode shapes)
        for t in ctx.ir.by_role(Role.KV_CACHE):
            self._shard_cache(ctx, t, budget)

        # KV residency: dense per-slot stripes vs a plan-sized block pool
        self._choose_kv_residency(ctx, budget, persistent)

        plan.estimates["hbm_budget_bytes"] = float(budget)

    # ------------------------------------------------------------------
    def _choose_kv_residency(self, ctx: PassContext, budget: float,
                             persistent: float) -> None:
        """Dense per-slot stripes vs a plan-sized paged block pool.

        The serving KV cache is the one memory whose *occupancy* varies
        at runtime (slots churn); paging turns freed slots back into pool
        capacity instead of dead masked rows.  The pass decides the 2-D
        geometry (block_len, n_blocks split data-major into per-data-
        shard sub-pools, each model-shardable) from the workload dims
        and the HBM left after persistent state: on a data×model mesh
        the pool shards over BOTH axes — batch slots partition across
        data and each (data, model) shard owns its block slice, the
        partitioned-multi-bank specialization of the template.  Dense
        wins when the cache is too shallow for blocks to matter, or when
        the batch cannot partition over the data degree (slots could not
        be owned per data shard, which would force the pool back to
        data-replication and regress per-chip compute).  An
        ``options['kv_residency']`` override forces either.
        """
        plan, arch, shape = ctx.plan, ctx.arch, ctx.shape
        if shape.kind != "decode" or not arch.has_attention:
            return
        # the pool spans every chip (data-major sub-pools × model
        # shards), so its budget is the GLOBAL HBM headroom; capacity
        # still targets 1/data_degree of the all-slots-at-max footprint
        # (the reclamation bet — churn keeps the sub-pools fed), which
        # is what puts per-chip paged bytes below the dense stripes.
        # (zero headroom is a real cap — it clamps each sub-pool to the
        # one-sequence floor, not to the uncapped worst case.)
        msize = ctx.mesh.axis_size("model") if "model" in ctx.mesh.axes else 1
        dsize = max(1, ctx.mesh.n_devices // msize)
        left = max(budget - persistent, 0.0) * msize * dsize
        geo = kv_block_geometry(
            shape.seq_len, shape.global_batch, arch.n_layers,
            arch.n_kv_heads, arch.hd, budget_bytes=left,
            data_shards=dsize, align=msize)
        batch_ok = dsize == 1 or shape.global_batch % dsize == 0
        forced = ctx.options.get("kv_residency")
        paged = (geo.blocks_per_seq >= 2 and batch_ok) if forced is None \
            else forced == "paged"
        plan.estimates["kv_residency"] = "paged" if paged else "dense"
        if not paged:
            if forced is not None:
                why = "forced by options"
            elif not batch_ok:
                why = (f"batch {shape.global_batch} does not partition "
                       f"over the {dsize}-wide data degree — slots could "
                       "not be owned per data shard, so the pool would "
                       "fall back to data-replication (per-chip working "
                       "set and compute regress vs dense stripes)")
            else:
                why = (f"cache depth {shape.seq_len} yields "
                       f"{geo.blocks_per_seq} block(s)/seq at "
                       f"block_len={geo.block_len} — paging buys no "
                       "reclamation granularity")
            self.record(ctx, "kv_residency", "dense", why)
            return
        plan.estimates["kv_block_len"] = geo.block_len
        plan.estimates["kv_n_blocks"] = geo.n_blocks
        plan.estimates["kv_dense_bytes"] = float(geo.dense_bytes)
        plan.estimates["kv_paged_bytes"] = float(geo.paged_bytes)
        plan.estimates["kv_pool_data_degree"] = geo.data_degree
        plan.estimates["kv_pool_model_degree"] = geo.model_degree
        plan.estimates["kv_admission"] = geo.admission
        plan.estimates["kv_preempt_headroom"] = geo.headroom_blocks
        # cross-request prefix reuse rides on the paged pool: record it
        # plus the expected-hit-rate headroom so from_plan engines and
        # the decision log carry the data-level-reuse bet explicitly
        residents = max(1, shape.global_batch // dsize)
        reuse_headroom = geo.prefix_hit_headroom(residents)
        plan.estimates["kv_prefix_reuse"] = geo.prefix_reuse
        plan.estimates["kv_prefix_hit_headroom"] = reuse_headroom
        self.record(
            ctx, "kv_prefix_reuse", geo.prefix_reuse,
            f"full prompt-prefix blocks are content-hashed and aliased "
            f"across requests (refcounted, CoW on divergence): at the "
            f"assumed {geo.assumed_hit_rate:.0%} shared-prefix rate, "
            f"{residents} resident seq(s)/sub-pool pin "
            f"~{reuse_headroom} fewer block(s) "
            f"(capacity x{geo.prefix_capacity_factor(residents):.2f}) "
            "and matched tokens skip prefill compute entirely")
        if geo.admission == "grant":
            self.record(
                ctx, "kv_admission", "grant",
                f"pool ({geo.n_blocks} blocks) is below the worst case "
                f"({shape.global_batch}x{geo.blocks_per_seq} blocks) — "
                "the reclamation bet; worst-case reservation would refuse "
                "servable requests, so admission grows holdings one block "
                "boundary at a time with preemption as the backstop "
                f"(headroom {geo.headroom_blocks} block(s)/sub-pool past "
                "one max sequence)")
        else:
            self.record(
                ctx, "kv_admission", "reserve",
                f"pool covers every slot's worst case "
                f"({shape.global_batch}x{geo.blocks_per_seq} blocks) — "
                "reserving full budgets at admission costs nothing and "
                "mid-decode grants can never fail")
        # multi-tier residency: size the host-DRAM spill pool behind the
        # HBM pool (the template specialized *across* tiers, not within
        # one).  The decode tick is modeled memory-bound — params plus
        # the per-chip pool read once per token — and the stream-back
        # check asks whether one block crosses PCIe inside the
        # block_len ticks between a slot's block-boundary crossings
        # (the engine's one-tick-lookahead prefetch window).
        n_chips = dsize * msize
        pin_frac = float(ctx.options.get("kv_host_pin_frac", 0.5))
        tick_s = ctx.target.hbm_time(persistent + geo.paged_bytes / n_chips)
        split = kv_tier_split(
            geo,
            host_budget_bytes=ctx.target.host_bytes_per_chip
            * n_chips * pin_frac,
            pcie_bw=ctx.target.pcie_bw,
            decode_tick_s=tick_s)
        plan.estimates["kv_tier_split"] = split.tier_name
        plan.estimates["kv_host_blocks"] = split.host_blocks
        plan.estimates["kv_host_bytes"] = float(split.host_bytes)
        plan.estimates["kv_stream_block_us"] = split.stream_block_s * 1e6
        plan.estimates["kv_decode_tick_us"] = split.decode_tick_s * 1e6
        plan.estimates["kv_prefetch"] = (
            "on" if split.prefetch_feasible else "off")
        if split.host_blocks:
            feas = ("feasible" if split.prefetch_feasible
                    else "NOT feasible (resumes may stall a tick on PCIe)")
            self.record(
                ctx, "kv_tier_split", split.tier_name,
                f"host pin budget ({pin_frac:.0%} of "
                f"{ctx.target.host_bytes_per_chip * n_chips / 2**30:.0f} "
                f"GiB) backs {split.host_blocks} spill block(s) behind "
                f"the {split.hbm_blocks}-block HBM pool; cold blocks "
                "(parked sessions, evicted prefix tails) park on host "
                f"and stream back at {ctx.target.pcie_bw / 1e9:.0f} GB/s "
                f"— one block in {split.stream_block_s * 1e6:.0f} us vs "
                f"a {split.lookahead_ticks}-tick boundary interval of "
                f"{split.lookahead_ticks * tick_s * 1e6:.0f} us, so "
                f"one-tick-lookahead prefetch is {feas}")
        else:
            self.record(
                ctx, "kv_tier_split", "hbm-only",
                "host pin budget cannot park even one full sequence "
                f"({split.block_bytes} B/block x {geo.blocks_per_seq} "
                "blocks/seq) — spilling a session that can never fully "
                "park only fragments the tier")
        # disaggregated prefill: one memory template per ROLE.  Prefill
        # is a flops-bound burst, decode a bandwidth-bound tick; run in
        # one process a worst-case prompt's prefill steals stall_ticks
        # consecutive decode ticks from every live slot.  Past the
        # threshold the plan flips to disagg — supervised prefill
        # workers stream block_len-sized KV chunks to the decode engine
        # (serve/disagg.py) and decode never waits on a prompt.
        psplit = kv_prefill_split(
            shape.seq_len, persistent, ctx.target.peak_bf16_flops,
            tick_s, chunk_len=geo.block_len)
        pmode = psplit.mode if not arch.has_ssm else "inline"
        plan.estimates["kv_prefill_mode"] = pmode
        plan.estimates["kv_prefill_chunk"] = psplit.chunk_len
        plan.estimates["kv_prefill_stall_ticks"] = psplit.stall_ticks
        if arch.has_ssm:
            self.record(
                ctx, "kv_prefill_mode", "inline",
                f"{arch.name} has an SSM path — its state is sequential "
                "across the whole prompt, so chunked block-native "
                "prefill (pure-attention KV) cannot ship blocks "
                "incrementally; prefill stays in-process")
        else:
            self.record(
                ctx, "kv_prefill_mode", pmode,
                f"worst-case {shape.seq_len}-token prefill burns "
                f"{psplit.prefill_s * 1e3:.1f} ms of chip flops vs a "
                f"{tick_s * 1e6:.0f} us decode tick — "
                f"{psplit.stall_ticks:.0f} tick(s) of head-of-line "
                f"stall (threshold {psplit.threshold_ticks:.0f}); "
                + ("prefill moves to supervised workers streaming "
                   f"{psplit.chunk_len}-token pool-block chunks"
                   if pmode == "disagg" else
                   "inline prefill cannot stall decode enough to pay "
                   "for a worker fleet"))
        for t in ctx.ir.by_role(Role.KV_CACHE):
            plan.placement(t.name).layout["kv_residency"] = "paged"
            plan.placement(t.name).decided_by.append(self.name + ":paged")
        self.record(
            ctx, "kv_residency",
            f"paged block_len={geo.block_len} n_blocks={geo.n_blocks} "
            f"pool_sharding={dsize}x{msize}",
            f"pool {geo.paged_bytes/n_chips/2**30:.2f} GiB/chip (2-D "
            f"sharded: {dsize} data-major sub-pools of "
            f"{geo.sub_pool_blocks} blocks x model degree {msize}, batch "
            f"partitioned across data) vs dense stripes "
            f"{geo.dense_bytes/n_chips/2**30:.2f} GiB/chip; freed slots "
            "return blocks to their sub-pool instead of masking rows")

    # ------------------------------------------------------------------
    def _pick_strategy(self, ctx: PassContext) -> str:
        """Static byte model: TP activation ARs vs FSDP weight AGs."""
        arch, shape, mesh = ctx.arch, ctx.shape, ctx.mesh
        if shape.kind != "train":
            return "megatron_tp"     # serving keeps weights TP-resident
        tp = mesh.axis_size("model")
        if tp <= 1:
            return "megatron_tp"
        n_dev = mesh.n_devices
        dp = n_dev // tp
        if shape.global_batch % n_dev != 0:
            # batch too small for full-DP (e.g. 256 samples on 512 chips).
            # hybrid (batch over pod+data, ZeRO-3 over data+model) pays the
            # weight all-gather once PER MICROBATCH and re-reads gathered
            # weights from HBM — measured 2x worse than its wire bytes
            # suggest (EXPERIMENTS.md §Perf, refuted iteration), so it must
            # beat TP with that penalty before we pick it.
            if shape.global_batch % dp == 0 and \
                    arch.d_model % (mesh.axis_size("data") * tp) == 0:
                L = max(arch.n_layers, 1)
                tokens_local = shape.tokens / dp
                tp_bytes = (2 * 3 * tokens_local * arch.d_model * 2
                            * 2 * (tp - 1) / tp) * L
                params_b = arch.param_count() * 2
                carry = L * tokens_local * arch.d_model * 2
                nmicro = max(1, int(carry // (4 * 2**30)) + 1)
                hybrid_bytes = 3 * params_b * nmicro
                if 2 * hybrid_bytes < tp_bytes:
                    return "fsdp_hybrid"
            return "megatron_tp"
        if arch.d_model % n_dev != 0:
            if arch.d_model % dp == 0:
                return "fsdp_dp_data"   # shard weights over data axis only
            return "megatron_tp"     # ZeRO-3 shards the embed dim
        L = max(arch.n_layers, 1)
        # TP: ~2 all-reduces of the residual per layer, fwd + 2x bwd,
        # ring volume 2(g-1)/g, bf16
        tokens_local = shape.tokens / dp
        tp_bytes = (2 * 3 * tokens_local * arch.d_model * 2
                    * 2 * (tp - 1) / tp) * L
        # FSDP: gather each layer's params fwd + bwd, reduce-scatter grads
        layer_params = (arch.param_count()
                        - arch.vocab_size * arch.d_model
                        * (1 if arch.tie_embeddings else 2)) / L
        fsdp_bytes = 3 * layer_params * 2 * (n_dev - 1) / n_dev * L
        ctx.plan.estimates["est_tp_coll_bytes"] = float(tp_bytes)
        ctx.plan.estimates["est_fsdp_coll_bytes"] = float(fsdp_bytes)
        return "fsdp_dp" if fsdp_bytes < tp_bytes else "megatron_tp"

    def _resolve(self, ctx: PassContext, t: TensorDecl) -> Tuple:
        """Apply axis rules to one tensor, dropping non-divisible assigns."""
        plan = ctx.plan
        mesh = ctx.mesh
        spec = list(plan.sharding_spec(t.logical_axes))
        for i, (dim, assign) in enumerate(zip(t.shape, spec)):
            if assign is None:
                continue
            names = (assign,) if isinstance(assign, str) else tuple(assign)
            size = math.prod(mesh.axis_size(n) for n in names)
            if dim % size != 0:
                spec[i] = None
                self.record(
                    ctx, t.name,
                    f"dim{i}={dim} not divisible by {names}({size}) -> unsharded",
                    "divisibility repair",
                )
        # a mesh axis may appear only once per tensor
        seen = set()
        for i, assign in enumerate(spec):
            if assign is None:
                continue
            names = (assign,) if isinstance(assign, str) else tuple(assign)
            keep = tuple(n for n in names if n not in seen)
            seen.update(keep)
            spec[i] = (keep[0] if len(keep) == 1 else (keep or None) and keep) \
                if keep else None
        return tuple(spec)

    def _persistent_bytes_per_dev(self, ctx: PassContext) -> int:
        total = 0
        for t in ctx.ir.by_role(Role.PARAM, Role.EXPERT_PARAM, Role.OPT_STATE):
            if t.annotations.get("folded"):
                continue
            spec = ctx.plan.placements[t.name].spec
            total += t.nbytes // _spec_factor(spec, ctx.mesh)
        return total

    def _enable_fsdp(self, ctx: PassContext) -> None:
        """Shard params' embed dim (and flat opt state) over the data axis.

        Feature-dim FSDP (not layer-dim) so ``lax.scan`` over layers sees a
        uniform per-iteration all-gather that XLA can software-pipeline.
        """
        plan = ctx.plan
        mesh = ctx.mesh
        dsize = mesh.axis_size("data")
        dp_axes = ("pod", "data") if "pod" in mesh.axes else ("data",)
        plan.axis_rules["embed"] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        plan.axis_rules["flat_params"] = dp_axes + ("model",)
        for t in ctx.ir.by_role(Role.PARAM, Role.EXPERT_PARAM, Role.OPT_STATE):
            spec = list(plan.placements[t.name].spec)
            used = {n for s in spec if s is not None
                    for n in ((s,) if isinstance(s, str) else s)}
            if "data" in used:
                continue
            for i, (dim, ax) in enumerate(zip(t.shape, t.logical_axes)):
                if ax not in ("embed", "flat_params"):
                    continue
                # prepend the DP axes to whatever already shards this dim
                existing = spec[i]
                names = dp_axes + (
                    () if existing is None
                    else ((existing,) if isinstance(existing, str)
                          else tuple(existing)))
                size = math.prod(mesh.axis_size(n) for n in names)
                if dim % size == 0:
                    spec[i] = names[0] if len(names) == 1 else names
                    break
            plan.placements[t.name].spec = tuple(spec)
            plan.placements[t.name].decided_by.append(self.name + ":fsdp")

    def _shard_cache(self, ctx: PassContext, t: TensorDecl, budget: float) -> None:
        """KV caches must also fit; spill to seq-dim sharding if needed.

        When kv_heads isn't divisible by the model axis (GQA kv=8 on a
        16-wide TP axis) the head dim stays unsharded and the *sequence*
        dim takes the model axis instead — decode attention then reduces
        over a sharded seq axis (flash-decode semantics via psum).
        """
        plan, mesh = ctx.plan, ctx.mesh
        spec = list(plan.placements[t.name].spec)
        used = {n for s in spec if s is not None
                for n in ((s,) if isinstance(s, str) else s)}
        per_dev = t.nbytes // _spec_factor(tuple(spec), mesh)
        if "model" not in used and "model" in mesh.axes:
            # shard_map flash-decode owns its append -> seq sharding is
            # best (local write + 3-term combine); the XLA-automatic path
            # prefers head_dim (local append, score-tensor psum) because a
            # runtime-offset update on a sharded seq dim becomes a gather
            impl = ctx.options.get("decode_impl", "shard_map_flash")
            ctx.plan.estimates["decode_impl"] = impl
            order = ("seq_kv", "head_dim") if impl == "shard_map_flash" \
                else ("head_dim", "seq_kv")
            for want in order:
                for i, ax in enumerate(t.logical_axes):
                    if ax == want and t.shape[i] % mesh.axis_size("model") == 0:
                        spec[i] = "model"
                        plan.placements[t.name].spec = tuple(spec)
                        plan.placements[t.name].decided_by.append(
                            self.name + ":cache")
                        self.record(
                            ctx, t.name, f"{want} -> model",
                            f"kv_heads not shardable by model axis; cache was "
                            f"{per_dev/2**30:.2f} GiB/chip — shard {want} "
                            "instead (flash-decode reduction)",
                        )
                        return


def _spec_factor(spec: Tuple, mesh: MeshModel) -> int:
    f = 1
    for s in spec:
        if s is None:
            continue
        names = (s,) if isinstance(s, str) else tuple(s)
        for n in names:
            f *= mesh.axis_size(n)
    return f
