"""Local-partitioning pass (paper §4, fourth level).

Paper: "determines the multi-bank PLM architecture, also sharing physical
memories for data with disjoint lifetimes."

TPU re-targeting: the PLM is VMEM, banks are pipeline buffers, ports are
per-grid-step tiles.  For every kernel-eligible op this pass derives the
Pallas BlockSpec tile shapes under the VMEM budget with double buffering,
MXU-aligned.  The kernels in :mod:`repro.kernels` read these
:class:`~repro.core.plan.BlockPlan` entries — kernel code never chooses
its own tiles (the paper's separation: the template is configured by the
compiler, the datapath just uses it).

"Sharing physical memories for data with disjoint lifetimes" maps to
buffer donation (input/output aliasing), decided here and applied by the
lowering pass.
"""

from __future__ import annotations

from repro.core.costmodel import attention_tile_bytes, matmul_tile_bytes
from repro.core.ir import OpKind
from repro.core.plan import BlockPlan
from repro.core.passes import Pass, PassContext


def _align_down(n: int, q: int) -> int:
    return max(q, (n // q) * q)


class LocalPartitioningPass(Pass):
    name = "local_partitioning"

    vmem_budget_frac: float = 0.75

    def run(self, ctx: PassContext) -> None:
        tgt = ctx.target
        budget = int(self.vmem_budget_frac * tgt.vmem_bytes)
        arch, mesh = ctx.arch, ctx.mesh
        kinds = {op.kind for op in ctx.ir.ops}

        if OpKind.ATTENTION in kinds:
            self._attention(ctx, budget)
        if OpKind.ATTENTION_DECODE in kinds:
            self._decode(ctx, budget)
        if OpKind.SSD_SCAN in kinds:
            self._ssd(ctx, budget)
        self._matmul(ctx, budget)

        # disjoint-lifetime sharing -> donation set
        ctx.plan.comm.donate_state = True
        self.record(ctx, "buffer_sharing", "donate params/opt/cache buffers",
                    "step N+1 state reuses step N's physical pages "
                    "(disjoint lifetimes across the step boundary)")

    # ------------------------------------------------------------------
    def _attention(self, ctx: PassContext, budget: int) -> None:
        arch, mesh = ctx.arch, ctx.mesh
        hd = arch.hd
        seq = ctx.shape.seq_len
        # start from the biggest MXU-aligned q tile and shrink to fit.
        # Causal workloads get SQUARE tiles: the kernel's packed-causal
        # grid (which skips the above-diagonal kv blocks, ~2x fewer
        # steps at long S) only engages when block_q == block_kv.
        if arch.causal:
            block_q = block_kv = 512
            while attention_tile_bytes(block_q, block_kv, hd) * 2 > budget \
                    and block_q > 128:
                block_q //= 2
                block_kv //= 2
        else:
            block_q, block_kv = 512, 1024
            while attention_tile_bytes(block_q, block_kv, hd) * 2 > budget:
                if block_kv > 128:
                    block_kv //= 2
                elif block_q > 128:
                    block_q //= 2
                else:
                    break
        block_q = min(block_q, _align_down(seq, 128))
        block_kv = min(block_kv, _align_down(seq, 128))
        vm = attention_tile_bytes(block_q, block_kv, hd)
        packed = arch.causal and block_q == block_kv
        bp = BlockPlan(
            kernel="flash_attention",
            blocks={"block_q": block_q, "block_kv": block_kv, "head_dim": hd},
            n_buffers=2,
            vmem_bytes=vm,
            grid_note=("packed-causal grid=(heads/TP, ceil(n/2), n+1), "
                       f"n=seq/{block_q}; above-diagonal kv blocks pruned"
                       if packed else
                       f"grid=(heads/TP, seq/{block_q}); kv streamed in "
                       f"{block_kv}-row banks, 2-deep pipeline"),
        )
        ctx.plan.partitions[bp.kernel] = bp
        ctx.template["plm.attention"].refine(
            self.name, **bp.blocks, n_buffers=2, vmem_bytes=vm)
        self.record(ctx, "flash_attention",
                    f"block_q={block_q} block_kv={block_kv}"
                    + (" (square: packed-causal grid)" if packed else ""),
                    f"2-bank working set {2*vm/2**20:.1f} MiB <= "
                    f"budget {budget/2**20:.0f} MiB; tiles MXU-aligned")

    def _decode(self, ctx: PassContext, budget: int) -> None:
        arch = ctx.arch
        hd = arch.hd
        # decode reads the whole cache once: wide kv tiles amortize the
        # grid overhead; q fits entirely (1 token x heads)
        block_kv = 2048
        q_bytes = arch.n_heads * hd * 2
        while (block_kv * hd * 2 * 2 + q_bytes) * 2 > budget and block_kv > 256:
            block_kv //= 2
        bp = BlockPlan(
            kernel="decode_attention",
            blocks={"block_kv": block_kv, "head_dim": hd},
            n_buffers=2,
            vmem_bytes=block_kv * hd * 2 * 2 + q_bytes,
            grid_note="grid=(kv_heads, cache_len/block_kv); online softmax "
                      "combine across grid steps",
        )
        ctx.plan.partitions[bp.kernel] = bp
        ctx.template["cache.kv"].refine(self.name, block_kv=block_kv)
        self.record(ctx, "decode_attention", f"block_kv={block_kv}",
                    "stream the session cache through VMEM in 2 banks")

    def _ssd(self, ctx: PassContext, budget: int) -> None:
        arch = ctx.arch
        chunk = 256
        hd, st = arch.ssm_head_dim, arch.ssm_state
        # working set per head-block: x(chunk,hd) B/C(chunk,st) state(hd,st)
        heads_block = 8
        per = (chunk * hd + 2 * chunk * st + hd * st * 2) * 4 * heads_block
        while per * 2 > budget and heads_block > 1:
            heads_block //= 2
            per //= 2
        bp = BlockPlan(
            kernel="ssd_scan",
            blocks={"chunk": chunk, "heads_block": heads_block,
                    "head_dim": hd, "state": st},
            n_buffers=2,
            vmem_bytes=per,
            grid_note="grid=(heads/heads_block, seq/chunk); carry = (hd,state) "
                      "running state in VMEM across chunk steps",
        )
        ctx.plan.partitions[bp.kernel] = bp
        ctx.template["plm.scan"].refine(self.name, **bp.blocks)
        self.record(ctx, "ssd_scan", f"chunk={chunk} heads_block={heads_block}",
                    "SSD duality: intra-chunk matmul (MXU) + inter-chunk "
                    "recurrence (VPU) with state resident in VMEM")

    def _matmul(self, ctx: PassContext, budget: int) -> None:
        bm, bk, bn = 512, 512, 512
        while matmul_tile_bytes(bm, bk, bn) * 2 > budget and bm > 128:
            bm //= 2
            bn //= 2
        bp = BlockPlan(
            kernel="tiled_matmul",
            blocks={"bm": bm, "bk": bk, "bn": bn},
            n_buffers=2,
            vmem_bytes=matmul_tile_bytes(bm, bk, bn),
            grid_note="grid=(M/bm, N/bn, K/bk); fp32 accumulator tile",
        )
        ctx.plan.partitions[bp.kernel] = bp
        ctx.template["plm.matmul"].refine(self.name, **bp.blocks)
        self.record(ctx, "tiled_matmul", f"{bm}x{bk}x{bn}",
                    f"2-bank {2*bp.vmem_bytes/2**20:.1f} MiB working set; "
                    "K-inner grid for accumulator reuse")
