"""Pass framework for the multi-level specialization flow (paper §4).

Each pass refines the :class:`~repro.core.plan.MemoryPlan` (and the
template components it configures) at one abstraction level, in the
paper's order:

  data_organization → layout → communication → local_partitioning → lowering

Passes are independent and ablatable: :class:`PassPipeline` can run any
prefix/subset, which is how ``benchmarks/bench_passes.py`` reproduces the
paper's flexibility-vs-specialization trade-off.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.costmodel import MeshModel
from repro.core.ir import ProgramIR
from repro.core.plan import MemoryPlan
from repro.core.template import MemoryTemplate


@dataclasses.dataclass
class PassContext:
    """Everything a pass may read/write."""

    arch: ArchConfig
    shape: ShapeConfig
    ir: ProgramIR
    mesh: MeshModel
    template: MemoryTemplate
    plan: MemoryPlan
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def target(self):
        return self.template.target

    @property
    def training(self) -> bool:
        return bool(self.ir.meta.get("training", self.shape.kind == "train"))


class Pass:
    name: str = "pass"

    def run(self, ctx: PassContext) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def record(self, ctx: PassContext, subject: str, decision: str, reason: str) -> None:
        ctx.plan.record(self.name, subject, decision, reason)


from repro.core.passes.data_organization import DataOrganizationPass  # noqa: E402
from repro.core.passes.layout import LayoutPass  # noqa: E402
from repro.core.passes.communication import CommunicationPass  # noqa: E402
from repro.core.passes.partitioning import LocalPartitioningPass  # noqa: E402

DEFAULT_PASSES = (
    DataOrganizationPass,
    LayoutPass,
    CommunicationPass,
    LocalPartitioningPass,
)

__all__ = [
    "Pass",
    "PassContext",
    "DEFAULT_PASSES",
    "DataOrganizationPass",
    "LayoutPass",
    "CommunicationPass",
    "LocalPartitioningPass",
]
