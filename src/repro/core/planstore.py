"""PlanStore — two-tier cache of frozen plan artifacts.

Tier 1 is an in-memory dict of :class:`~repro.core.plan.FrozenPlan`
views keyed by the *request* hash (arch × shape × mesh × target ×
passes × options).  Hits return the cached object itself — the artifact
is immutable, so no deepcopy is needed and a warm ``specialize()`` is
O(1).

Tier 2 is a content-addressed on-disk store::

    <plan_dir>/
        <content_hash>.json     # {"schema": N, "content_hash": h, "plan": {...}}
        by_key/<request_hash>   # text file holding the content hash

``plan_dir`` defaults to ``$REPRO_PLAN_DIR`` or ``~/.cache/repro/plans``
and can be overridden per call (e.g. a directory next to checkpoints so
the plan ships with the model).  Entries are written atomically
(tmp + ``os.replace``); reads tolerate truncated/corrupt/stale files by
treating them as misses (the flow simply recompiles).  The payload's
hash is re-verified on load, so a plan reloaded in a second process is
guaranteed bit-identical to what the first process compiled.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional

from repro.core.plan import (FrozenPlan, MemoryPlan, PLAN_SCHEMA_VERSION,
                             canonical_json)


def default_plan_dir() -> Path:
    env = os.environ.get("REPRO_PLAN_DIR", "")
    if env:
        return Path(env).expanduser()
    return Path.home() / ".cache" / "repro" / "plans"


class PlanStore:
    def __init__(self, plan_dir: Optional[str | Path] = None,
                 persist: bool = True):
        self.plan_dir = Path(plan_dir) if plan_dir else default_plan_dir()
        self.persist = persist
        self._mem: Dict[str, FrozenPlan] = {}
        self._stats = {"hits": 0, "disk_hits": 0, "misses": 0,
                       "corrupt": 0, "evictions": 0, "puts": 0}

    # -- tier-1 + tier-2 lookup ---------------------------------------
    def get(self, key_hash: str) -> Optional[FrozenPlan]:
        """Frozen view for a request key, or None (caller compiles)."""
        plan = self._mem.get(key_hash)
        if plan is not None:
            self._stats["hits"] += 1
            return plan
        plan = self._load_by_key(key_hash)
        if plan is not None:
            self._stats["disk_hits"] += 1
            self._mem[key_hash] = plan
            return plan
        self._stats["misses"] += 1
        return None

    def put(self, key_hash: str, plan: FrozenPlan) -> str:
        """Insert a freshly-compiled plan; returns its content hash."""
        if not isinstance(plan, FrozenPlan):
            plan = plan.freeze()
        self._mem[key_hash] = plan
        self._stats["puts"] += 1
        h = plan.content_hash()
        if self.persist:
            try:
                self._write_entry(plan, h)
                self._write_text(self.plan_dir / "by_key" / key_hash, h)
            except OSError:
                pass                    # cache dir unwritable -> memory-only
        return h

    # -- content-addressed access (checkpoint warm starts) ------------
    def save(self, plan: FrozenPlan) -> str:
        """Persist by content hash only (no request key)."""
        if not isinstance(plan, FrozenPlan):
            plan = plan.freeze()
        h = plan.content_hash()
        if self.persist:
            try:
                self._write_entry(plan, h)
            except OSError:
                pass
        return h

    def load(self, content_hash: str) -> Optional[FrozenPlan]:
        """Reload a persisted plan by its content hash (verified)."""
        return self._read_entry(self.plan_dir / f"{content_hash}.json",
                                expect_hash=content_hash)

    # -- maintenance ---------------------------------------------------
    def stats(self) -> Dict[str, int]:
        disk = 0
        if self.plan_dir.is_dir():
            disk = sum(1 for _ in self.plan_dir.glob("*.json"))
        return {**self._stats, "size": len(self._mem), "disk_size": disk}

    def clear(self, disk: bool = False) -> None:
        """Drop the memory tier (and optionally the on-disk entries)."""
        self._mem.clear()
        self._stats.update(hits=0, disk_hits=0, misses=0, corrupt=0,
                           evictions=0, puts=0)
        if disk and self.plan_dir.is_dir():
            for f in self.plan_dir.glob("*.json"):
                f.unlink(missing_ok=True)
            by_key = self.plan_dir / "by_key"
            if by_key.is_dir():
                for f in by_key.iterdir():
                    f.unlink(missing_ok=True)

    def evict(self, key_hash: str) -> bool:
        """Remove one request key from both tiers.

        The content file is deleted only when no *other* request key
        still references it — content-addressed entries can be shared
        (identical plans reached via different specialize args, or
        pinned by a checkpoint's ``plan_hash``).
        """
        found = self._mem.pop(key_hash, None) is not None
        ref = self.plan_dir / "by_key" / key_hash
        if ref.exists():
            try:
                h = ref.read_text().strip()
                ref.unlink(missing_ok=True)
                by_key = self.plan_dir / "by_key"
                still_referenced = any(
                    f.read_text().strip() == h for f in by_key.iterdir())
                if h and not still_referenced:
                    (self.plan_dir / f"{h}.json").unlink(missing_ok=True)
                found = True
            except OSError:
                pass
        if found:
            self._stats["evictions"] += 1
        return found

    # -- disk plumbing -------------------------------------------------
    def _write_entry(self, plan: FrozenPlan, content_hash: str) -> None:
        # always (re)write: the atomic replace makes this self-healing —
        # a corrupt entry under this hash is repaired by the recompile
        # that its own read-failure triggered
        entry = {"schema": PLAN_SCHEMA_VERSION, "content_hash": content_hash,
                 "plan": plan.to_dict()}
        self._write_text(self.plan_dir / f"{content_hash}.json",
                         json.dumps(entry, indent=1, default=str))

    def _write_text(self, path: Path, text: str) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)       # atomic: readers never see partials
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _load_by_key(self, key_hash: str) -> Optional[FrozenPlan]:
        ref = self.plan_dir / "by_key" / key_hash
        try:
            h = ref.read_text().strip()
        except OSError:
            return None
        if not h:
            self._stats["corrupt"] += 1
            return None
        return self._read_entry(self.plan_dir / f"{h}.json", expect_hash=h)

    def _read_entry(self, path: Path,
                    expect_hash: Optional[str] = None) -> Optional[FrozenPlan]:
        """Parse + verify one on-disk entry; any defect -> miss."""
        try:
            entry = json.loads(path.read_text())
            if entry.get("schema") != PLAN_SCHEMA_VERSION:
                self._stats["corrupt"] += 1
                return None
            # hash the parsed payload directly: the stored dict IS the
            # canonical to_dict() form (freeze/from_dict are lossless),
            # so this equals FrozenPlan.content_hash() at half the cost
            h = hashlib.sha256(
                canonical_json(entry["plan"]).encode()).hexdigest()
            if entry.get("content_hash") != h or \
                    (expect_hash is not None and h != expect_hash):
                self._stats["corrupt"] += 1
                return None
            plan = MemoryPlan.from_dict(entry["plan"]).freeze()
            object.__setattr__(plan, "_content_hash", h)
            return plan
        except OSError:
            return None
        except Exception:
            # truncated JSON, missing fields, stale schema details —
            # tolerate and recompile rather than crash the caller
            self._stats["corrupt"] += 1
            return None


# ---------------------------------------------------------------------
# per-directory store registry (the default store follows REPRO_PLAN_DIR,
# so tests can point specialize() at a tmpdir via the environment)
# ---------------------------------------------------------------------

_STORES: Dict[Path, PlanStore] = {}


def get_store(plan_dir: Optional[str | Path] = None) -> PlanStore:
    path = Path(plan_dir) if plan_dir else default_plan_dir()
    store = _STORES.get(path)
    if store is None:
        store = _STORES[path] = PlanStore(path)
    return store


def all_stores() -> tuple:
    """Every store this process has created (default + plan_dir= ones)."""
    return tuple(_STORES.values())


def request_key(*parts: Any) -> str:
    """Deterministic request hash from reprs of the specialize() args."""
    blob = canonical_json({"schema": PLAN_SCHEMA_VERSION,
                           "parts": [repr(p) for p in parts]})
    return hashlib.sha256(blob.encode()).hexdigest()
