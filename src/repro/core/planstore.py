"""PlanStore — two-tier cache of frozen plan artifacts.

Tier 1 is an in-memory dict of :class:`~repro.core.plan.FrozenPlan`
views keyed by the *request* hash (arch × shape × mesh × target ×
passes × options).  Hits return the cached object itself — the artifact
is immutable, so no deepcopy is needed and a warm ``specialize()`` is
O(1).

Tier 2 is a content-addressed on-disk store::

    <plan_dir>/
        <content_hash>.json     # {"schema": N, "content_hash": h, "plan": {...}}
        by_key/<request_hash>   # text file holding the content hash

``plan_dir`` defaults to ``$REPRO_PLAN_DIR`` or ``~/.cache/repro/plans``
and can be overridden per call (e.g. a directory next to checkpoints so
the plan ships with the model).  Entries are written atomically
(tmp + ``os.replace``); reads tolerate truncated/corrupt/stale files by
treating them as misses (the flow simply recompiles).  The payload's
hash is re-verified on load, so a plan reloaded in a second process is
guaranteed bit-identical to what the first process compiled.

The content-addressed tier is size-capped: entries accumulate across
schema bumps and flow-fingerprint changes (every one is a fresh content
hash), so each write triggers a lazy :meth:`PlanStore.gc` once the
entry count passes ``max_disk_entries`` (``$REPRO_PLAN_MAX_ENTRIES``,
default 256).  GC drops stale-schema entries first, then the
least-recently-used current ones (disk hits touch mtime); ``by_key``
refs go with their entry, dangling refs are dropped, refs to live
entries are LRU-capped at 4x the entry cap (fingerprint churn mints
new request hashes for identical content), and
``stats()["disk_size"]`` reflects the evictions.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional

from repro.core.plan import (FrozenPlan, MemoryPlan, PLAN_SCHEMA_VERSION,
                             canonical_json)


def default_plan_dir() -> Path:
    env = os.environ.get("REPRO_PLAN_DIR", "")
    if env:
        return Path(env).expanduser()
    return Path.home() / ".cache" / "repro" / "plans"


class PlanStore:
    def __init__(self, plan_dir: Optional[str | Path] = None,
                 persist: bool = True,
                 max_disk_entries: Optional[int] = None):
        self.plan_dir = Path(plan_dir) if plan_dir else default_plan_dir()
        self.persist = persist
        if max_disk_entries is None:
            env = os.environ.get("REPRO_PLAN_MAX_ENTRIES", "")
            max_disk_entries = int(env) if env else 256
        self.max_disk_entries = max_disk_entries or None   # 0 -> uncapped
        self._mem: Dict[str, FrozenPlan] = {}
        self._stats = {"hits": 0, "disk_hits": 0, "misses": 0,
                       "corrupt": 0, "evictions": 0, "gc_evictions": 0,
                       "puts": 0}

    # -- tier-1 + tier-2 lookup ---------------------------------------
    def get(self, key_hash: str) -> Optional[FrozenPlan]:
        """Frozen view for a request key, or None (caller compiles)."""
        plan = self._mem.get(key_hash)
        if plan is not None:
            self._stats["hits"] += 1
            return plan
        plan = self._load_by_key(key_hash)
        if plan is not None:
            self._stats["disk_hits"] += 1
            self._mem[key_hash] = plan
            return plan
        self._stats["misses"] += 1
        return None

    def put(self, key_hash: str, plan: FrozenPlan) -> str:
        """Insert a freshly-compiled plan; returns its content hash."""
        if not isinstance(plan, FrozenPlan):
            plan = plan.freeze()
        self._mem[key_hash] = plan
        self._stats["puts"] += 1
        h = plan.content_hash()
        if self.persist:
            try:
                self._write_entry(plan, h)
                self._write_text(self.plan_dir / "by_key" / key_hash, h)
                self._maybe_gc()
            except OSError:
                pass                    # cache dir unwritable -> memory-only
        return h

    # -- content-addressed access (checkpoint warm starts) ------------
    def save(self, plan: FrozenPlan) -> str:
        """Persist by content hash only (no request key)."""
        if not isinstance(plan, FrozenPlan):
            plan = plan.freeze()
        h = plan.content_hash()
        if self.persist:
            try:
                self._write_entry(plan, h)
                self._maybe_gc()
            except OSError:
                pass
        return h

    def load(self, content_hash: str) -> Optional[FrozenPlan]:
        """Reload a persisted plan by its content hash (verified)."""
        return self._read_entry(self.plan_dir / f"{content_hash}.json",
                                expect_hash=content_hash)

    # -- maintenance ---------------------------------------------------
    def stats(self) -> Dict[str, int]:
        disk = disk_bytes = 0
        if self.plan_dir.is_dir():
            for f in self.plan_dir.glob("*.json"):
                disk += 1
                try:
                    disk_bytes += f.stat().st_size
                except OSError:
                    pass
        return {**self._stats, "size": len(self._mem), "disk_size": disk,
                "disk_bytes": disk_bytes}

    def gc(self, max_entries: Optional[int] = None) -> int:
        """Shrink the content-addressed tier; returns entries removed.

        Stale-schema entries (accumulated across schema bumps) go first;
        then the oldest-mtime current entries beyond ``max_entries``
        (defaults to the store's cap).  An evicted entry takes its
        ``by_key`` refs with it, so the next request is a clean miss
        that recompiles and re-persists.
        """
        if not self.plan_dir.is_dir():
            return 0
        cap = self.max_disk_entries if max_entries is None else max_entries
        removed, live = 0, []
        dropped: set = set()
        for f in self.plan_dir.glob("*.json"):
            if self._entry_schema(f) != PLAN_SCHEMA_VERSION:
                removed += self._unlink(f)
                dropped.add(f.stem)
            else:
                live.append(f)

        def mtime(f):
            try:
                return f.stat().st_mtime
            except OSError:
                return 0.0

        if cap and len(live) > cap:
            live.sort(key=lambda f: (mtime(f), f.name))
            for f in live[:len(live) - cap]:
                removed += self._unlink(f)
                dropped.add(f.stem)
        # by_key hygiene, one pass: refs of just-dropped or missing
        # entries go, then the survivors are LRU-trimmed to 4x the entry
        # cap (reads touch mtime) — refs are tiny but unbounded, since
        # every flow-fingerprint change mints a fresh request hash that
        # can point at a still-live content entry.
        by_key = self.plan_dir / "by_key"
        if by_key.is_dir():
            refs = []
            for ref in by_key.iterdir():
                try:
                    h = ref.read_text().strip()
                except OSError:
                    continue
                if (not h or h in dropped
                        or not (self.plan_dir / f"{h}.json").exists()):
                    self._unlink(ref)
                else:
                    refs.append(ref)
            if cap and len(refs) > 4 * cap:
                refs.sort(key=lambda f: (mtime(f), f.name))
                for ref in refs[:len(refs) - 4 * cap]:
                    self._unlink(ref)
        self._stats["gc_evictions"] += removed
        return removed

    @staticmethod
    def _entry_schema(f: Path) -> Optional[int]:
        """The entry's schema stamp, from the file's head only.

        Entries are written with ``schema`` as the first field, so a
        64-byte read answers the (hot: every over-cap put) GC question
        without parsing multi-KB plan payloads; foreign layouts fall
        back to a full parse.
        """
        try:
            with f.open() as fh:
                head = fh.read(64)
        except OSError:
            return None
        m = re.search(r'"schema":\s*(-?\d+)', head)
        if m:
            return int(m.group(1))
        try:
            entry = json.loads(f.read_text())
        except (OSError, ValueError):
            return None
        # non-dict payloads (stray arrays/strings) are corrupt -> stale
        return entry.get("schema") if isinstance(entry, dict) else None

    def _maybe_gc(self) -> None:
        if not self.max_disk_entries or not self.plan_dir.is_dir():
            return
        n = sum(1 for _ in self.plan_dir.glob("*.json"))
        if n > self.max_disk_entries:
            self.gc()
            return
        # ref churn without entry churn (fingerprint changes remapping to
        # identical content) must also trigger the trim
        by_key = self.plan_dir / "by_key"
        if by_key.is_dir():
            nrefs = sum(1 for _ in by_key.iterdir())
            if nrefs > 4 * self.max_disk_entries:
                self.gc()

    @staticmethod
    def _unlink(path: Path) -> int:
        try:
            path.unlink(missing_ok=True)
            return 1
        except OSError:
            return 0

    def clear(self, disk: bool = False) -> None:
        """Drop the memory tier (and optionally the on-disk entries)."""
        self._mem.clear()
        self._stats.update(hits=0, disk_hits=0, misses=0, corrupt=0,
                           evictions=0, gc_evictions=0, puts=0)
        if disk and self.plan_dir.is_dir():
            for f in self.plan_dir.glob("*.json"):
                f.unlink(missing_ok=True)
            by_key = self.plan_dir / "by_key"
            if by_key.is_dir():
                for f in by_key.iterdir():
                    f.unlink(missing_ok=True)

    def evict(self, key_hash: str) -> bool:
        """Remove one request key from both tiers.

        The content file is deleted only when no *other* request key
        still references it — content-addressed entries can be shared
        (identical plans reached via different specialize args, or
        pinned by a checkpoint's ``plan_hash``).
        """
        found = self._mem.pop(key_hash, None) is not None
        ref = self.plan_dir / "by_key" / key_hash
        if ref.exists():
            try:
                h = ref.read_text().strip()
                ref.unlink(missing_ok=True)
                by_key = self.plan_dir / "by_key"
                still_referenced = any(
                    f.read_text().strip() == h for f in by_key.iterdir())
                if h and not still_referenced:
                    (self.plan_dir / f"{h}.json").unlink(missing_ok=True)
                found = True
            except OSError:
                pass
        if found:
            self._stats["evictions"] += 1
        return found

    # -- disk plumbing -------------------------------------------------
    def _write_entry(self, plan: FrozenPlan, content_hash: str) -> None:
        # always (re)write: the atomic replace makes this self-healing —
        # a corrupt entry under this hash is repaired by the recompile
        # that its own read-failure triggered
        entry = {"schema": PLAN_SCHEMA_VERSION, "content_hash": content_hash,
                 "plan": plan.to_dict()}
        self._write_text(self.plan_dir / f"{content_hash}.json",
                         json.dumps(entry, indent=1, default=str))

    def _write_text(self, path: Path, text: str) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, path)       # atomic: readers never see partials
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _load_by_key(self, key_hash: str) -> Optional[FrozenPlan]:
        ref = self.plan_dir / "by_key" / key_hash
        try:
            h = ref.read_text().strip()
        except OSError:
            return None
        if not h:
            self._stats["corrupt"] += 1
            return None
        plan = self._read_entry(self.plan_dir / f"{h}.json", expect_hash=h)
        if plan is not None:
            try:
                os.utime(ref)           # LRU touch for the by_key trim
            except OSError:
                pass
        return plan

    def _check_entry(self, path: Path, expect_hash: Optional[str] = None):
        """``(status, entry, hash)`` for one on-disk entry — THE entry
        verification recipe (``_read_entry`` loads through it and the
        ``plan verify`` CLI reports through it, so the two can never
        diverge).  ``status``: ``"ok"`` | ``"stale-schema"`` |
        ``"corrupt"`` | ``"missing"`` (unreadable file).  The hash is
        computed over the parsed payload directly: the stored dict IS
        the canonical ``to_dict()`` form (freeze/from_dict are
        lossless), so this equals ``FrozenPlan.content_hash()`` at half
        the cost."""
        try:
            entry = json.loads(path.read_text())
        except OSError:
            return "missing", None, None
        except ValueError:
            return "corrupt", None, None
        if not isinstance(entry, dict) or "plan" not in entry:
            return "corrupt", entry, None
        if entry.get("schema") != PLAN_SCHEMA_VERSION:
            return "stale-schema", entry, None
        try:
            h = hashlib.sha256(
                canonical_json(entry["plan"]).encode()).hexdigest()
        except Exception:
            return "corrupt", entry, None
        if entry.get("content_hash") != h or \
                (expect_hash is not None and h != expect_hash):
            return "corrupt", entry, h
        return "ok", entry, h

    def verify_entry(self, path: Path) -> str:
        """One entry's health for inspection tools: ``"ok"`` |
        ``"stale-schema"`` | ``"corrupt"`` (an entry whose filename
        does not match its content hash, or an unreadable file, is
        corrupt — it can never be loaded under its own name)."""
        status, _, h = self._check_entry(path)
        if status == "missing" or (status == "ok" and h != path.stem):
            return "corrupt"
        return status

    def _read_entry(self, path: Path,
                    expect_hash: Optional[str] = None) -> Optional[FrozenPlan]:
        """Parse + verify one on-disk entry; any defect -> miss."""
        status, entry, h = self._check_entry(path, expect_hash)
        if status == "missing":
            return None
        if status != "ok":
            self._stats["corrupt"] += 1
            return None
        try:
            plan = MemoryPlan.from_dict(entry["plan"]).freeze()
            object.__setattr__(plan, "_content_hash", h)
            try:
                os.utime(path)          # LRU touch: gc evicts oldest-mtime
            except OSError:
                pass
            return plan
        except Exception:
            # payload fields the current plan schema cannot rebuild —
            # tolerate and recompile rather than crash the caller
            self._stats["corrupt"] += 1
            return None


# ---------------------------------------------------------------------
# per-directory store registry (the default store follows REPRO_PLAN_DIR,
# so tests can point specialize() at a tmpdir via the environment)
# ---------------------------------------------------------------------

_STORES: Dict[Path, PlanStore] = {}


def get_store(plan_dir: Optional[str | Path] = None) -> PlanStore:
    path = Path(plan_dir) if plan_dir else default_plan_dir()
    store = _STORES.get(path)
    if store is None:
        store = _STORES[path] = PlanStore(path)
    return store


def all_stores() -> tuple:
    """Every store this process has created (default + plan_dir= ones)."""
    return tuple(_STORES.values())


def request_key(*parts: Any) -> str:
    """Deterministic request hash from reprs of the specialize() args."""
    blob = canonical_json({"schema": PLAN_SCHEMA_VERSION,
                           "parts": [repr(p) for p in parts]})
    return hashlib.sha256(blob.encode()).hexdigest()
