"""MemoryPlan — the specialized template instance the flow produces.

Paper §4: each phase progressively refines the template; the *result* of
the whole flow is a fully-parameterized memory architecture plus a
rewritten IR.  Here the result is a :class:`MemoryPlan`:

* per-tensor :class:`Placement` (residency + mesh sharding + layout),
* a :class:`CommPlan` (collective schedule, prefetch, compression),
* per-kernel :class:`BlockPlan` (Pallas BlockSpec tiles = PLM banks),
* the refined :class:`~repro.core.template.MemoryTemplate` summary,
* a decision log (pass → decision → reason) for ablation/inspection.

The plan is JSON-serializable: it is the artifact a deployment would ship
next to the model config, and the lowering pass consumes *only* the plan
(the model code never sees the passes — the paper's "accelerator is mostly
unaware of the data organization").
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.ir import MemorySpace


AxisAssign = Tuple[Optional[Any], ...]  # per-dim: mesh axis name, tuple, or None


@dataclasses.dataclass
class Placement:
    """Where one logical tensor lives (data-organization + layout output)."""

    residency: str = MemorySpace.HBM.value
    # one entry per tensor dim: None | "data" | "model" | ("pod","data") ...
    spec: AxisAssign = ()
    dtype: Optional[str] = None          # layout pass may override (bf16/f32)
    pad_to: Optional[Tuple[int, ...]] = None  # MXU-alignment padding
    layout: Dict[str, Any] = dataclasses.field(default_factory=dict)
    decided_by: List[str] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CommPlan:
    """Communication-phase output (prefetcher + channel configuration)."""

    grad_schedule: str = "reduce_scatter"     # or "all_reduce"
    compress_pod_grads: bool = False          # int8+error-feedback on DCN axis
    compress_grads: bool = False              # int8+EF on the full DP reduction
    compress_bits: int = 8
    microbatches: int = 1                     # grad-accum for comm overlap
    prefetch_depth: int = 2                   # host input pipeline depth
    overlap_collectives: bool = True          # async collective scheduling
    remat_policy: str = "none"                # none|dots|full
    donate_state: bool = True                 # buffer sharing (disjoint lifetimes)
    notes: List[str] = dataclasses.field(default_factory=list)

    @property
    def compresses_gradients(self) -> bool:
        """Any EF-compressed gradient path on (lowering adds an EF state)."""
        return self.compress_pod_grads or self.compress_grads


@dataclasses.dataclass
class BlockPlan:
    """Local-partitioning output for one kernel (multi-bank PLM config)."""

    kernel: str                                # "flash_attention" | ...
    blocks: Dict[str, int] = dataclasses.field(default_factory=dict)
    n_buffers: int = 2                         # banking degree
    vmem_bytes: int = 0                        # modeled working set
    grid_note: str = ""


@dataclasses.dataclass
class MemoryPlan:
    """The fully-specialized memory architecture for (arch × shape × mesh)."""

    arch: str
    shape: str
    mesh_axes: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    target: str = "tpu-v5e"

    # logical-axis -> mesh-axis rules (data organization output)
    axis_rules: Dict[str, Any] = dataclasses.field(default_factory=dict)
    placements: Dict[str, Placement] = dataclasses.field(default_factory=dict)
    comm: CommPlan = dataclasses.field(default_factory=CommPlan)
    partitions: Dict[str, BlockPlan] = dataclasses.field(default_factory=dict)
    template_summary: Dict[str, Any] = dataclasses.field(default_factory=dict)
    use_pallas: str = "auto"                   # auto|on|off
    estimates: Dict[str, float] = dataclasses.field(default_factory=dict)
    # optimizer-state "technology" decisions (data-organization ladder)
    opt: Dict[str, Any] = dataclasses.field(
        default_factory=lambda: {"moment_dtype": "float32", "master_weights": True})

    # decision log: (pass, subject, decision, reason)
    log: List[Tuple[str, str, str, str]] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------------
    def record(self, pass_name: str, subject: str, decision: str, reason: str) -> None:
        self.log.append((pass_name, subject, decision, reason))

    def placement(self, name: str) -> Placement:
        if name not in self.placements:
            self.placements[name] = Placement()
        return self.placements[name]

    def sharding_spec(self, logical_axes: Sequence[Optional[str]]) -> AxisAssign:
        """Resolve logical axes through the plan's axis rules."""
        out = []
        used: set = set()
        for ax in logical_axes:
            assign = self.axis_rules.get(ax) if ax is not None else None
            if assign is None:
                out.append(None)
                continue
            names = (assign,) if isinstance(assign, str) else tuple(assign)
            names = tuple(n for n in names if n not in used)
            used.update(names)
            if not names:
                out.append(None)
            elif len(names) == 1:
                out.append(names[0])
            else:
                out.append(names)
        return tuple(out)

    # ------------------------------------------------------------------
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2, default=str)

    @classmethod
    def from_json(cls, s: str) -> "MemoryPlan":
        d = json.loads(s)
        d["placements"] = {
            k: Placement(**{**v, "spec": _untuple(v["spec"])})
            for k, v in d["placements"].items()
        }
        d["comm"] = CommPlan(**d["comm"])
        d["partitions"] = {k: BlockPlan(**v) for k, v in d["partitions"].items()}
        d["mesh_axes"] = tuple(d["mesh_axes"])
        d["mesh_shape"] = tuple(d["mesh_shape"])
        d["log"] = [tuple(x) for x in d["log"]]
        return cls(**d)


def _untuple(spec: Any) -> AxisAssign:
    out = []
    for s in spec:
        if isinstance(s, list):
            out.append(tuple(s))
        else:
            out.append(s)
    return tuple(out)
