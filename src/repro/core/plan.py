"""MemoryPlan — the specialized template instance the flow produces.

Paper §4: each phase progressively refines the template; the *result* of
the whole flow is a fully-parameterized memory architecture plus a
rewritten IR.  Two classes split the lifecycle:

* :class:`MemoryPlan` — the build-time **builder** the passes mutate
  (``record()``, ``placement()``, dict/list containers);
* :class:`FrozenPlan` — the immutable **artifact** ``specialize()``
  returns and every consumer (lowering, trainer, serve engine,
  checkpointer) reads.  Frozen dataclasses, tuple-ified containers,
  ``MappingProxyType`` dicts; hashable via a stable
  :meth:`FrozenPlan.content_hash` over the canonical JSON.

Both hold:

* per-tensor :class:`Placement` (residency + mesh sharding + layout),
* a :class:`CommPlan` (collective schedule, prefetch, compression),
* per-kernel :class:`BlockPlan` (Pallas BlockSpec tiles = PLM banks),
* the refined :class:`~repro.core.template.MemoryTemplate` summary,
* a decision log (pass → decision → reason) for ablation/inspection.

The frozen plan is JSON-serializable: it is the artifact a deployment
ships next to the model config (persisted content-addressed by
:mod:`repro.core.planstore`), and the lowering pass consumes *only* the
plan (the model code never sees the passes — the paper's "accelerator is
mostly unaware of the data organization").
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from types import MappingProxyType
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.ir import MemorySpace


AxisAssign = Tuple[Optional[Any], ...]  # per-dim: mesh axis name, tuple, or None

#: bumped whenever the serialized plan layout changes incompatibly; the
#: plan store refuses (and recompiles past) entries from another schema.
PLAN_SCHEMA_VERSION = 1


# =====================================================================
# canonicalization helpers (shared by to_json / content_hash / freeze)
# =====================================================================

def _plain(obj: Any) -> Any:
    """Recursively convert to plain JSON-able types (dict/list/scalars)."""
    if isinstance(obj, (MappingProxyType, dict)):
        return {str(k): _plain(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_plain(v) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _plain(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    return obj


def _deep_freeze(obj: Any) -> Any:
    """dicts -> MappingProxyType, lists -> tuples, recursively."""
    if isinstance(obj, (MappingProxyType, dict)):
        return MappingProxyType({k: _deep_freeze(v) for k, v in obj.items()})
    if isinstance(obj, (list, tuple)):
        return tuple(_deep_freeze(v) for v in obj)
    return obj


def _deep_thaw(obj: Any) -> Any:
    """Inverse of :func:`_deep_freeze` (tuples stay tuples only where the
    mutable schema expects them; containers become dict/list)."""
    if isinstance(obj, (MappingProxyType, dict)):
        return {k: _deep_thaw(v) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return [_deep_thaw(v) for v in obj]
    return obj


def canonical_json(d: Dict[str, Any]) -> str:
    """Deterministic encoding: sorted keys, compact separators."""
    return json.dumps(_plain(d), sort_keys=True, separators=(",", ":"),
                      default=str)


def _sharding_spec(axis_rules: Mapping[str, Any],
                   logical_axes: Sequence[Optional[str]]) -> AxisAssign:
    out = []
    used: set = set()
    for ax in logical_axes:
        assign = axis_rules.get(ax) if ax is not None else None
        if assign is None:
            out.append(None)
            continue
        names = (assign,) if isinstance(assign, str) else tuple(assign)
        names = tuple(n for n in names if n not in used)
        used.update(names)
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return tuple(out)


def _padded_sizes(estimates: Mapping[str, Any]) -> Tuple[int, int, int, int]:
    return (int(estimates.get("vocab_padded", 0)),
            int(estimates.get("heads_padded", 0)),
            int(estimates.get("ssm_heads_padded", 0)),
            int(estimates.get("kv_heads_padded", 0)))


def diff_decision_logs(old: Sequence[Tuple[str, str, str, str]],
                       new: Sequence[Tuple[str, str, str, str]]) -> List[str]:
    """Human-readable diff of two decision logs, keyed by (pass, subject).

    Used when a restarted job recompiles and the fresh plan's hash does
    not match the one stored with the checkpoint: the diff says *which
    decisions moved*, not just that something did.
    """
    def index(log):
        d: Dict[Tuple[str, str], List[Tuple[str, str]]] = {}
        for p, subj, dec, why in log:
            d.setdefault((p, subj), []).append((dec, why))
        return d

    a, b = index(old), index(new)
    lines: List[str] = []
    for key in sorted(set(a) | set(b)):
        pa, pb = a.get(key), b.get(key)
        if pa == pb:
            continue
        p, subj = key
        if pa is None:
            lines.append(f"+ {p}/{subj}: {pb[-1][0]}")
        elif pb is None:
            lines.append(f"- {p}/{subj}: {pa[-1][0]}")
        else:
            lines.append(f"~ {p}/{subj}: {pa[-1][0]} -> {pb[-1][0]}")
    return lines


# =====================================================================
# mutable build-time pieces (what the passes refine)
# =====================================================================

@dataclasses.dataclass
class Placement:
    """Where one logical tensor lives (data-organization + layout output)."""

    residency: str = MemorySpace.HBM.value
    # one entry per tensor dim: None | "data" | "model" | ("pod","data") ...
    spec: AxisAssign = ()
    dtype: Optional[str] = None          # layout pass may override (bf16/f32)
    pad_to: Optional[Tuple[int, ...]] = None  # MXU-alignment padding
    layout: Dict[str, Any] = dataclasses.field(default_factory=dict)
    decided_by: List[str] = dataclasses.field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CommPlan:
    """Communication-phase output (prefetcher + channel configuration)."""

    grad_schedule: str = "reduce_scatter"     # or "all_reduce"
    compress_pod_grads: bool = False          # int8+error-feedback on DCN axis
    compress_grads: bool = False              # int8+EF on the full DP reduction
    compress_lowered: bool = False            # codes (not f32) cross the wire
    compress_bits: int = 8
    combine_topology: str = "flat"            # decode softmax combine: flat|ring|bidir
    microbatches: int = 1                     # grad-accum for comm overlap
    prefetch_depth: int = 2                   # host input pipeline depth
    overlap_collectives: bool = True          # async collective scheduling
    remat_policy: str = "none"                # none|dots|full
    donate_state: bool = True                 # buffer sharing (disjoint lifetimes)
    notes: List[str] = dataclasses.field(default_factory=list)

    @property
    def compresses_gradients(self) -> bool:
        """Any EF-compressed gradient path on (lowering adds an EF state)."""
        return self.compress_pod_grads or self.compress_grads


@dataclasses.dataclass
class BlockPlan:
    """Local-partitioning output for one kernel (multi-bank PLM config)."""

    kernel: str                                # "flash_attention" | ...
    blocks: Dict[str, int] = dataclasses.field(default_factory=dict)
    n_buffers: int = 2                         # banking degree
    vmem_bytes: int = 0                        # modeled working set
    grid_note: str = ""


# =====================================================================
# frozen artifact pieces (what consumers read)
# =====================================================================

@dataclasses.dataclass(frozen=True)
class FrozenPlacement:
    residency: str
    spec: AxisAssign
    dtype: Optional[str]
    pad_to: Optional[Tuple[int, ...]]
    layout: Mapping[str, Any]
    decided_by: Tuple[str, ...]

    __hash__ = None  # type: ignore[assignment]  # hash the plan, not pieces

    def to_json(self) -> Dict[str, Any]:
        return _plain(self)


@dataclasses.dataclass(frozen=True)
class FrozenCommPlan:
    grad_schedule: str
    compress_pod_grads: bool
    compress_grads: bool
    compress_lowered: bool
    compress_bits: int
    combine_topology: str
    microbatches: int
    prefetch_depth: int
    overlap_collectives: bool
    remat_policy: str
    donate_state: bool
    notes: Tuple[str, ...]

    __hash__ = None  # type: ignore[assignment]

    @property
    def compresses_gradients(self) -> bool:
        return self.compress_pod_grads or self.compress_grads


@dataclasses.dataclass(frozen=True)
class FrozenBlockPlan:
    kernel: str
    blocks: Mapping[str, int]
    n_buffers: int
    vmem_bytes: int
    grid_note: str

    __hash__ = None  # type: ignore[assignment]


@dataclasses.dataclass(frozen=True)
class FrozenPlan:
    """The immutable, hashable, shippable plan artifact.

    Returned by ``specialize()`` and shared structurally between all
    consumers — cache hits hand out *the same object* (no deepcopy), so
    mutation raises instead of silently poisoning the cache.
    """

    arch: str
    shape: str
    mesh_axes: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    target: str
    shape_kind: str
    seq_len: int
    global_batch: int
    axis_rules: Mapping[str, Any]
    placements: Mapping[str, FrozenPlacement]
    comm: FrozenCommPlan
    partitions: Mapping[str, FrozenBlockPlan]
    template_summary: Mapping[str, Any]
    use_pallas: str
    estimates: Mapping[str, Any]
    opt: Mapping[str, Any]
    log: Tuple[Tuple[str, str, str, str], ...]

    # ------------------------------------------------------------------
    def sharding_spec(self, logical_axes: Sequence[Optional[str]]) -> AxisAssign:
        """Resolve logical axes through the plan's axis rules."""
        return _sharding_spec(self.axis_rules, logical_axes)

    def padded_sizes(self) -> Tuple[int, int, int, int]:
        """(vocab, heads, ssm_heads, kv_heads) the layout pass padded to
        (0 = unpadded) — the sizes ``init_params``/``init_cache`` need to
        materialize state matching this plan."""
        return _padded_sizes(self.estimates)

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return _plain(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, default=str)

    def content_hash(self) -> str:
        """sha256 over the canonical JSON — stable across processes,
        across ``to_json``/``from_json`` round-trips, and independent of
        dict insertion order."""
        cached = self.__dict__.get("_content_hash")
        if cached is None:
            cached = hashlib.sha256(
                canonical_json(self.to_dict()).encode()).hexdigest()
            object.__setattr__(self, "_content_hash", cached)
        return cached

    def __hash__(self) -> int:
        return hash(self.content_hash())

    def thaw(self) -> "MemoryPlan":
        """A fresh mutable builder with this plan's contents (the escape
        hatch for callers that genuinely need to edit a plan)."""
        return MemoryPlan.from_dict(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "FrozenPlan":
        return MemoryPlan.from_json(s).freeze()


# =====================================================================
# the builder
# =====================================================================

@dataclasses.dataclass
class MemoryPlan:
    """Build-time mutable plan the pass pipeline refines; ``freeze()``
    yields the :class:`FrozenPlan` artifact consumers receive."""

    arch: str
    shape: str
    mesh_axes: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    target: str = "tpu-v5e"

    # the workload dims the plan was specialized for — carried in the
    # artifact so consumers (serve engine KV sizing, batching limits)
    # never need the shape registry at deploy time
    shape_kind: str = ""
    seq_len: int = 0
    global_batch: int = 0

    # logical-axis -> mesh-axis rules (data organization output)
    axis_rules: Dict[str, Any] = dataclasses.field(default_factory=dict)
    placements: Dict[str, Placement] = dataclasses.field(default_factory=dict)
    comm: CommPlan = dataclasses.field(default_factory=CommPlan)
    partitions: Dict[str, BlockPlan] = dataclasses.field(default_factory=dict)
    template_summary: Dict[str, Any] = dataclasses.field(default_factory=dict)
    use_pallas: str = "auto"                   # auto|on|off
    estimates: Dict[str, float] = dataclasses.field(default_factory=dict)
    # optimizer-state "technology" decisions (data-organization ladder)
    opt: Dict[str, Any] = dataclasses.field(
        default_factory=lambda: {"moment_dtype": "float32", "master_weights": True})

    # decision log: (pass, subject, decision, reason)
    log: List[Tuple[str, str, str, str]] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------------
    def record(self, pass_name: str, subject: str, decision: str, reason: str) -> None:
        self.log.append((pass_name, subject, decision, reason))

    def placement(self, name: str) -> Placement:
        if name not in self.placements:
            self.placements[name] = Placement()
        return self.placements[name]

    def sharding_spec(self, logical_axes: Sequence[Optional[str]]) -> AxisAssign:
        """Resolve logical axes through the plan's axis rules."""
        return _sharding_spec(self.axis_rules, logical_axes)

    def padded_sizes(self) -> Tuple[int, int, int, int]:
        """See :meth:`FrozenPlan.padded_sizes`."""
        return _padded_sizes(self.estimates)

    # ------------------------------------------------------------------
    def freeze(self) -> FrozenPlan:
        """The immutable artifact view (tuples + MappingProxyType).

        Field lists are derived from the builder dataclasses, so a field
        added to Placement/CommPlan/BlockPlan/MemoryPlan fails loudly
        here (its frozen counterpart lacks it) instead of silently
        vanishing from the artifact and its content hash.
        """
        def freeze_as(frozen_cls, obj):
            return frozen_cls(**{
                f.name: _deep_freeze(getattr(obj, f.name))
                for f in dataclasses.fields(obj)})

        kw = {f.name: _deep_freeze(getattr(self, f.name))
              for f in dataclasses.fields(self)}
        kw["placements"] = MappingProxyType({
            k: freeze_as(FrozenPlacement, p)
            for k, p in self.placements.items()})
        kw["comm"] = freeze_as(FrozenCommPlan, self.comm)
        kw["partitions"] = MappingProxyType({
            k: freeze_as(FrozenBlockPlan, b)
            for k, b in self.partitions.items()})
        kw["mesh_shape"] = tuple(int(x) for x in self.mesh_shape)
        kw["seq_len"] = int(self.seq_len)
        kw["global_batch"] = int(self.global_batch)
        return FrozenPlan(**kw)

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return _plain(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, default=str)

    def content_hash(self) -> str:
        """Same hash the frozen artifact reports (freeze is canonicalizing)."""
        return self.freeze().content_hash()

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MemoryPlan":
        d = dict(_deep_thaw(d))
        d["placements"] = {
            k: Placement(**{**v,
                            "spec": _untuple(v["spec"]),
                            "pad_to": (None if v.get("pad_to") is None
                                       else tuple(v["pad_to"]))})
            for k, v in d["placements"].items()
        }
        d["comm"] = CommPlan(**d["comm"])
        d["partitions"] = {k: BlockPlan(**v) for k, v in d["partitions"].items()}
        d["mesh_axes"] = tuple(d["mesh_axes"])
        d["mesh_shape"] = tuple(d["mesh_shape"])
        # axis-rule assignments serialize as JSON arrays; the live form
        # is tuples (equality + hashing depend on it)
        d["axis_rules"] = {k: _untuple_one(v) for k, v in d["axis_rules"].items()}
        d["log"] = [tuple(x) for x in d["log"]]
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "MemoryPlan":
        return cls.from_dict(json.loads(s))


def _untuple_one(v: Any) -> Any:
    return tuple(v) if isinstance(v, (list, tuple)) else v


def _untuple(spec: Any) -> AxisAssign:
    out = []
    for s in spec:
        if isinstance(s, (list, tuple)):
            out.append(tuple(s))
        else:
            out.append(s)
    return tuple(out)
