"""Core library: the paper's contribution.

* Memory IR + domain-specific annotations (``ir``, ``annotations``)
* The domain-specific memory template (``template``)
* The multi-level specialization flow (``pipeline`` + ``passes``)
* The resulting specialized-template artifact (``plan``)
"""

from repro.core.ir import (
    AccessPattern,
    Lifetime,
    MemorySpace,
    OpDecl,
    OpKind,
    ProgramIR,
    Reuse,
    Role,
    TensorDecl,
)
from repro.core.pipeline import (PassPipeline, clear_plan_cache,
                                 plan_cache_stats, specialize)
from repro.core.plan import (PLAN_SCHEMA_VERSION, BlockPlan, CommPlan,
                             FrozenBlockPlan, FrozenCommPlan, FrozenPlacement,
                             FrozenPlan, MemoryPlan, Placement,
                             diff_decision_logs)
from repro.core.planstore import PlanStore, default_plan_dir, get_store
from repro.core.template import Component, ComponentKind, MemoryTemplate

__all__ = [
    "AccessPattern", "Lifetime", "MemorySpace", "OpDecl", "OpKind",
    "ProgramIR", "Reuse", "Role", "TensorDecl", "PassPipeline", "specialize",
    "clear_plan_cache", "plan_cache_stats",
    "BlockPlan", "CommPlan", "MemoryPlan", "Placement", "Component",
    "ComponentKind", "MemoryTemplate",
    "FrozenPlan", "FrozenPlacement", "FrozenCommPlan", "FrozenBlockPlan",
    "PLAN_SCHEMA_VERSION", "diff_decision_logs",
    "PlanStore", "default_plan_dir", "get_store",
]
