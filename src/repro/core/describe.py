"""Build the Memory IR for an (architecture × shape) workload.

This is the flow's *frontend*: it walks the model family and emits one
annotated :class:`TensorDecl` per logical tensor class plus coarse
:class:`OpDecl` entries with FLOP/byte estimates.  In the paper this
information arrives via source-level annotations; here the annotation
helpers in :mod:`repro.core.annotations` encode the same knowledge.

Logical axis vocabulary (consumed by the data-organization pass):
  params:       layers, embed, heads, kv_heads, head_dim, ff, vocab,
                experts, ssm_inner
  activations:  batch, seq, act_embed, act_heads, act_ff, act_experts
  caches:       batch, seq_kv, kv_heads, head_dim / ssm_heads
"""

from __future__ import annotations

from typing import Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import annotations as A
from repro.core.ir import OpDecl, OpKind, ProgramIR


def describe_program(arch: ArchConfig, shape: ShapeConfig,
                     training: bool | None = None) -> ProgramIR:
    training = shape.kind == "train" if training is None else training
    ir = ProgramIR(name=f"{arch.name}@{shape.name}")
    L, d, V = arch.n_layers, arch.d_model, arch.vocab_size
    B, S = shape.global_batch, shape.seq_len
    hd = arch.hd
    H, K = arch.n_heads, arch.n_kv_heads
    T = shape.tokens                      # tokens processed per step
    Sctx = S if shape.kind != "train" else S  # context length

    # ---------------- parameters ----------------------------------------
    ir.declare(A.weight("embed", (V, d), ("vocab", "embed")))
    if not arch.tie_embeddings:
        ir.declare(A.weight("lm_head", (d, V), ("embed", "vocab")))

    if arch.has_attention:
        ir.declare(A.weight("attn.wq", (L, d, H * hd), ("layers", "embed", "heads")))
        ir.declare(A.weight("attn.wk", (L, d, K * hd), ("layers", "embed", "kv_heads")))
        ir.declare(A.weight("attn.wv", (L, d, K * hd), ("layers", "embed", "kv_heads")))
        ir.declare(A.weight("attn.wo", (L, H * hd, d), ("layers", "heads", "embed")))

    if arch.has_ssm:
        di = arch.d_inner
        g, st = arch.ssm_n_groups, arch.ssm_state
        nh = arch.ssm_heads
        in_dim = 2 * di + 2 * g * st + nh
        ir.declare(A.weight("ssm.in_proj", (L, d, in_dim), ("layers", "embed", "ssm_inner")))
        ir.declare(A.weight("ssm.conv", (L, arch.ssm_conv, di + 2 * g * st),
                            ("layers", None, "ssm_inner")))
        ir.declare(A.weight("ssm.out_proj", (L, di, d), ("layers", "ssm_inner", "embed")))
        ir.declare(A.weight("ssm.A", (L, nh), ("layers", None), dtype="float32"))

    if arch.is_moe:
        Lm = L // arch.moe_interleave
        Ld = L - Lm
        ff = arch.moe_d_ff or arch.d_ff
        E = arch.n_experts
        ir.declare(A.weight("moe.wi", (Lm, E, d, 2 * ff),
                            ("layers", "experts", "embed", "ff"), expert=True))
        ir.declare(A.weight("moe.wo", (Lm, E, ff, d),
                            ("layers", "experts", "ff", "embed"), expert=True))
        ir.declare(A.weight("moe.router", (Lm, d, E), ("layers", "embed", "act_experts")))
        if arch.n_shared_experts:
            ir.declare(A.weight("moe.shared_wi", (Lm, d, 2 * ff * arch.n_shared_experts),
                                ("layers", "embed", "ff")))
            ir.declare(A.weight("moe.shared_wo", (Lm, ff * arch.n_shared_experts, d),
                                ("layers", "ff", "embed")))
        if Ld:
            ir.declare(A.weight("mlp.wi", (Ld, d, 2 * arch.d_ff), ("layers", "embed", "ff")))
            ir.declare(A.weight("mlp.wo", (Ld, arch.d_ff, d), ("layers", "ff", "embed")))
    elif arch.d_ff:
        gated = arch.gated_mlp and arch.family != "encoder"
        n_in = 2 if gated else 1                       # SwiGLU: gate+up fused
        ir.declare(A.weight("mlp.wi", (L, d, n_in * arch.d_ff), ("layers", "embed", "ff")))
        ir.declare(A.weight("mlp.wo", (L, arch.d_ff, d), ("layers", "ff", "embed")))

    ir.declare(A.weight("norms", (L, 2, d), ("layers", None, "embed"), dtype="float32"))

    # ---------------- step inputs / activations -------------------------
    if shape.kind == "decode":
        ir.declare(A.model_input("tokens", (B, 1), ("batch", None)))
        if arch.has_attention:
            # cache layout is decided by the layout pass; declared seq-major.
            # names match the runtime cache pytree (dist.sharding.cache_axes)
            for nm in ("cache.k", "cache.v"):
                ir.declare(A.kv_cache(nm, (L, B, S, K, hd),
                                      ("layers", "batch", "seq_kv",
                                       "kv_heads", "head_dim")))
        if arch.has_ssm:
            ir.declare(A.ssm_state("cache.ssm",
                                   (L, B, arch.ssm_heads, arch.ssm_head_dim, arch.ssm_state),
                                   ("layers", "batch", "ssm_heads", None, None)))
            ir.declare(A.ssm_state("cache.conv",
                                   (L, B, arch.ssm_conv,
                                    arch.d_inner + 2 * arch.ssm_n_groups * arch.ssm_state),
                                   ("layers", "batch", None, "ssm_inner")))
        act_T = B
    else:
        ir.declare(A.model_input("tokens", (B, S), ("batch", "seq")))
        if training:
            ir.declare(A.model_input("targets", (B, S), ("batch", "seq")))
        act_T = B * S

    ir.declare(A.activation("residual", (act_T, d), (None, "act_embed")))
    if arch.has_attention:
        ir.declare(A.activation("qkv", (act_T, (H + 2 * K) * hd), (None, "act_heads")))
    if arch.d_ff:
        ir.declare(A.activation("ffn_hidden", (act_T, arch.d_ff), (None, "act_ff")))

    # ---------------- optimizer state (training only) -------------------
    if training:
        # padded so any mesh factorization divides (the real opt state is a
        # per-leaf pytree; this flat decl only feeds the byte cost model)
        n_params = -(-arch.param_count() // 65536) * 65536
        ir.declare(A.opt_state("adam_m", (n_params,), ("flat_params",)))
        ir.declare(A.opt_state("adam_v", (n_params,), ("flat_params",)))
        ir.declare(A.opt_state("master", (n_params,), ("flat_params",)))
        ir.declare(A.gradient("grads", (n_params,), ("flat_params",)))

    # ---------------- coarse ops (FLOP model) ---------------------------
    def op(name, kind, flops, nbytes, operands=("residual",), results=("residual",), **dims):
        ir.add_op(OpDecl(name, kind, tuple(operands), tuple(results),
                         float(flops), float(nbytes), dims))

    tokens_name = "tokens"
    op("embed_lookup", OpKind.EMBED, 0, act_T * d * 2, operands=(tokens_name, "embed"))

    if arch.has_attention:
        proj_flops = 2 * act_T * d * (H + 2 * K) * hd * L
        op("attn.qkv_proj", OpKind.MATMUL, proj_flops,
           (d * (H + 2 * K) * hd * 2) * L, operands=("residual", "attn.wq"))
        # attention context per query token
        if shape.kind == "decode":
            ctx = S if arch.window == 0 else min(S, arch.window)
            # hymba: global layers see the whole context
            n_glob = _n_global_layers(arch)
            att_flops = 4 * B * hd * H * (ctx * (L - n_glob) + S * n_glob)
            kind = OpKind.ATTENTION_DECODE
            operands = ("qkv", "cache.k")
        else:
            ctx = S if arch.window == 0 else min(S, arch.window)
            n_glob = _n_global_layers(arch)
            per_layer_full = 4 * B * S * S * hd * H * 0.5  # causal half
            per_layer_win = 4 * B * S * ctx * hd * H * (0.5 if ctx >= S else 1.0)
            att_flops = per_layer_full * n_glob + per_layer_win * (L - n_glob)
            if not arch.causal:
                att_flops = 4 * B * S * S * hd * H * L
            kind = OpKind.ATTENTION
            operands = ("qkv",)
        op("attn.core", kind, att_flops, act_T * H * hd * 2 * L * 2,
           operands=operands, heads=H, head_dim=hd, ctx=ctx)
        op("attn.out_proj", OpKind.MATMUL, 2 * act_T * H * hd * d * L,
           H * hd * d * 2 * L)

    if arch.has_ssm:
        di, st = arch.d_inner, arch.ssm_state
        in_dim = 2 * di + 2 * arch.ssm_n_groups * st + arch.ssm_heads
        op("ssm.in_proj", OpKind.MATMUL, 2 * act_T * d * in_dim * L,
           d * in_dim * 2 * L, operands=("residual", "ssm.in_proj"))
        chunk = 256
        ssd_flops = (4 * act_T * di * st + 2 * act_T * min(chunk, Sctx) * di) * L
        op("ssm.ssd", OpKind.SSD_SCAN, ssd_flops, act_T * di * 2 * L * 2,
           state=st, chunk=chunk)
        op("ssm.out_proj", OpKind.MATMUL, 2 * act_T * di * d * L, di * d * 2 * L)

    if arch.is_moe:
        Lm = L // arch.moe_interleave
        Ld = L - Lm
        ff = arch.moe_d_ff or arch.d_ff
        topk = arch.experts_per_token
        op("moe.router", OpKind.MATMUL, 2 * act_T * d * arch.n_experts * Lm,
           d * arch.n_experts * 4 * Lm, operands=("residual", "moe.router"),
           results=("residual",))
        moe_flops = 2 * act_T * d * ff * 3 * topk * Lm
        moe_flops += 2 * act_T * d * ff * 3 * arch.n_shared_experts * Lm
        op("moe.experts", OpKind.MOE_DISPATCH, moe_flops,
           arch.n_experts * 3 * d * ff * 2 * Lm,
           operands=("residual", "moe.wi", "moe.wo"),
           experts=arch.n_experts, topk=topk, capacity_factor=arch.capacity_factor)
        if Ld:
            op("mlp.dense", OpKind.MATMUL, 2 * act_T * d * arch.d_ff * 3 * Ld,
               3 * d * arch.d_ff * 2 * Ld, operands=("residual", "mlp.wi"))
    elif arch.d_ff:
        mult = 3 if (arch.gated_mlp and arch.family != "encoder") else 2
        op("mlp", OpKind.MATMUL, 2 * act_T * d * arch.d_ff * mult * L,
           mult * d * arch.d_ff * 2 * L, operands=("residual", "mlp.wi"))

    op("norms", OpKind.NORM, act_T * d * 8 * L, act_T * d * 2 * 2 * L)
    if training or shape.kind == "decode":
        op("lm_head", OpKind.MATMUL, 2 * act_T * d * V, d * V * 2,
           operands=("residual", "embed" if arch.tie_embeddings else "lm_head"))

    ir.meta.update(
        arch=arch.name, shape=shape.name, training=training,
        tokens_per_step=T, model_params=arch.param_count(),
        active_params=arch.active_param_count(),
    )
    ir.validate()
    return ir


def _n_global_layers(arch: ArchConfig) -> int:
    if arch.window == 0:
        return arch.n_layers if arch.has_attention else 0
    if arch.global_every <= 0:
        return 0
    # hymba convention: first, every global_every-th, and last layer
    idxs = set(range(0, arch.n_layers, arch.global_every)) | {arch.n_layers - 1}
    return len(idxs)


def global_layer_mask(arch: ArchConfig) -> Tuple[bool, ...]:
    """Per-layer: does this layer use global (full) attention?"""
    if not arch.has_attention:
        return tuple()
    if arch.window == 0:
        return tuple(True for _ in range(arch.n_layers))
    idxs = set(range(0, arch.n_layers, arch.global_every)) | {arch.n_layers - 1} \
        if arch.global_every > 0 else set()
    return tuple(i in idxs for i in range(arch.n_layers))
