"""Memory IR — the multi-level intermediate representation of §4.

The paper's flow operates on an IR that carries *data-related* information
(access patterns, lifetimes, sizes) alongside the computation, so that the
memory architecture can be refined before the accelerator logic is
generated.  This module is that IR, re-targeted to TPU workloads:

* :class:`TensorDecl` — one logical tensor (parameter, activation, KV
  cache, optimizer state, ...) with its *domain-specific annotations*
  (access pattern, reuse, lifetime, logical axes).
* :class:`OpDecl`     — one coarse op (matmul / attention / scan / moe
  dispatch) with FLOP and byte estimates, used by the cost model.
* :class:`ProgramIR`  — the program-level container the passes rewrite.

The IR is deliberately *coarse*: one entry per logically-distinct tensor
class (e.g. "all 80 stacked q_proj weights" is one TensorDecl with a
``layers`` leading dim), which is what the placement decisions operate on.
The lowering pass maps decisions back onto the concrete pytree by matching
``role`` + ``logical_axes``.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np


class MemorySpace(enum.Enum):
    """Where bytes physically live — the template's storage sites."""

    HBM = "hbm"            # on-chip (per-accelerator) DRAM: the default
    VMEM = "vmem"          # kernel working set (PLM analogue)
    SMEM = "smem"          # scalars / prefetch indices
    HOST = "host"          # host DRAM (off-chip analogue)
    REMOTE = "remote"      # other pods / storage (NVM analogue)


class AccessPattern(enum.Enum):
    SEQUENTIAL = "sequential"    # streaming, unit stride (DMA friendly)
    STRIDED = "strided"          # regular but non-unit stride (layout pass fixes)
    RANDOM = "random"            # gather/scatter (latency-insensitive path)
    BROADCAST = "broadcast"      # read by all compute units (weights)
    REDUCTION = "reduction"      # written via accumulation (grads)


class Reuse(enum.Enum):
    NONE = "none"        # touched once per step (activations in a stream)
    LOW = "low"          # a few touches (residual streams)
    HIGH = "high"        # many touches (weights, KV cache during decode)


class Lifetime(enum.Enum):
    EPHEMERAL = "ephemeral"      # intra-step (activations) — remat candidates
    STEP = "step"                # lives across one step (grads, inputs)
    PERSISTENT = "persistent"    # lives across steps (params, opt state)
    SESSION = "session"          # lives across requests (KV cache)


class Role(enum.Enum):
    PARAM = "param"
    EXPERT_PARAM = "expert_param"    # MoE expert weights (EP-shardable)
    OPT_STATE = "opt_state"
    GRAD = "grad"
    ACTIVATION = "activation"
    INPUT = "input"
    OUTPUT = "output"
    KV_CACHE = "kv_cache"
    SSM_STATE = "ssm_state"
    ROUTING = "routing"              # MoE router tensors


@dataclasses.dataclass
class TensorDecl:
    """One logical tensor + its domain-specific annotations (paper §1, §4)."""

    name: str
    shape: Tuple[int, ...]
    dtype: str                       # numpy dtype name, e.g. "bfloat16"
    role: Role
    logical_axes: Tuple[Optional[str], ...]  # one label per dim, None = unsharded
    access: AccessPattern = AccessPattern.SEQUENTIAL
    reuse: Reuse = Reuse.NONE
    lifetime: Lifetime = Lifetime.EPHEMERAL
    annotations: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if len(self.logical_axes) != len(self.shape):
            raise ValueError(
                f"{self.name}: logical_axes {self.logical_axes} rank "
                f"!= shape {self.shape} rank"
            )

    @property
    def dtype_bytes(self) -> int:
        if self.dtype == "bfloat16":
            return 2
        return np.dtype(self.dtype).itemsize

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * self.dtype_bytes

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


class OpKind(enum.Enum):
    MATMUL = "matmul"
    ATTENTION = "attention"
    ATTENTION_DECODE = "attention_decode"
    SSD_SCAN = "ssd_scan"
    MOE_DISPATCH = "moe_dispatch"
    EMBED = "embed"
    ELEMENTWISE = "elementwise"
    NORM = "norm"


@dataclasses.dataclass
class OpDecl:
    """A coarse compute op: enough structure for cost/partitioning passes."""

    name: str
    kind: OpKind
    operands: Tuple[str, ...]        # TensorDecl names read
    results: Tuple[str, ...]         # TensorDecl names written
    flops: float                     # forward FLOPs, whole-program (all layers)
    bytes_accessed: float            # min HBM traffic (compulsory)
    dims: Dict[str, int] = dataclasses.field(default_factory=dict)
    annotations: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_accessed, 1.0)


@dataclasses.dataclass
class ProgramIR:
    """The program the passes rewrite.

    ``phase`` records how far down the multi-level flow this IR instance
    has been refined (paper Figure 1: each pass moves the IR to a lower
    abstraction level).
    """

    name: str
    tensors: Dict[str, TensorDecl] = dataclasses.field(default_factory=dict)
    ops: List[OpDecl] = dataclasses.field(default_factory=list)
    phase: str = "source"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # --- construction -----------------------------------------------------
    def declare(self, t: TensorDecl) -> TensorDecl:
        if t.name in self.tensors:
            raise ValueError(f"duplicate tensor decl {t.name!r}")
        self.tensors[t.name] = t
        return t

    def add_op(self, op: OpDecl) -> OpDecl:
        for ref in op.operands + op.results:
            if ref not in self.tensors:
                raise ValueError(f"op {op.name}: unknown tensor {ref!r}")
        self.ops.append(op)
        return op

    # --- queries ----------------------------------------------------------
    def by_role(self, *roles: Role) -> List[TensorDecl]:
        want = set(roles)
        return [t for t in self.tensors.values() if t.role in want]

    def total_bytes(self, *roles: Role) -> int:
        return sum(t.nbytes for t in self.by_role(*roles))

    def total_flops(self) -> float:
        return sum(op.flops for op in self.ops)

    def validate(self) -> None:
        for op in self.ops:
            for ref in op.operands + op.results:
                assert ref in self.tensors, (op.name, ref)
        for t in self.tensors.values():
            assert all(d > 0 for d in t.shape), (t.name, t.shape)
