"""Training loop: plan-lowered step + pipeline + checkpoints + fault hooks.

Everything configurable arrives via the MemoryPlan (the paper's flow
output) — the trainer itself is plan-agnostic glue:

    plan = specialize(arch, shape, mesh...)
    trainer = Trainer(plan, mesh)
    trainer.fit(n_steps)
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import get_arch, get_shape
from repro.core.passes.lowering import LoweredStep, lower_train_step, _padded
from repro.core.plan import MemoryPlan
from repro.data.pipeline import PrefetchPipeline, SyntheticSource
from repro.models import lm
from repro.optim import adamw
from repro.runtime.straggler import DeadlineSkipper, StepTimer


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, plan: MemoryPlan, mesh, cfg: Optional[TrainerConfig] = None,
                 opt_cfg: Optional[adamw.OptConfig] = None,
                 arch=None, shape=None):
        self.plan = plan
        self.mesh = mesh
        self.cfg = cfg or TrainerConfig()
        # reduced/custom configs are passed explicitly; registry by default
        self.arch = arch if arch is not None else get_arch(plan.arch)
        self.shape = shape if shape is not None else get_shape(plan.shape)
        self.step_def: LoweredStep = lower_train_step(
            plan, self.arch, self.shape, mesh, opt_cfg)
        self.step_fn = self.step_def.jit()
        self.opt_cfg = opt_cfg or adamw.OptConfig.from_plan(plan)
        self.ckpt = Checkpointer(self.cfg.ckpt_dir)
        self.timer = StepTimer()
        self.skipper = DeadlineSkipper()
        self.history: list = []

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            self.step_def.in_pspecs[0],
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

        def make():
            params = lm.init_params(self.arch, jax.random.PRNGKey(seed),
                                    *_padded(self.plan))
            opt = adamw.init_opt_state(params, self.opt_cfg)
            if self.plan.comm.compresses_gradients:
                from repro.dist.collectives import ef_state
                opt["ef"] = ef_state(params)
            return {"params": params, "opt": opt}

        # one jit: fresh (non-aliased, donation-safe) buffers, born sharded
        return jax.jit(make, out_shardings=shardings)()

    def fit(self, state: Optional[Dict[str, Any]] = None,
            n_steps: Optional[int] = None, start_step: int = 0):
        n_steps = n_steps or self.cfg.n_steps
        state = state if state is not None else self.init_state(self.cfg.seed)
        source = SyntheticSource(self.arch, self.shape, seed=self.cfg.seed)
        pipe = PrefetchPipeline(source, self.plan.comm.prefetch_depth,
                                start_step=start_step)
        metrics = {}
        try:
            for step, batch in pipe:
                if step >= n_steps:
                    break
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])   # sync point
                dt = time.time() - t0
                self.timer.observe(dt)
                self.history.append({"step": step, "loss": loss,
                                     "dt_s": round(dt, 4)})
                if step % self.cfg.log_every == 0:
                    print(f"step {step:6d} loss {loss:8.4f} "
                          f"{dt*1e3:7.1f} ms "
                          f"gnorm {float(metrics['grad_norm']):.3f}",
                          flush=True)
                if self.cfg.ckpt_every and (step + 1) % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step + 1, state,
                                   meta={"arch": self.arch.name,
                                         "shape": self.shape.name})
        finally:
            pipe.close()
            self.ckpt.wait()
        return state, metrics

    def resume(self):
        """Restore the latest checkpoint (resharded for this mesh)."""
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            self.step_def.in_pspecs[0],
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        state, manifest = self.ckpt.restore(shardings=shardings)
        return state, manifest["step"]
