"""Training loop: plan-lowered step + pipeline + checkpoints + fault hooks.

Everything configurable arrives via the frozen plan artifact (the
paper's flow output) — the trainer itself is plan-agnostic glue:

    plan = specialize(arch, shape, mesh...)
    trainer = Trainer(plan, mesh)
    trainer.fit(n_steps)

The plan ships with the model: the trainer persists the artifact into a
content-addressed store next to the checkpoints (``<ckpt_dir>/plans``)
and stamps every checkpoint manifest with ``plan_hash``.  A restarted
job warm-starts from the stored artifact (:meth:`Trainer.warm_start`)
without re-running the compiler; if it recompiles anyway and the hash
moved, :meth:`Trainer.resume` logs a diff of the two decision logs so
the drift is visible, not silent.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.ckpt.checkpointer import Checkpointer
from repro.configs.base import get_arch, get_shape
from repro.core import planstore
from repro.core.passes.lowering import LoweredStep, lower_train_step, _padded
from repro.core.plan import FrozenPlan, diff_decision_logs
from repro.data.pipeline import PrefetchPipeline, SyntheticSource
from repro.models import lm
from repro.optim import adamw
from repro.runtime.straggler import DeadlineSkipper, StepTimer


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, plan: FrozenPlan, mesh, cfg: Optional[TrainerConfig] = None,
                 opt_cfg: Optional[adamw.OptConfig] = None,
                 arch=None, shape=None):
        self.plan = plan
        self.mesh = mesh
        self.cfg = cfg or TrainerConfig()
        # reduced/custom configs are passed explicitly; registry by default
        self.arch = arch if arch is not None else get_arch(plan.arch)
        self.shape = shape if shape is not None else get_shape(plan.shape)
        self.step_def: LoweredStep = lower_train_step(
            plan, self.arch, self.shape, mesh, opt_cfg)
        self.step_fn = self.step_def.jit()
        self.opt_cfg = opt_cfg or adamw.OptConfig.from_plan(plan)
        self.ckpt = Checkpointer(self.cfg.ckpt_dir)
        self.timer = StepTimer()
        self.skipper = DeadlineSkipper()
        self.history: list = []
        # the plan artifact ships with the checkpoints: persist it
        # content-addressed so a restart reloads it without recompiling
        self.plan_store = planstore.get_store(
            Path(self.cfg.ckpt_dir) / "plans")
        self.plan_hash = (plan.content_hash()
                          if hasattr(plan, "content_hash") else "")
        if self.plan_hash:
            self.plan_store.save(plan)

    # ------------------------------------------------------------------
    @classmethod
    def warm_start(cls, ckpt_dir: str | Path, mesh,
                   cfg: Optional[TrainerConfig] = None,
                   opt_cfg: Optional[adamw.OptConfig] = None,
                   arch=None, shape=None) -> "Trainer":
        """Rebuild a trainer from a checkpoint directory's stored plan.

        Reads the latest manifest's ``plan_hash``, reloads the frozen
        artifact from ``<ckpt_dir>/plans`` (no compiler run), and falls
        back to re-specializing when the artifact is missing or corrupt
        (from the caller's ``arch``/``shape`` if given, else the
        manifest metadata; note non-default ``specialize(**options)``
        are not recorded in the manifest and cannot be recovered by the
        fallback — the resulting hash drift is surfaced by
        :meth:`resume`).
        """
        ckpt = Checkpointer(ckpt_dir)
        step = ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
        meta = ckpt.manifest(step).get("meta", {})
        store = planstore.get_store(Path(ckpt_dir) / "plans")
        plan = store.load(meta.get("plan_hash", "")) \
            if meta.get("plan_hash") else None
        if plan is None:
            from repro.core.pipeline import specialize
            # prefer the caller's configs: reduced/custom arch and ad-hoc
            # shapes share registry names (or have none at all), so the
            # manifest names alone would recompile for the wrong model
            arch_src = arch if arch is not None else meta.get("arch")
            shape_src = shape if shape is not None else meta.get("shape")
            if arch_src is None or shape_src is None:
                raise FileNotFoundError(
                    f"warm_start: no plan artifact in {ckpt_dir}/plans and "
                    f"the step_{step:08d} manifest has no usable metadata; "
                    f"pass arch=/shape= to recompile")
            print(f"warm_start: plan artifact missing in {ckpt_dir}/plans; "
                  f"re-running the specialization flow", flush=True)
            plan = specialize(arch_src, shape_src,
                              mesh_axes=tuple(mesh.axis_names),
                              mesh_shape=tuple(mesh.devices.shape),
                              target=meta.get("plan_target", "tpu-v5e"),
                              use_pallas=meta.get("plan_use_pallas", "auto"))
        cfg = cfg or TrainerConfig()
        cfg = dataclasses.replace(cfg, ckpt_dir=str(ckpt_dir))
        return cls(plan, mesh, cfg, opt_cfg=opt_cfg, arch=arch, shape=shape)

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            self.step_def.in_pspecs[0],
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

        def make():
            params = lm.init_params(self.arch, jax.random.PRNGKey(seed),
                                    *_padded(self.plan))
            opt = adamw.init_opt_state(params, self.opt_cfg)
            if self.plan.comm.compresses_gradients:
                from repro.core.passes.lowering import wire_compression
                from repro.dist.collectives import ef_state
                # lowered wire path keeps one residual per DP slice
                dp = wire_compression(self.plan, self.mesh, self.arch)
                opt["ef"] = ef_state(params, replicas=max(dp, 1))
            return {"params": params, "opt": opt}

        # one jit: fresh (non-aliased, donation-safe) buffers, born sharded
        return jax.jit(make, out_shardings=shardings)()

    def fit(self, state: Optional[Dict[str, Any]] = None,
            n_steps: Optional[int] = None, start_step: int = 0):
        n_steps = n_steps or self.cfg.n_steps
        state = state if state is not None else self.init_state(self.cfg.seed)
        source = SyntheticSource(self.arch, self.shape, seed=self.cfg.seed)
        pipe = PrefetchPipeline(source, self.plan.comm.prefetch_depth,
                                start_step=start_step)
        metrics = {}
        try:
            for step, batch in pipe:
                if step >= n_steps:
                    break
                t0 = time.time()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])   # sync point
                dt = time.time() - t0
                self.timer.observe(dt)
                self.history.append({"step": step, "loss": loss,
                                     "dt_s": round(dt, 4)})
                if step % self.cfg.log_every == 0:
                    print(f"step {step:6d} loss {loss:8.4f} "
                          f"{dt*1e3:7.1f} ms "
                          f"gnorm {float(metrics['grad_norm']):.3f}",
                          flush=True)
                if self.cfg.ckpt_every and (step + 1) % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step + 1, state,
                                   meta={"arch": self.arch.name,
                                         "shape": self.shape.name,
                                         "plan_hash": self.plan_hash,
                                         "plan_target": self.plan.target,
                                         "plan_use_pallas":
                                             self.plan.use_pallas})
        finally:
            pipe.close()
            self.ckpt.wait()
        return state, metrics

    def resume(self):
        """Restore the latest checkpoint (resharded for this mesh).

        Validates the checkpoint's ``plan_hash`` against this trainer's
        plan: on mismatch the step was recompiled under different
        decisions, so the diff of the two decision logs is printed (the
        restore still proceeds — elastic restarts legitimately change
        the mesh, and the state is resharded either way).
        """
        shardings = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(self.mesh, s),
            self.step_def.in_pspecs[0],
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        state, manifest = self.ckpt.restore(shardings=shardings)
        saved_hash = manifest.get("meta", {}).get("plan_hash", "")
        if saved_hash and self.plan_hash and saved_hash != self.plan_hash:
            print(f"resume: plan hash changed "
                  f"{saved_hash[:12]} -> {self.plan_hash[:12]} "
                  f"(recompiled under different decisions)", flush=True)
            old = self.plan_store.load(saved_hash)
            if old is not None:
                for line in diff_decision_logs(old.log, self.plan.log):
                    print(f"  plan diff: {line}", flush=True)
        return state, manifest["step"]
