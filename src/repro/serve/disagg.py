"""Disaggregated prefill: a supervised worker fleet behind the engine.

The paper's flow specializes one memory template per *role*; prefill
and decode are different roles with opposite profiles (a flops-bound
burst over the whole prompt vs a bandwidth-bound tick over one token),
so when the plan's interference model says an inline prefill would
steal too many decode ticks (``kv_prefill_mode: disagg``), prefill
moves out of the engine process entirely:

* :func:`_worker_main` — the prefill worker.  Spawned (never forked —
  the parent's JAX runtime does not survive a fork), it rebuilds the
  cache geometry from the *same* :class:`~repro.core.plan.FrozenPlan`
  JSON the engine holds and proves it at handshake: the first message
  home is its recomputed plan content hash, and a mismatch is a typed
  :class:`PlanHandshakeError` on the orchestrator side — two processes
  disagreeing about block geometry must never exchange KV bytes.
  Prompts prefill **chunked block-native** via
  :func:`repro.models.lm.prefill_chunked`: each ``block_len``-sized
  chunk is one pool-block-shaped KV slab streamed home as soon as it
  exists (no dense ``(B, plen)`` intermediate), with a heartbeat after
  every chunk.

* :class:`PrefillFleet` — the host-side supervisor.  Dispatches
  prompts to the least-loaded live worker, feeds heartbeats into
  :class:`repro.runtime.fault.HealthMonitor` (workers are
  ``expect()``-registered at spawn, so a dead-on-arrival worker is
  detected, not invisible), detects death by both liveness probe and
  heartbeat deadline, respawns under a per-slot
  :class:`~repro.runtime.fault.RestartPolicy` exponential backoff, and
  reports the in-flight request ids a death orphaned so the engine can
  re-dispatch them from its chunk journal.  A slot whose restart
  budget is exhausted retires; when every slot has retired the fleet
  raises its :class:`DegradedMode` flag and the engine falls back to
  in-process prefill — degraded, never crashed.
"""

from __future__ import annotations

import dataclasses
import queue as _queue
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.fault import HealthMonitor, RestartPolicy


class PlanHandshakeError(RuntimeError):
    """A prefill worker's recomputed FrozenPlan content hash does not
    match the engine's — the two sides would build different cache
    geometry, so no KV block may cross the wire."""


@dataclasses.dataclass(frozen=True)
class DegradedMode:
    """Typed degraded state: the fleet is gone and prefill runs
    in-process again.  Surfaced through ``pressure_stats()`` /
    ``telemetry()`` so operators see *that* and *why* the engine
    degraded instead of inferring it from latency."""

    reason: str
    worker_deaths: int
    restarts: int
    at_tick: int = -1              # stamped by the engine when observed

    def to_json(self) -> Dict[str, Any]:
        return {"reason": self.reason,
                "worker_deaths": int(self.worker_deaths),
                "restarts": int(self.restarts),
                "at_tick": int(self.at_tick)}


def _worker_main(wid: int, inq, outq, payload: Dict[str, Any]) -> None:
    """Prefill worker entry point (spawn target; must be importable).

    Protocol (worker -> orchestrator, all through ``outq``):
      ``("hello", wid, plan_hash)``      handshake, first message
      ``("beat", wid, t)``               heartbeat (idle and per chunk)
      ``("chunk", wid, rid, idx, k, v)`` one pool-block-shaped KV slab
      ``("done", wid, rid, logits)``     last-token logits, prompt done
      ``("error", wid, rid, msg)``       prefill raised (typed, not a crash)

    Instructions (orchestrator -> worker, through ``inq``):
      ``("prefill", rid, tail_tokens, prefix_k, prefix_v)``
      ``("stop",)``
    """
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax

    from repro.core.passes.lowering import build_run_cfg
    from repro.core.plan import FrozenPlan
    from repro.models import lm

    plan = FrozenPlan.from_json(payload["plan_json"])
    got = plan.content_hash()
    outq.put(("hello", wid, got))
    if got != payload["plan_hash"]:
        return                      # the orchestrator raises; we just leave
    arch = payload["arch"]
    cfg = build_run_cfg(plan, arch, None)
    params = payload["params"]
    bl, kvh = payload["block_len"], payload["kv_heads"]
    hb, delay = payload["heartbeat_s"], payload["chunk_delay_s"]
    # one long-lived jit so the per-(prefix, tail) shape compile cache
    # survives across prompts
    tail_fn = jax.jit(
        lambda p, b, pk, pv: lm.prefill_tail(arch, p, b, cfg, pk, pv))
    while True:
        try:
            msg = inq.get(timeout=hb)
        except _queue.Empty:
            outq.put(("beat", wid, time.time()))
            continue
        if msg[0] == "stop":
            return
        _, rid, tokens, pk, pv = msg

        def on_chunk(idx, kc, vc, _rid=rid):
            if delay:
                time.sleep(delay)   # chaos knob: widen the kill window
            outq.put(("chunk", wid, _rid, idx, np.asarray(kc),
                      np.asarray(vc)))
            outq.put(("beat", wid, time.time()))

        try:
            logits, _, _ = lm.prefill_chunked(
                arch, params, tokens, bl, cfg, kv_heads=kvh,
                prefix_k=pk, prefix_v=pv, on_chunk=on_chunk,
                tail_fn=tail_fn)
            outq.put(("done", wid, rid, np.asarray(logits)))
        except Exception as e:      # noqa: BLE001 — typed event, no crash
            outq.put(("error", wid, rid, f"{type(e).__name__}: {e}"))


@dataclasses.dataclass
class _WorkerSlot:
    """One supervised worker position: a process incarnation chain
    under a restart budget.  Worker ids are unique per incarnation so a
    late message from a killed predecessor can never impersonate its
    replacement."""

    idx: int
    policy: RestartPolicy
    proc: Any = None
    inq: Any = None
    wid: int = -1
    incarnation: int = 0
    ready: bool = False            # hello received (hash verified)
    retired: bool = False          # restart budget exhausted
    retire_reason: str = ""
    respawn_at: float = 0.0
    inflight: List[int] = dataclasses.field(default_factory=list)


class PrefillFleet:
    """Supervisor for N prefill worker processes (see module docstring).

    The fleet is transport-complete but policy-free: it spawns,
    handshakes, dispatches, detects death, respawns with backoff, and
    retires exhausted slots — what to *do* about an orphaned request
    (the chunk journal, the resume boundary, degraded fallback) is the
    engine's call, driven by the events :meth:`poll` returns.
    """

    def __init__(self, plan, arch, params, n_workers: int = 1, *,
                 block_len: int, kv_heads: int = 0,
                 heartbeat_s: float = 2.0,
                 heartbeat_timeout_s: float = 60.0,
                 max_restarts: int = 4,
                 backoff_base_s: float = 0.25,
                 backoff_cap_s: float = 5.0,
                 chunk_delay_s: float = 0.0,
                 hello_timeout_s: float = 300.0,
                 start: bool = True,
                 _expect_hash: Optional[str] = None):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        import multiprocessing as mp
        # fork after JAX initialization deadlocks; spawn re-imports
        self._ctx = mp.get_context("spawn")
        self._outq = self._ctx.Queue()
        self.n_workers = n_workers
        self.expected_hash = _expect_hash or plan.content_hash()
        self._payload = {
            "plan_json": plan.to_json(),
            "plan_hash": self.expected_hash,
            "arch": arch,
            "params": _to_numpy(params),
            "block_len": int(block_len),
            "kv_heads": int(kv_heads),
            "heartbeat_s": float(heartbeat_s),
            "chunk_delay_s": float(chunk_delay_s),
        }
        self.monitor = HealthMonitor(timeout_s=heartbeat_timeout_s)
        self._hello_timeout_s = hello_timeout_s
        self._slots = [
            _WorkerSlot(idx=i, policy=RestartPolicy(
                max_restarts=max_restarts,
                backoff_base_s=backoff_base_s,
                backoff_cap_s=backoff_cap_s))
            for i in range(n_workers)]
        self._wid2slot: Dict[int, _WorkerSlot] = {}
        self._assign: Dict[int, _WorkerSlot] = {}      # rid -> slot
        self.dispatches = 0
        self.deaths = 0
        self.restarts = 0
        self.errors = 0
        self.degraded: Optional[DegradedMode] = None
        self._started = False
        if start:
            self.start()

    # ------------------------------------------------------------------
    def _spawn(self, slot: _WorkerSlot) -> None:
        slot.incarnation += 1
        slot.wid = slot.idx + self.n_workers * slot.incarnation
        slot.inq = self._ctx.Queue()
        slot.ready = False
        slot.proc = self._ctx.Process(
            target=_worker_main,
            args=(slot.wid, slot.inq, self._outq, self._payload),
            daemon=True)
        slot.proc.start()
        self._wid2slot[slot.wid] = slot
        self.monitor.expect([slot.wid])

    def start(self) -> None:
        """Spawn every slot and block until each live worker's hello
        verifies the plan hash (mismatch: :class:`PlanHandshakeError`).
        A worker that dies before hello is left to the restart path."""
        if self._started:
            return
        self._started = True
        for slot in self._slots:
            self._spawn(slot)
        deadline = time.time() + self._hello_timeout_s
        while time.time() < deadline:
            if all(s.ready or s.proc is None or not s.proc.is_alive()
                   for s in self._slots):
                return
            try:
                msg = self._outq.get(timeout=0.2)
            except _queue.Empty:
                continue
            self._handle(msg, [])

    # ------------------------------------------------------------------
    def dispatch(self, rid: int, tokens, prefix_k=None,
                 prefix_v=None) -> bool:
        """Send one prompt (tail tokens past any journaled prefix) to
        the least-loaded live worker.  ``False`` when no worker is
        live right now (all between death and respawn, or retired) —
        the caller retries next poll or degrades."""
        live = [s for s in self._slots
                if not s.retired and s.proc is not None
                and s.proc.is_alive()]
        if not live:
            return False
        slot = min(live, key=lambda s: (len(s.inflight), s.idx))
        tokens = np.asarray(tokens, np.int32)
        slot.inq.put(("prefill", rid, tokens,
                      None if prefix_k is None else np.asarray(prefix_k),
                      None if prefix_v is None else np.asarray(prefix_v)))
        slot.inflight.append(rid)
        self._assign[rid] = slot
        self.dispatches += 1
        return True

    def cancel(self, rid: int) -> None:
        """Forget a request (shed/aborted engine-side).  The worker may
        still burn compute on it; its late events are dropped here."""
        slot = self._assign.pop(rid, None)
        if slot is not None and rid in slot.inflight:
            slot.inflight.remove(rid)

    def kill_worker(self, idx: Optional[int] = None,
                    rid: Optional[int] = None) -> bool:
        """Chaos hook: SIGKILL a live worker — by slot index, by the
        request it is running (``rid``), or any live one."""
        slot = None
        if rid is not None:
            slot = self._assign.get(rid)
        elif idx is not None:
            slot = self._slots[idx]
        else:
            for s in self._slots:
                if s.proc is not None and s.proc.is_alive():
                    slot = s
                    break
        if slot is None or slot.proc is None or not slot.proc.is_alive():
            return False
        slot.proc.kill()
        slot.proc.join(timeout=30)
        return True

    # ------------------------------------------------------------------
    def _handle(self, msg, events: List[Tuple]) -> None:
        kind, wid = msg[0], msg[1]
        slot = self._wid2slot.get(wid)
        if slot is None or slot.wid != wid:
            return                  # stale incarnation: drop
        self.monitor.beat(wid)
        if kind == "hello":
            got = msg[2]
            if got != self.expected_hash:
                self.shutdown()
                raise PlanHandshakeError(
                    f"worker {wid} rebuilt the plan with content hash "
                    f"{got[:12]}… but the engine expects "
                    f"{self.expected_hash[:12]}… — mismatched cache "
                    "geometry; refusing to exchange KV blocks")
            slot.ready = True
        elif kind == "chunk":
            _, _, rid, idx, k, v = msg
            if rid in self._assign:
                events.append(("chunk", rid, idx, k, v))
        elif kind == "done":
            _, _, rid, logits = msg
            if rid in self._assign:
                self.cancel(rid)
                events.append(("done", rid, logits))
        elif kind == "error":
            _, _, rid, err = msg
            self.errors += 1
            if rid in self._assign:
                self.cancel(rid)
                events.append(("error", rid, err))
        # "beat" needs nothing beyond the monitor feed above

    def poll(self) -> List[Tuple]:
        """Drain worker messages and supervise the fleet.  Returns
        engine-facing events: ``("chunk", rid, idx, k, v)``,
        ``("done", rid, logits)``, ``("error", rid, msg)``, and
        ``("dead", rid)`` for every request a worker death orphaned.
        Also respawns due slots and raises the degraded flag when the
        whole fleet has retired."""
        events: List[Tuple] = []
        while True:
            try:
                msg = self._outq.get_nowait()
            except _queue.Empty:
                break
            self._handle(msg, events)
        now = time.time()
        hung = set(self.monitor.dead_hosts(now))
        for slot in self._slots:
            if slot.retired or slot.proc is None:
                continue
            if slot.proc.is_alive() and slot.wid not in hung:
                continue
            # death: liveness probe failed, or heartbeat deadline passed
            self.deaths += 1
            if slot.proc.is_alive():
                slot.proc.kill()    # hung-alive: put it out of its misery
            slot.proc.join(timeout=30)
            self.monitor.forget(slot.wid)
            self._wid2slot.pop(slot.wid, None)
            slot.proc = None
            for rid in slot.inflight:
                self._assign.pop(rid, None)
                events.append(("dead", rid))
            slot.inflight = []
            try:
                slot.respawn_at = now + slot.policy.next_delay()
            except RuntimeError as e:   # budget exhausted: retire
                slot.retired = True
                slot.retire_reason = str(e)
        for slot in self._slots:
            if slot.proc is None and not slot.retired \
                    and now >= slot.respawn_at:
                self._spawn(slot)
                self.restarts += 1
        if self.degraded is None and all(s.retired for s in self._slots):
            self.degraded = DegradedMode(
                reason=(f"all {self.n_workers} prefill worker slot(s) "
                        "exhausted their restart budget "
                        f"({self._slots[0].policy.max_restarts} each)"),
                worker_deaths=self.deaths,
                restarts=self.restarts)
        return events

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """JSON-serializable fleet snapshot (telemetry building block)."""
        live = sum(1 for s in self._slots
                   if s.proc is not None and s.proc.is_alive())
        return {"workers": self.n_workers,
                "live": live,
                "retired": sum(1 for s in self._slots if s.retired),
                "dispatches": self.dispatches,
                "deaths": self.deaths,
                "restarts": self.restarts,
                "errors": self.errors,
                "inflight": len(self._assign),
                "degraded": (self.degraded.to_json()
                             if self.degraded is not None else None)}

    def shutdown(self) -> None:
        """Stop every worker (graceful stop, then SIGKILL stragglers)."""
        for slot in self._slots:
            if slot.proc is None:
                continue
            try:
                slot.inq.put(("stop",))
            except Exception:       # noqa: BLE001 — queue may be broken
                pass
        for slot in self._slots:
            if slot.proc is None:
                continue
            slot.proc.join(timeout=5)
            if slot.proc.is_alive():
                slot.proc.kill()
                slot.proc.join(timeout=5)
            self.monitor.forget(slot.wid)
            slot.proc = None
        self._assign.clear()


def _to_numpy(params):
    """Host-side copy of a params pytree (pickled into worker spawns)."""
    import jax
    return jax.tree.map(np.asarray, params)
