"""Batched serving engine: continuous batching over prefill + decode.

The session cache is the template's ``cache.kv`` component: allocated
once at engine start (shape from the plan), slots assigned to requests,
freed on completion — residency management, not reallocation.

Scheduling: waiting requests are admitted in same-length buckets — every
pending prompt of the head-of-line length that fits a free slot (and,
when paged, the block pool) is prefilled in ONE jitted call — then every
engine tick decodes one token for all active slots.  Positions are
**per slot** (``cache["pos"]`` is ``(B,)``): a continuous batch mixes
prompt lengths, so each slot appends KV and masks attention at its own
offset — an engine-global scalar position silently corrupts every slot
whose length differs from the batch max.  Freed slots are masked to
``(token 0, pos 0)`` so their stale KV never flows into a live decode.
Greedy or temperature sampling; sampling threads one engine PRNG key
(``seed=``), split per tick and per slot, so runs are reproducible and
slots never share a key within a tick.

KV residency is a plan decision (``kv_residency`` in the artifact):
``dense`` keeps the classic per-slot ``max_len`` stripes; ``paged``
allocates a block pool (``lm.init_paged_cache``) whose geometry the
data-organization pass chose, and *returns blocks to the pool on
finish* — real reclamation, so slot churn frees memory instead of
leaving masked rows resident.  On a data×model mesh the pool is 2-D
sharded (block dim data-major over both axes, batch slots partitioned
across data — ``dist.flash_decode.pool_sharding_kind``), so the
allocator works over *per-data-shard sub-pools*
(``serve.allocator.BlockAllocator``): a slot may only hold blocks from
the sub-pool of the data shard hosting it, because a foreign block
would be owned by no shard in the slot's data row and mask out of the
combine.

Admission is a plan decision too (``kv_admission``): ``reserve`` hands
an admitted request its full worst-case block budget up front (grants
can never fail mid-decode, but the pool pins bytes long-tail requests
never touch); ``grant`` is grow-on-demand — admission reserves only the
prompt's blocks and a slot asks for its next block when decode crosses
a block boundary.  Under ``grant`` exhaustion is a *handled* condition,
degraded through three rungs instead of a serialization cliff:

1. **grant** from the slot's own sub-pool;
2. **migrate** — when the home sub-pool is hot but another idles (and
   hosts a free slot), the slot's blocks, table row, and per-slot
   states move to the donor sub-pool, preserving the slot→sub-pool
   combine contract;
3. **preempt** — a victim (fewest-tokens-generated first,
   deadline-aware) is evicted to a host-side
   :class:`PreemptedRequest` — tokens generated so far retained — and
   re-admitted later via re-prefill of prompt+generated, with
   exponential backoff and a per-request retry budget (the
   :class:`repro.runtime.fault.RestartPolicy` shape, in ticks).

Past the retry budget (or a missed deadline) the request is *shed*
(``Request.error`` set, blocks released) rather than thrashed forever;
once the recent preemption rate crosses the policy threshold,
``submit()`` rejects new work with a typed :class:`OverloadError`
instead of hanging the admission queue.  Preemption is token-identical
for greedy sampling: a preempted-then-re-prefilled request emits
exactly the tokens of an uninterrupted run (the re-prefill rebuilds the
same KV rows; the discarded prefill sample is the token the host
already holds).

Paged residency additionally enables **cross-request prefix reuse**
(``kv_prefix_reuse``, a plan decision): every full ``block_len`` chunk
of an admitted feed is chain-hashed (:mod:`repro.serve.prefix_cache`)
and matched against a per-sub-pool radix trie of resident blocks.
Matched blocks are *aliased* into the new request's table with a
refcount bump (``BlockAllocator.retain``) instead of re-prefilled:
attention-only archs skip the matched tokens' prefill compute entirely
(a tail-only forward, :func:`repro.models.lm.prefill_tail`; a request
whose whole feed-but-last is matched rides the decode path with zero
prefill calls), while hybrid archs still prefill the full feed (their
SSM states need every token) but share the matched blocks' capacity.
Writers never mutate shared state: a copy-on-write barrier before each
decode tick copies any shared append block into a freshly granted one
(one jitted gather-scatter of k/v rows plus the table entry).  The
degradation ladder is sharing-aware — migration refuses to move shared
blocks and victim selection prefers requests pinning the fewest —
and trie entries are pruned exactly when their blocks return to the
free list.

Residency is **multi-tier** when the plan sized a host spill pool
(``kv_tier_split`` / ``kv_host_blocks``): behind the HBM block pool
sits a host-DRAM pool (:func:`repro.models.lm.init_host_pool`, plain
numpy — host memory by construction) and every block carries an
explicit tier (``BlockAllocator.tier_of``).  Three mechanisms ride on
it:

1. **Cold-block spill.**  Blocks that would be freed but are still
   prefix-trie-indexed are retained as a block *cache* (refcount held
   by the engine); under low-water pressure the spill scheduler moves
   them to the host tier — and drops them only when the host pool is
   full too — so the reclaim ladder gets a rung *before* grant →
   migrate → preempt → shed ever fires.  Trie entries survive the
   spill tier-tagged (``PrefixCache.rekey``): a prefix hit on a
   spilled block **promotes** it back into the slot's sub-pool instead
   of missing.
2. **Park-with-state.**  Preemption's host-side park is unified with
   the tier: a victim's KV blocks spill to host (and its SSM/conv
   rows are saved host-side) instead of being discarded, so
   re-admission *promotes the blocks back and skips re-prefill
   entirely* — token-identical resume with zero recompute.  Shared
   blocks pin a victim in the legacy path (release + re-prefill):
   sharers' tables point at the old ids.
3. **Async prefetch.**  Re-admission is known one tick ahead (the
   backoff expiry), so the engine stages the host->device transfer
   (``jax.device_put``) for tick ``T`` during tick ``T-1`` — double
   buffered: the decode of one tick overlaps the stream-in for the
   next, keyed off the parked slot's next block-boundary crossing.
   With ``kv_prefetch="off"`` the transfer happens synchronously at
   resume (the stall the benchmark rows measure).

With tiering off (``kv_host_blocks=0``, the default) every path keeps
its exact pre-tier semantics.

Prefill itself is a plan decision (``kv_prefill_mode``): when the
interference model says a worst-case inline prefill would steal too
many decode ticks, the engine runs **disaggregated** — prompts
dispatch to a supervised worker fleet (:mod:`repro.serve.disagg`)
that prefills them chunked block-native and streams pool-block-shaped
KV slabs back; the engine scatters each arriving block into the paged
pool (the spill path run in reverse) and decode never waits on a
prompt.  In-flight requests hold their slot and blocks but keep the
block-table row at -1 until completion, so the freed-slot dummy
decode can never touch a half-written block.  Acked full blocks form
an idempotent journal: when a worker dies mid-prompt the request
re-dispatches *from the last acked block boundary* (the journaled
rows are gathered back as the resume prefix — token-identical by the
``attention_tail`` bitwise contract).  When the fleet exhausts its
restart budget the engine degrades to in-process prefill under a
typed :class:`~repro.serve.disagg.DegradedMode` — never an unhandled
crash — and deadline/overload semantics compose with the shed ladder
unchanged (a request sheds the same way whether it dies in prefill or
decode).

Engines are plan-driven: :meth:`ServeEngine.from_plan` consumes the
frozen plan artifact the specialization flow produced (possibly reloaded
from the on-disk plan store in a different process) and derives the KV
cache sizing, decode implementation, admission mode, and batching
limits from it — no ad-hoc kwargs needed between the compiler and the
server.  With a ``mesh`` the engine state is *placed* per the plan's
axis rules (``dist.sharding.resolve_pspec``/``cache_pspecs``) and a
plan that chose the seq-sharded ``shard_map_flash`` decode drives it
end-to-end — no silent XLA fallback.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, get_arch
from repro.models import lm
from repro.models.lm import RunCfg
from repro.runtime.fault import RestartPolicy
from repro.runtime.straggler import StepTimer
from repro.serve.prefix_cache import PrefixCache, chain_hashes


class OverloadError(RuntimeError):
    """The engine is shedding load: the recent preemption rate crossed
    the policy threshold, so new admissions would only thrash the pool
    (evict work that re-prefills and evicts the next victim).  Callers
    should back off and retry, or route to another replica — the typed
    rejection is the graceful-degradation contract: reject loudly at
    the door instead of hanging every queued request."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    blocks: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    deadline: Optional[float] = None   # absolute wall-clock deadline
    preemptions: int = 0               # times evicted mid-decode
    error: str = ""                    # set when shed (never finished)
    # chain hashes of the feed's full blocks at last (re-)admission —
    # migration re-registers the moved blocks under these
    prefix_hashes: List[str] = dataclasses.field(default_factory=list)

    @property
    def feed_tokens(self) -> np.ndarray:
        """The token sequence a (re-)prefill must build KV for: the
        prompt, plus — after a preemption — every generated token except
        the last (whose KV row does not exist yet; the next decode tick
        feeds it)."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens[:-1], np.int32)])


@dataclasses.dataclass
class PreemptionPolicy:
    """How the engine degrades when a mid-decode block grant fails.

    Victim choice is fewest-tokens-generated first (least re-prefill
    work thrown away) and deadline-aware: requests carrying a deadline
    are spared while any deadline-free victim exists, and among
    deadline'd candidates the latest deadline goes first.  Backoff and
    retry budget reuse the :class:`repro.runtime.fault.RestartPolicy`
    shape, measured in engine ticks (the serving clock) instead of
    seconds.
    """

    max_preemptions: int = 4          # per-request retry budget
    backoff_base_ticks: int = 1       # first re-admission delay
    backoff_cap_ticks: int = 32       # exponential backoff ceiling
    shed_window_ticks: int = 64       # sliding window for the rate
    shed_rate: float = 0.5            # preemptions/tick that means overload

    def restart_policy(self) -> RestartPolicy:
        return RestartPolicy(max_restarts=self.max_preemptions,
                             backoff_base_s=float(self.backoff_base_ticks),
                             backoff_cap_s=float(self.backoff_cap_ticks))

    def pick_victim(self, candidates: List[Request],
                    now: float) -> Request:
        def key(r: Request):
            if r.deadline is None:
                return (0, len(r.out_tokens), 0.0, r.rid)
            # spare deadline'd requests; among them evict latest-deadline
            return (1, len(r.out_tokens), -(r.deadline - now), r.rid)
        return min(candidates, key=key)


@dataclasses.dataclass
class PreemptedRequest:
    """Host-side parking spot for an evicted request: the tokens
    generated so far stay on the request; its KV is rebuilt by
    re-prefill at ``not_before_tick`` (exponential backoff) — unless
    ``parked_state`` is set (tiered park): then the KV blocks live on
    in the host tier (ids in ``request.blocks``) with SSM/conv rows
    saved alongside, and re-admission promotes instead of
    re-prefilling."""

    request: Request
    not_before_tick: int
    # tiered park: {"slot_len": int, "kv_host": [host ids]} for paged
    # KV, {"kv_rows": (k, v)} for dense stripes, plus "ssm"/"conv"
    # host copies when the arch carries them
    parked_state: Optional[Dict[str, Any]] = None


@dataclasses.dataclass
class _DisaggFlight:
    """A request whose prefill is out at the worker fleet.  It owns a
    slot and its admission blocks, but the block-table row stays -1
    until completion (the freed-slot dummy decode must never append
    into a half-written block).  ``acked`` counts contiguous *full*
    blocks scattered into the pool — the idempotent journal a
    re-dispatch resumes from after a worker death."""

    request: Request
    slot: int
    group: int
    nb_feed: int                   # ceil(flen / block_len)
    acked: int = 0


class ServeEngine:
    def __init__(self, arch: ArchConfig, params, cfg: RunCfg,
                 max_batch: int = 8, max_len: int = 512,
                 ssm_heads: int = 0, kv_heads: int = 0, seed: int = 0,
                 kv_residency: str = "dense", kv_block_len: int = 0,
                 kv_n_blocks: int = 0, kv_admission: str = "reserve",
                 kv_pool_groups: int = 0, kv_prefix_reuse: str = "on",
                 kv_host_blocks: int = 0, kv_prefetch: str = "on",
                 preemption: Optional[PreemptionPolicy] = None):
        if kv_admission not in ("reserve", "grant"):
            raise ValueError(
                f"kv_admission must be 'reserve' or 'grant', "
                f"got {kv_admission!r}")
        if kv_prefix_reuse not in ("on", "off"):
            raise ValueError(
                f"kv_prefix_reuse must be 'on' or 'off', "
                f"got {kv_prefix_reuse!r}")
        if kv_prefetch not in ("on", "off"):
            raise ValueError(
                f"kv_prefetch must be 'on' or 'off', got {kv_prefetch!r}")
        if kv_host_blocks < 0:
            raise ValueError(
                f"kv_host_blocks must be >= 0, got {kv_host_blocks}")
        self.arch, self.params, self.cfg = arch, params, cfg
        self.plan = None               # set by from_plan()
        self.max_batch, self.max_len = max_batch, max_len
        self.kv_admission = kv_admission
        self.preemption = preemption or PreemptionPolicy()
        # paged residency only exists for attention caches; an SSM-only
        # arch has no KV stripes to page (its states are O(1) in seq)
        self.kv_residency = ("paged" if kv_residency == "paged"
                             and arch.has_attention else "dense")
        if self.kv_residency == "paged":
            import math
            from repro.core.costmodel import kv_block_len as _default_bl
            from repro.serve.allocator import BlockAllocator
            self.block_len = kv_block_len or _default_bl(max_len)
            per_seq = -(-max_len // self.block_len)
            # never larger than this engine's slots can ever pin (a plan
            # sized for a bigger deployment must not balloon a small one);
            # a plan-shrunk (budget-capped) pool stays shrunk
            cap = max_batch * per_seq
            n = min(kv_n_blocks, cap) if kv_n_blocks else cap
            groups = 1
            if cfg.mesh is not None:
                # preserve the plan's pool divisibility through the
                # clamp: a clamp that breaks it would silently downgrade
                # the pool-sharded decode (2-D -> 1-D -> single-shard)
                # AND replicate the pool on the broken axis
                from repro.dist.flash_decode import pool_sharding_kind
                from repro.dist.sharding import mesh_sizes
                sizes = mesh_sizes(cfg.mesh)
                msize = sizes.get(cfg.model_axis, 1)
                dsize = math.prod(sizes.get(a, 1) for a in cfg.data_axes)
                aligns = []
                if dsize > 1 and max_batch % dsize == 0:
                    aligns.append(dsize * msize)
                if msize > 1:
                    aligns.append(msize)
                for align in aligns:
                    if align > 1 and n % align and \
                            (not kv_n_blocks or kv_n_blocks % align == 0):
                        n = align * (-(-n // align))
                        if kv_n_blocks:
                            n = min(kv_n_blocks, n)
                        break
                # sub-pool grouping exists for the 2-D combine's
                # ownership contract; other decode impls (xla gather)
                # read any block from anywhere, so constraining their
                # admission would refuse servable requests
                if cfg.decode_impl == "shard_map_flash" and \
                        pool_sharding_kind(cfg.mesh, n, max_batch,
                                           cfg.data_axes,
                                           cfg.model_axis) == "2d":
                    groups = dsize
            if kv_pool_groups:
                # explicit grouping: single-host emulation of the 2-D
                # sub-pool contract (tests, diagnostics) — the slot→
                # sub-pool mapping needs equal slot ranges per group
                if n % kv_pool_groups or max_batch % kv_pool_groups:
                    raise ValueError(
                        f"kv_pool_groups={kv_pool_groups} must divide both "
                        f"n_blocks={n} and max_batch={max_batch}")
                groups = kv_pool_groups
            self.n_blocks = n
            self.pool_groups = groups
            # host spill tier (the plan's kv_tier_split): a second pool
            # of host-DRAM blocks behind the HBM pool.  Clamped like
            # n_blocks — a plan sized for a bigger deployment must not
            # balloon a small engine's host pin — to a park depth of 8
            # full worst-case batches (past that, parked sessions wait
            # on slots, not on host bytes).
            self.host_blocks = min(kv_host_blocks, 8 * cap) \
                if kv_host_blocks > 0 else 0
            self.cache = lm.init_paged_cache(
                arch, max_batch, max_len, self.block_len, self.n_blocks,
                ssm_heads=ssm_heads, kv_heads=kv_heads)
            self._alloc = BlockAllocator(self.n_blocks, groups,
                                         host_blocks=self.host_blocks)
            self._host = (lm.init_host_pool(arch, self.host_blocks,
                                            self.block_len,
                                            kv_heads=kv_heads)
                          if self.host_blocks else None)
            # cross-request prefix reuse: one trie per sub-pool (a match
            # in a foreign sub-pool would break the combine contract)
            self.kv_prefix_reuse = kv_prefix_reuse == "on"
            self._prefix: Optional[PrefixCache] = (
                PrefixCache(groups) if self.kv_prefix_reuse else None)
        else:
            from repro.serve.allocator import BlockAllocator
            self.block_len = 0
            self.n_blocks = 0
            self.host_blocks = 0
            self.pool_groups = 1
            self.cache = lm.init_cache(arch, max_batch, max_len,
                                       ssm_heads=ssm_heads, kv_heads=kv_heads)
            self._alloc = BlockAllocator(0, 1)
            self._host = None
            self.kv_prefix_reuse = False
            self._prefix = None
        # tiered residency: host-side park of KV blocks (paged), dense
        # stripes, and SSM/conv rows — enables no-re-prefill resume.
        # Dense/SSM engines park per-slot state without a block pool.
        self.kv_tiering = kv_host_blocks > 0
        self.kv_prefetch = kv_prefetch == "on"
        # engine-held block cache: blocks a release would have freed
        # but that the prefix trie still indexes (refcount 1, held
        # here).  Insertion-ordered — the spill scheduler works
        # oldest-first.  Only populated with tiering on.
        self._cached: Dict[int, None] = {}
        # prefetch staging: rid -> (host id tuple, k rows, v rows) put
        # on device one tick before the parked request's re-admission
        self._staged: Dict[int, Any] = {}
        # admission-scoped map of promoted ids (old host id -> new HBM
        # id) so a second request matching the same just-promoted block
        # follows it instead of aliasing a freed host id
        self._promo_map: Dict[int, int] = {}
        # matched tokens' prefill compute is only skippable when the
        # whole per-token state is attention KV; an SSM/hybrid state
        # depends on every prefix token, so those archs alias blocks
        # (capacity sharing) but still prefill the full feed
        self._skip_prefix = (self._prefix is not None
                             and arch.has_attention and not arch.has_ssm)
        self.free_slots = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.pending: List[Request] = []
        self._rid = 0
        self.finished: List[Request] = []
        # overload-degradation state: host-side parked evictions, shed
        # requests (never finished; Request.error says why), per-request
        # backoff budgets, and the sliding preemption-rate window
        self.preempted: List[PreemptedRequest] = []
        self.shed: List[Request] = []
        self._backoff: Dict[int, RestartPolicy] = {}
        self._preempt_ticks: Deque[int] = deque(maxlen=4096)
        self.tick = 0
        self.preemptions = 0
        self.migrations = 0
        self.grant_denials = 0
        # chaos/test hook: return True to deny one mid-decode grant even
        # when blocks are free (drives the preemption path exactly like
        # a hot sub-pool would; see scripts/serve_smoke.py --chaos)
        self.grant_fault: Optional[Callable[[], bool]] = None
        # tick-time telemetry (straggler detection at the engine edge)
        self.tick_timer = StepTimer()
        self.straggler_ticks = 0
        # per-slot valid lengths; mirrored into cache["pos"] every tick
        # (freed slots stay at 0 — their stale KV is masked out)
        self.slot_len = np.zeros((max_batch,), np.int32)
        self._key = jax.random.PRNGKey(seed)
        self._pos_sharding = None      # set by _place_on_mesh()
        # admission telemetry: bucketed prefill batch sizes per call
        # (bounded — long-running engines must not accumulate history)
        self.prefill_calls = 0
        self.prefill_batches: Deque[int] = deque(maxlen=1024)
        # prefix-sharing telemetry (hit/miss counters live on _prefix)
        self.cow_copies = 0
        self.prefix_rides = 0          # admissions with zero prefill calls
        # disaggregated prefill (attach_fleet() flips the mode on):
        # rid -> in-flight dispatch; acked full blocks are the
        # idempotent re-dispatch journal
        self.kv_prefill_mode = "inline"
        self._fleet = None
        self._disagg: Dict[int, _DisaggFlight] = {}
        self._redispatch: List[int] = []
        self._inline_poison: set = set()   # rids whose worker prefill raised
        self.degraded = None               # disagg.DegradedMode once degraded
        self.disagg_dispatches = 0
        self.disagg_chunks = 0
        self.disagg_resumes = 0

        self._decode = jax.jit(
            lambda p, c, b: lm.decode_step(arch, p, c, b, cfg))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(arch, p, b, cfg, max_len=max_len))
        self._prefill_tail = jax.jit(
            lambda p, b, pk, pv: lm.prefill_tail(arch, p, b, cfg, pk, pv))
        # CoW: duplicate one pool block's k/v rows and repoint one table
        # entry, in a single jitted gather-scatter
        self._cow_kernel = jax.jit(
            lambda k, v, tbl, old, new, slot, col: (
                k.at[:, new].set(k[:, old]),
                v.at[:, new].set(v[:, old]),
                tbl.at[slot, col].set(new)))
        # tier migration: batched whole-block gather/scatter between
        # the device pool and the host spill pool
        self._gather_blocks = jax.jit(lm.gather_blocks)
        self._scatter_blocks = jax.jit(lm.scatter_blocks)

    # ------------------------------------------------------------------
    @property
    def decode_path(self) -> str:
        """The decode implementation ticks actually run through.

        ``"shard_map_flash_paged_2d"`` when the paged pool is 2-D
        sharded (block dim over data×model, batch partitioned across
        data); ``"shard_map_flash"`` when the 1-D sharded path really
        executes; ``"flash"`` when the internal single-shard combine
        takes over — model axis of size 1, or the sharded dim not
        divisible (``max_len`` for a dense cache, ``n_blocks`` for a
        paged pool); ``"xla"`` when no mesh was provided.
        """
        impl = self.cfg.decode_impl
        if impl == "xla":
            return impl
        if self.cfg.mesh is None:
            return "xla"               # lm.decode_step's own guard
        if impl == "shard_map_flash":
            from repro.dist.flash_decode import (pool_sharding_kind,
                                                 uses_seq_sharding)
            if self.kv_residency == "paged":
                kind = pool_sharding_kind(
                    self.cfg.mesh, self.n_blocks, self.max_batch,
                    self.cfg.data_axes, self.cfg.model_axis)
                if kind == "2d":
                    return "shard_map_flash_paged_2d"
                if kind == "none":
                    return "flash"     # flash_decode's single-shard path
            elif not uses_seq_sharding(self.cfg.mesh, self.max_len,
                                       self.cfg.model_axis):
                return "flash"
        return impl

    @property
    def combine_topology(self) -> str:
        """The model-axis softmax-combine topology decode ticks run —
        the same :func:`repro.dist.flash_decode.combine_topology`
        predicate the kernels dispatch on, with the engine's RunCfg
        override (a plan-recorded or caller-pinned topology) applied.
        Paths with no cross-shard combine (xla / single-shard flash)
        report "flat"."""
        if self.decode_path not in ("shard_map_flash",
                                    "shard_map_flash_paged_2d"):
            return "flat"
        from repro.dist.flash_decode import combine_topology
        return combine_topology(self.cfg.mesh,
                                model_axis=self.cfg.model_axis,
                                override=self.cfg.combine_topology)

    # ---------------- disaggregated prefill ---------------------------
    @property
    def prefill_mode(self) -> str:
        """Effective prefill mode: ``"disagg"`` while a fleet serves,
        ``"degraded"`` after the fleet exhausted its restart budget
        (prefill is back in-process), ``"inline"`` otherwise."""
        if self.degraded is not None:
            return "degraded"
        return self.kv_prefill_mode

    def attach_fleet(self, fleet) -> None:
        """Switch prefill to disaggregated mode through ``fleet`` (a
        :class:`repro.serve.disagg.PrefillFleet`).  Typed rejections:
        chunked block-native prefill needs the paged pool to scatter
        into and pure-attention KV to chunk (an SSM path's state is
        sequential across the whole prompt)."""
        if self.kv_residency != "paged":
            raise ValueError(
                "disaggregated prefill streams pool-block-shaped KV "
                "chunks — a dense-residency engine has no block pool "
                "to scatter them into")
        if not self.arch.has_attention or self.arch.has_ssm:
            raise ValueError(
                f"disaggregated prefill needs pure-attention KV; "
                f"{self.arch.name} carries SSM state that is sequential "
                "across the whole prompt")
        self._fleet = fleet
        self.kv_prefill_mode = "disagg"

    def shutdown(self) -> None:
        """Stop the prefill fleet (if any).  Idempotent."""
        if self._fleet is not None:
            self._fleet.shutdown()

    @classmethod
    def from_plan(cls, plan, params, *, arch: Optional[ArchConfig] = None,
                  mesh=None, max_batch: Optional[int] = None,
                  max_len: Optional[int] = None, seed: int = 0,
                  kv_admission: Optional[str] = None,
                  kv_prefix_reuse: Optional[str] = None,
                  kv_host_blocks: Optional[int] = None,
                  kv_prefetch: Optional[str] = None,
                  combine_topology: Optional[str] = None,
                  preemption: Optional[PreemptionPolicy] = None,
                  kv_prefill_mode: Optional[str] = None,
                  disagg_workers: int = 0,
                  disagg_opts: Optional[Dict[str, Any]] = None,
                  fleet=None) -> "ServeEngine":
        """Build an engine from the frozen plan artifact.

        The plan supplies everything the kwargs constructor asks for:
        the RunCfg (flash-attention tiles, padded head counts, decode
        implementation, pallas-vs-ref dispatch), the KV-cache sizing
        (padded kv/ssm heads), the admission mode the cost model chose
        (``kv_admission`` — grow-on-demand grants when the pool is the
        reclamation bet, worst-case reservation when it covers every
        slot), and the batching limits (the workload dims carried in
        the artifact).  ``arch`` overrides the registry lookup for
        reduced/custom configs whose name shadows a registered one;
        ``max_batch``/``max_len`` override the plan limits (e.g. a
        single-host deployment of a decode_32k plan); ``kv_admission``
        overrides the plan's admission mode (an ops escape hatch —
        e.g. forcing ``reserve`` while diagnosing preemption churn).

        With a ``mesh`` the engine's params and KV cache are placed per
        the plan's axis rules and a ``shard_map_flash`` decode decision
        is honored end-to-end.  Without one the engine is
        single-process, so a plan that chose the seq-sharded decode
        falls back to the XLA decode path (the sharding decision needs
        a real mesh).

        Workload-dims compatibility is validated instead of silently
        sizing the cache from stale dims: a non-decode plan has no
        serving dims at all (both overrides are then required), and
        overrides *larger* than the dims the plan was specialized for
        are rejected — the pass sized the KV memory (and, for paged
        residency, the block pool) from those dims, so a bigger runtime
        cache needs a respecialized plan, not a quiet under-allocation.
        """
        from repro.core.passes.lowering import build_run_cfg
        arch = arch if arch is not None else get_arch(plan.arch)
        if plan.shape_kind != "decode":
            if max_batch is None or max_len is None:
                raise ValueError(
                    f"plan {plan.content_hash()[:12]} was specialized for "
                    f"shape_kind={plan.shape_kind!r}, not a decode workload; "
                    f"its dims (seq_len={plan.seq_len}, "
                    f"global_batch={plan.global_batch}) cannot size a "
                    "serving cache — pass max_batch= and max_len= "
                    "explicitly, or specialize a decode shape")
        else:
            if max_len is not None and plan.seq_len and max_len > plan.seq_len:
                raise ValueError(
                    f"max_len={max_len} exceeds the seq_len={plan.seq_len} "
                    f"this plan was specialized for — the pass sized the KV "
                    "memory from that dim; respecialize with the larger "
                    "decode shape instead of overriding past it")
            if max_batch is not None and plan.global_batch \
                    and max_batch > plan.global_batch:
                raise ValueError(
                    f"max_batch={max_batch} exceeds the global_batch="
                    f"{plan.global_batch} this plan was specialized for — "
                    "respecialize with the larger decode shape instead of "
                    "overriding past it")
        cfg = build_run_cfg(plan, arch, mesh)
        if mesh is None and cfg.decode_impl != "xla":
            cfg = dataclasses.replace(cfg, decode_impl="xla")
        if combine_topology is not None:
            # ops escape hatch, same shape as kv_admission: pin the
            # softmax-combine wire pattern over the plan's record
            cfg = dataclasses.replace(cfg, combine_topology=combine_topology)
        if max_batch is None:
            max_batch = (plan.global_batch
                         if plan.shape_kind == "decode" and plan.global_batch
                         else 8)
        if max_len is None:
            max_len = plan.seq_len or 512
        eng = cls(arch, params, cfg, max_batch=max_batch, max_len=max_len,
                  ssm_heads=cfg.ssm_heads_padded, kv_heads=cfg.kv_heads_padded,
                  seed=seed,
                  kv_residency=str(plan.estimates.get("kv_residency",
                                                      "dense")),
                  kv_block_len=int(plan.estimates.get("kv_block_len", 0)),
                  kv_n_blocks=int(plan.estimates.get("kv_n_blocks", 0)),
                  kv_admission=(kv_admission if kv_admission is not None
                                else str(plan.estimates.get("kv_admission",
                                                            "reserve"))),
                  kv_prefix_reuse=(
                      kv_prefix_reuse if kv_prefix_reuse is not None
                      else str(plan.estimates.get("kv_prefix_reuse", "on"))),
                  kv_host_blocks=(
                      kv_host_blocks if kv_host_blocks is not None
                      else int(plan.estimates.get("kv_host_blocks", 0))),
                  kv_prefetch=(
                      kv_prefetch if kv_prefetch is not None
                      else str(plan.estimates.get("kv_prefetch", "on"))),
                  preemption=preemption)
        eng.plan = plan
        if mesh is not None:
            eng._place_on_mesh(mesh)
        # disaggregated prefill: honor the pass's kv_prefill_mode
        # decision (or the override), spawning a supervised worker
        # fleet when the caller asked for workers.  disagg needs paged
        # residency and a pure-attention arch; anything else — and a
        # zero worker count — quietly keeps the inline path, exactly
        # like the pass's own inline fallback.
        pmode = (kv_prefill_mode if kv_prefill_mode is not None
                 else str(plan.estimates.get("kv_prefill_mode", "inline")))
        if pmode == "disagg":
            if fleet is None and disagg_workers > 0 \
                    and eng.kv_residency == "paged" \
                    and arch.has_attention and not arch.has_ssm:
                from repro.serve.disagg import PrefillFleet
                fleet = PrefillFleet(
                    plan, arch, params, disagg_workers,
                    block_len=eng.block_len,
                    kv_heads=cfg.kv_heads_padded,
                    **(disagg_opts or {}))
            if fleet is not None:
                eng.attach_fleet(fleet)
        return eng

    def _place_on_mesh(self, mesh) -> None:
        """Shard params + session cache per the plan's axis rules."""
        from jax.sharding import NamedSharding
        from repro.core.passes.lowering import param_pspecs
        from repro.dist.sharding import cache_pspecs, mesh_sizes

        sizes = mesh_sizes(mesh)
        # resolve against the arrays actually handed to us — their shapes
        # may differ from the IR (reduced configs, caller-side padding)
        pspecs = param_pspecs(self.plan, self.arch, sizes,
                              shapes=self.params)
        self.params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            self.params, pspecs)
        cshapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in self.cache.items()}
        cpspecs = cache_pspecs(self.plan, self.arch, cshapes, sizes)
        shardings = {k: NamedSharding(mesh, s) for k, s in cpspecs.items()}
        self.cache = {k: jax.device_put(v, shardings[k])
                      for k, v in self.cache.items()}
        self._pos_sharding = shardings["pos"]

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0,
               deadline_s: Optional[float] = None) -> int:
        """Queue a request.  ``deadline_s`` (relative seconds) sets a
        per-request deadline: still-pending requests past it are shed
        (``Request.error``) instead of served late, and deadline'd
        requests are spared by victim selection while any deadline-free
        victim exists.  Raises :class:`OverloadError` while the engine
        is past its preemption-rate threshold — reject at the door, not
        a queue that can only thrash."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + max_new_tokens > self.max_len:
            # past capacity the per-slot append clamps onto the last cache
            # row and silently corrupts the tail — refuse loudly instead
            raise ValueError(
                f"request needs {len(prompt)} prompt + {max_new_tokens} new "
                f"tokens > max_len={self.max_len} cache rows; raise max_len "
                "or lower max_new_tokens")
        if self.kv_residency == "paged":
            need = self._blocks_needed(len(prompt), max_new_tokens)
            sub = self.n_blocks // max(1, self.pool_groups)
            if need > sub:
                # a request draws all its blocks from ONE data shard's
                # sub-pool; even grow-on-demand admission would hold the
                # full budget simultaneously by its last tick — refuse
                # loudly, not a silent hang (or a preemption storm)
                raise ValueError(
                    f"request needs {need} blocks of {self.block_len} rows "
                    f"but each sub-pool holds only {sub} "
                    f"({self.n_blocks} blocks over {self.pool_groups} "
                    "sub-pool(s)); raise kv_n_blocks or lower "
                    "max_new_tokens")
        if self.overloaded():
            raise OverloadError(
                f"engine is shedding load: {self._recent_preemptions()} "
                f"preemption(s) in the last "
                f"{self.preemption.shed_window_ticks} ticks exceeds the "
                f"shed rate {self.preemption.shed_rate}/tick — back off "
                "and retry, or route to another replica")
        r = Request(self._rid, prompt, max_new_tokens, temperature,
                    t_submit=time.time())
        if deadline_s is not None:
            r.deadline = r.t_submit + deadline_s
        self._rid += 1
        self.pending.append(r)
        return r.rid

    def _blocks_needed(self, plen: int, max_new: int) -> int:
        """Blocks covering every cache row the request can ever touch
        (``plen`` prompt rows + one append per decode tick) — its
        lifetime *peak* holding under either admission mode.  A request
        the prefill sample already satisfies (``max_new <= 1``) finishes
        before any cache write and needs none."""
        if max_new <= 1:
            return 0
        return -(-(plen + max_new) // self.block_len)

    def _admission_blocks(self, r: Request) -> int:
        """Blocks admission must secure before prefilling ``r``:
        the full worst-case budget under ``reserve`` (mid-decode grants
        can then never fail), just the blocks covering the (re-)prefill
        rows under ``grant`` (the rest arrive one block boundary at a
        time)."""
        if self.kv_residency != "paged":
            return 0
        if r.max_new_tokens <= 1 and not r.out_tokens:
            return 0                   # satisfied by the prefill sample
        if self.kv_admission == "grant":
            return -(-len(r.feed_tokens) // self.block_len)
        return self._blocks_needed(len(r.prompt), r.max_new_tokens)

    def block_stats(self) -> Dict[str, int]:
        """Pool accounting (``free + in_use`` always equals ``total``
        per tier; dense engines report an empty 0-block pool).
        ``shared`` counts resident blocks with more than one holder;
        ``prefix_trie`` the blocks the prefix cache currently indexes;
        ``cached`` the engine-held cold blocks (trie-retained, either
        tier) the spill scheduler may reclaim at will."""
        st = self._alloc.stats()
        st["prefix_trie"] = (len(self._prefix)
                             if self._prefix is not None else 0)
        st["cached"] = len(self._cached)
        return st

    def pressure_stats(self) -> Dict[str, Any]:
        """Overload-degradation telemetry: how often the engine had to
        fall back down the grant → migrate → preempt → shed ladder —
        plus the prefix-sharing counters (blocks shared right now,
        tokens whose prefill was aliased away, CoW copies taken)."""
        return {"tick": self.tick,
                "preemptions": self.preemptions,
                "migrations": self.migrations,
                "grant_denials": self.grant_denials,
                "shed": len(self.shed),
                "parked": len(self.preempted),
                "straggler_ticks": self.straggler_ticks,
                "overloaded": self.overloaded(),
                "shared_blocks": self._alloc.shared_blocks,
                "prefix_hits": (self._prefix.hits
                                if self._prefix is not None else 0),
                "prefix_hit_tokens": (self._prefix.hit_tokens
                                      if self._prefix is not None else 0),
                "prefix_trie": (len(self._prefix)
                                if self._prefix is not None else 0),
                "prefix_rides": self.prefix_rides,
                "cow_copies": self.cow_copies,
                "spills": self._alloc.spills,
                "promotes": self._alloc.promotes,
                "cached_blocks": len(self._cached),
                "prefill_mode": self.prefill_mode,
                "degraded": (self.degraded.to_json()
                             if self.degraded is not None else None),
                "disagg_dispatches": self.disagg_dispatches,
                "disagg_chunks": self.disagg_chunks,
                "disagg_resumes": self.disagg_resumes,
                "disagg_inflight": len(self._disagg)}

    def telemetry(self) -> Dict[str, Any]:
        """One JSON-serializable snapshot of everything the engine
        knows about itself — plan decisions, queue depths, prefill
        accounting, block-pool state, the degradation ladder, and (in
        disagg mode) the fleet's supervision counters.  This is THE
        observability surface: drivers dump it instead of growing their
        own ad-hoc per-mode prints, and tests pin that ``json.dumps``
        of it round-trips."""
        fleet = self._fleet.stats() if self._fleet is not None else None
        return {
            "tick": self.tick,
            "decode_path": self.decode_path,
            "combine_topology": self.combine_topology,
            "kv_residency": self.kv_residency,
            "kv_admission": self.kv_admission,
            "prefill_mode": self.prefill_mode,
            "requests": {
                "pending": len(self.pending),
                "active": len(self.active),
                "finished": len(self.finished),
                "shed": len(self.shed),
                "parked": len(self.preempted),
                "disagg_inflight": len(self._disagg),
            },
            "prefill": {
                "calls": self.prefill_calls,
                "batches": [int(b) for b in
                            list(self.prefill_batches)[-32:]],
                "rides": self.prefix_rides,
                "disagg": {
                    "dispatches": self.disagg_dispatches,
                    "chunks": self.disagg_chunks,
                    "resumes": self.disagg_resumes,
                    "fleet": fleet,
                },
            },
            "blocks": {k: int(v) for k, v in self.block_stats().items()},
            "pressure": self.pressure_stats(),
        }

    def _recent_preemptions(self) -> int:
        lo = self.tick - self.preemption.shed_window_ticks
        return sum(1 for t in self._preempt_ticks if t > lo)

    def overloaded(self) -> bool:
        """True while the recent preemption rate says new admissions
        would only thrash (the load-shedding trigger)."""
        return (self._recent_preemptions()
                > self.preemption.shed_rate
                * self.preemption.shed_window_ticks)

    def _slot_group(self, slot: int) -> int:
        """The data-shard sub-pool that hosts a slot: the batch dim is
        sharded contiguously across data, so slot ranges map 1:1 onto
        the pool's data-major sub-pools."""
        return slot * self.pool_groups // self.max_batch

    # ---------------- prefix matching at admission --------------------
    def _match_info(self, r: Request) -> Optional[Dict[str, Any]]:
        """Per-request match state for one admission pass: the feed's
        chain hashes plus a per-group memo of trie matches (matching is
        per sub-pool — the combine contract forbids foreign blocks)."""
        if self._prefix is None:
            return None
        return {"hashes": chain_hashes(r.feed_tokens, self.block_len),
                "matches": {}}

    def _match_for(self, r: Request, info: Optional[Dict[str, Any]],
                   group: int) -> List[int]:
        """Longest resident prefix of ``r``'s feed in ``group``'s trie,
        as block ids.  Capped one token short of the whole feed: the
        last feed token's compute must always run here (its logits seed
        a fresh request's sampling; its KV row is the one a resumed
        request's next tick appends)."""
        if info is None:
            return []
        got = info["matches"].get(group)
        if got is None:
            got = self._prefix.match(info["hashes"], group)
            cap = (len(r.feed_tokens) - 1) // self.block_len
            got = got[:cap]
            info["matches"][group] = got
        return got

    def _bucket_key(self, r: Request, matched: List[int]):
        """Admission bucket identity: ``(matched_tokens, tail_tokens)``.
        Compute-skip archs batch one jitted tail forward per bucket, so
        every member must skip the same row count; archs that cannot
        skip (SSM state) bucket by feed length alone."""
        flen = len(r.feed_tokens)
        if self._skip_prefix and matched:
            m = len(matched) * self.block_len
            return (m, flen - m)
        return (0, flen)

    def _can_ride(self, r: Request, matched: List[int]) -> bool:
        """True when admission can skip prefill *entirely*: a fresh
        request whose whole feed-but-last-token is aliased from the
        trie.  Its first decode tick feeds that last token and samples
        the first output — the decode-ride path (zero prefill calls;
        decode logits are bitwise the prefill logits for the same
        position, which the shared-prefix identity tests pin)."""
        if not (self._skip_prefix and matched and not r.out_tokens):
            return False
        if r.max_new_tokens <= 1:
            return False      # satisfied by the sample; never holds blocks
        return len(matched) * self.block_len == len(r.feed_tokens) - 1

    def _register_prefix(self, r: Request,
                         info: Optional[Dict[str, Any]],
                         group: int) -> None:
        """Index ``r``'s full feed blocks in its sub-pool's trie (first
        writer wins) and remember the hashes for migration re-keying."""
        if self._prefix is None or info is None or not r.blocks:
            return
        hashes = info["hashes"]
        r.prefix_hashes = list(hashes)
        self._prefix.insert(hashes, r.blocks[:len(hashes)], group)

    def _release_blocks(self, blocks: List[int]) -> None:
        """Drop one holder reference per block; prune trie entries for
        the blocks that actually left the pool (a freed id's next
        tenant writes unrelated rows).

        With tiering on, a block whose *last* holder is releasing but
        which the prefix trie still indexes is not freed — the engine
        keeps the reference and parks the id in its cold-block cache
        (``_cached``), a page-cache bet: the content costs nothing
        until pressure, and a future admission with the same prefix
        aliases it instead of re-prefilling.  The spill scheduler
        (:meth:`_spill_cold`) reclaims cached blocks on demand — spill
        to host first, drop outright only when the host tier is full
        too."""
        if self.kv_tiering and self._prefix is not None:
            kept = []
            for b in blocks:
                if self._alloc.refcount(b) == 1 \
                        and self._prefix.has_block(b) \
                        and b not in self._cached:
                    self._cached[b] = None
                else:
                    kept.append(b)
            blocks = kept
        if not blocks:
            return
        freed = self._alloc.release(blocks)
        if self._prefix is not None and freed:
            self._prefix.evict(freed)

    # ---------------- tier transitions + spill scheduler --------------
    def _spill_rows(self, pairs: List[Tuple[int, int]]) -> None:
        """Copy the k/v rows of just-spilled blocks into the host pool
        (one batched device→host gather per tensor).  The vacated HBM
        ids are already back on their free lists, but their rows stay
        intact until a next tenant writes — the copy races nothing."""
        old_ids = jnp.asarray(np.asarray([b for b, _ in pairs], np.int32))
        idx = np.asarray([h - self.n_blocks for _, h in pairs], np.int64)
        self._host["k"][:, idx] = np.asarray(
            self._gather_blocks(self.cache["k"], old_ids))
        self._host["v"][:, idx] = np.asarray(
            self._gather_blocks(self.cache["v"], old_ids))

    def _promote_rows(self, pairs: List[Tuple[int, int]],
                      k_rows=None, v_rows=None) -> None:
        """Copy spilled k/v rows back into the device pool at the
        pairs' new HBM ids — from the prefetcher's staged device arrays
        when they landed, else a synchronous host→device transfer (the
        stall ``kv_prefetch="off"`` benchmarks)."""
        idx = np.asarray([h - self.n_blocks for h, _ in pairs], np.int64)
        new_ids = jnp.asarray(np.asarray([b for _, b in pairs], np.int32))
        if k_rows is None:
            k_rows = jnp.asarray(self._host["k"][:, idx])
            v_rows = jnp.asarray(self._host["v"][:, idx])
        self.cache["k"] = self._scatter_blocks(self.cache["k"], new_ids,
                                               k_rows)
        self.cache["v"] = self._scatter_blocks(self.cache["v"], new_ids,
                                               v_rows)

    def _promote_matched(self, matched: List[int],
                         group: int) -> List[int]:
        """Resolve a matched block list to decode-ready HBM ids — the
        hit-after-spill path.  Ids another request promoted earlier in
        this same admission pass are followed through ``_promo_map``
        (their host ids are already back on the host free list); any
        still-host-resident block is promoted into ``group`` now: rows
        copied back, trie and cold-cache entries re-keyed.  Placement
        already budgeted the draws (:meth:`_hbm_matched`)."""
        if not self.kv_tiering or not matched:
            return list(matched)
        out = [self._promo_map.get(b, b) for b in matched]
        host_ids = [b for b in out if self._alloc.tier_of(b) == "host"]
        if not host_ids:
            return out
        pairs = self._alloc.promote(host_ids, group)
        assert pairs is not None, "placement budgeted the promote draw"
        self._promote_rows(pairs)
        self._prefix.rekey(pairs, "hbm")
        for old, new in pairs:
            if old in self._cached:
                del self._cached[old]
                self._cached[new] = None
            self._promo_map[old] = new
        trans = dict(pairs)
        return [trans.get(b, b) for b in out]

    def _evict_cached_host(self, n: int) -> int:
        """Drop up to ``n`` oldest engine-cached *host*-tier blocks
        outright (free the ids, prune the trie) — the host pool's own
        reclamation, run when a spill or a park finds it full."""
        victims = [b for b in self._cached
                   if self._alloc.tier_of(b) == "host"][:n]
        for b in victims:
            del self._cached[b]
            freed = self._alloc.release([b])
            if self._prefix is not None and freed:
                self._prefix.evict(freed)
        return len(victims)

    def _spill_cold(self, group: int, need: int) -> int:
        """The reclaim rung *before* the grant → migrate → preempt →
        shed ladder: free up to ``need`` HBM blocks in ``group`` by
        moving the engine's oldest cached (cold, trie-retained) blocks
        to the host tier.  Cold-block selection is insertion order over
        ``_cached`` — exactly the blocks idle sessions, evicted trie
        tails, and fully-decoded prompts left behind, oldest first.
        When the host pool is full the oldest cached host block is
        evicted to make room; when there is no host room at all the
        cold block is dropped outright (it was a cache — the content
        is reconstructible by re-prefill).  Blocks an admission has
        since aliased (refcount > 1) are pinned: an active table points
        at them.  Returns the number of HBM blocks actually freed."""
        if not self.kv_tiering:
            return 0
        freed = 0
        while freed < need:
            cand = next((b for b in self._cached
                         if b < self.n_blocks
                         and self._alloc.group_of(b) == group
                         and self._alloc.refcount(b) == 1), None)
            if cand is None:
                break
            if self._alloc.host_free == 0:
                self._evict_cached_host(1)
            if self._alloc.host_free > 0:
                pairs = self._alloc.spill([cand])
                assert pairs is not None, "host headroom was just checked"
                self._spill_rows(pairs)
                self._prefix.rekey(pairs, "host")
                del self._cached[cand]
                self._cached[pairs[0][1]] = None
            else:
                del self._cached[cand]
                fr = self._alloc.release([cand])
                if self._prefix is not None and fr:
                    self._prefix.evict(fr)
            freed += 1
        return freed

    def spill_cached(self, group: Optional[int] = None) -> int:
        """Force-spill every unpinned cached HBM block to the host tier
        (test/ops hook: drives the hit-after-spill path without real
        pool pressure).  Returns the number of blocks spilled."""
        total = 0
        gs = range(self.pool_groups) if group is None else [group]
        for g in gs:
            n = sum(1 for b in self._cached
                    if b < self.n_blocks and self._alloc.group_of(b) == g
                    and self._alloc.refcount(b) == 1)
            total += self._spill_cold(g, n)
        return total

    def drop_block_cache(self) -> int:
        """Release every engine-cached cold block (both tiers) and
        prune their trie entries — the test/ops hook that restores the
        exact-leak-check identity (``free == total`` per tier once no
        requests are live).  Returns the number of blocks freed."""
        blocks = list(self._cached)
        self._cached.clear()
        freed = self._alloc.release(blocks) if blocks else []
        if self._prefix is not None and freed:
            self._prefix.evict(freed)
        return len(freed)

    def _hbm_matched(self, matched: List[int]) -> int:
        """Matched trie blocks that are already HBM-resident — only
        those reduce the admission draw.  A host-tier match still saves
        the prefill compute, but its promote consumes one free HBM
        block from the slot's sub-pool exactly like a fresh allocation
        would, so placement must budget for it."""
        if not self.kv_tiering:
            return len(matched)
        return sum(1 for b in matched if self._alloc.tier_of(b) == "hbm")

    def _place(self, r: Request, avail: List[int],
               free_by_group: Dict[int, int],
               info: Optional[Dict[str, Any]] = None) -> Optional[int]:
        """Reserve a free slot whose sub-pool can cover ``r``'s
        admission block need net of aliased blocks; mutates both
        accounting structures.  With match info and multiple sub-pools,
        slots are tried longest-match-first (admission prefers the
        sub-pool holding the longest resident prefix), FIFO otherwise.
        """
        need_full = self._admission_blocks(r)
        order = list(range(len(avail)))
        if info is not None and self.pool_groups > 1:
            order.sort(key=lambda i: (
                -len(self._match_for(r, info, self._slot_group(avail[i]))),
                avail[i]))
        for i in order:
            g = self._slot_group(avail[i])
            matched = self._match_for(r, info, g) if info is not None else []
            need = max(0, need_full - self._hbm_matched(matched))
            if need <= free_by_group[g]:
                free_by_group[g] -= need
                return avail.pop(i)
        return None

    def _place_bucket(self, r: Request, info: Optional[Dict[str, Any]],
                      key, avail: List[int],
                      free_by_group: Dict[int, int]) -> Optional[int]:
        """Like :meth:`_place`, but only into a slot whose sub-pool's
        match keeps ``r`` in the head request's admission bucket (same
        skipped-prefix length, same tail length)."""
        need_full = self._admission_blocks(r)
        for i, s in enumerate(avail):
            g = self._slot_group(s)
            matched = self._match_for(r, info, g) if info is not None else []
            if self._bucket_key(r, matched) != key:
                continue
            need = max(0, need_full - self._hbm_matched(matched))
            if need <= free_by_group[g]:
                free_by_group[g] -= need
                return avail.pop(i)
        return None

    # ---------------- disaggregated prefill paths ---------------------
    def _admit_disagg(self) -> None:
        """Head-of-line admission in disagg mode: fully-matched feeds
        still ride inline (zero prefill either way); everything else
        reserves a slot plus its FULL admission-block need and
        dispatches to the worker fleet.  Partial prefix matches are not
        aliased on this path — the worker recomputes the whole feed and
        the trie indexes the finished blocks at completion."""
        self._promo_map.clear()
        while self.pending and self.free_slots:
            head = self.pending[0]
            info = self._match_info(head)
            # alias-aware probe: decode-ride beats any dispatch
            avail = list(self.free_slots)
            fbg = {g: self._alloc.free_in(g)
                   for g in range(self.pool_groups)}
            s_alias = self._place(head, avail, fbg, info)
            if s_alias is not None and self._can_ride(
                    head,
                    self._match_for(head, info,
                                    self._slot_group(s_alias))):
                self.pending.pop(0)
                self.free_slots.remove(s_alias)
                self._admit_ride(head, s_alias, info)
                continue
            avail = list(self.free_slots)
            fbg = {g: self._alloc.free_in(g)
                   for g in range(self.pool_groups)}
            s0 = self._place(head, avail, fbg, None)
            if s0 is None and self.kv_tiering and self._cached:
                # tier rung: spill cold cached blocks, retry once
                need0 = self._admission_blocks(head)
                for g in range(self.pool_groups):
                    short = need0 - self._alloc.free_in(g)
                    if short > 0:
                        self._spill_cold(g, short)
                avail = list(self.free_slots)
                fbg = {g: self._alloc.free_in(g)
                       for g in range(self.pool_groups)}
                s0 = self._place(head, avail, fbg, None)
            if s0 is None:
                return             # pool exhausted: wait for frees
            if head.rid in self._inline_poison:
                # this rid's worker prefill raised (deterministically,
                # as far as we know): run it in-process instead
                self.pending.pop(0)
                self.free_slots.remove(s0)
                self._admit_group([head], [s0])
                continue
            if not self._dispatch_prefill(head, s0):
                return             # no live worker (respawn in flight)
            self.pending.pop(0)
            self.free_slots.remove(s0)

    def _dispatch_prefill(self, r: Request, slot: int,
                          start_block: int = 0,
                          flight: Optional[_DisaggFlight] = None) -> bool:
        """Ship ``r``'s feed to the fleet.  A fresh dispatch allocates
        the admission blocks first — they are the journal's scatter
        target; a re-dispatch (``flight`` set) keeps them and gathers
        the journaled blocks' rows back out of the pool as the worker's
        resume prefix (token-identical: the rows ARE the prefix KV a
        dense prefill would have computed)."""
        g = self._slot_group(slot)
        fresh = flight is None
        if fresh:
            blocks = self._alloc.allocate(self._admission_blocks(r), g)
            if blocks is None:
                return False       # placement said yes; lost the race
            r.blocks = blocks
        pk = pv = None
        if start_block:
            ids = jnp.asarray(np.asarray(r.blocks[:start_block], np.int32))
            pk = np.asarray(self._gather_blocks(self.cache["k"], ids))
            pv = np.asarray(self._gather_blocks(self.cache["v"], ids))
            L = pk.shape[0]
            m = start_block * self.block_len
            pk = pk.reshape(L, m, *pk.shape[3:])
            pv = pv.reshape(L, m, *pv.shape[3:])
        feed = r.feed_tokens
        ok = self._fleet.dispatch(
            r.rid, feed[start_block * self.block_len:],
            prefix_k=pk, prefix_v=pv)
        if not ok:
            if fresh and r.blocks:
                self._release_blocks(r.blocks)
                r.blocks = []
            return False
        if fresh:
            r.slot = int(slot)
            self._disagg[r.rid] = _DisaggFlight(
                request=r, slot=slot, group=g,
                nb_feed=-(-len(feed) // self.block_len))
        elif start_block:
            self.disagg_resumes += 1
        self.disagg_dispatches += 1
        return True

    def _on_chunk(self, fl: _DisaggFlight, idx: int,
                  k_rows, v_rows) -> None:
        """Scatter one streamed pool-block-shaped KV slab into the
        paged pool (the tier-spill mover run in reverse) and advance
        the journal.  Chunks re-sent after a re-dispatch overwrite
        bit-identical rows — idempotent by the chunked-prefill
        contract.  Requests with no blocks (satisfied by the prefill
        sample) only need the logits, so their chunks drop."""
        r = fl.request
        if not r.blocks or idx >= len(r.blocks) or idx > fl.acked:
            return
        k_rows = np.asarray(k_rows)
        v_rows = np.asarray(v_rows)
        t = k_rows.shape[1]
        if t < self.block_len:
            # partial tail block: pad to block shape (slot_len masks
            # the zero rows, exactly like the inline scatter's clamp)
            shape = (k_rows.shape[0], self.block_len, *k_rows.shape[2:])
            kf = np.zeros(shape, k_rows.dtype)
            vf = np.zeros(shape, v_rows.dtype)
            kf[:, :t] = k_rows
            vf[:, :t] = v_rows
            k_rows, v_rows = kf, vf
        bid = jnp.asarray(np.asarray([r.blocks[idx]], np.int32))
        self.cache["k"] = self._scatter_blocks(
            self.cache["k"], bid, jnp.asarray(k_rows)[:, None])
        self.cache["v"] = self._scatter_blocks(
            self.cache["v"], bid, jnp.asarray(v_rows)[:, None])
        self.disagg_chunks += 1
        if t == self.block_len and idx == fl.acked:
            fl.acked = idx + 1

    def _complete_prefill(self, fl: _DisaggFlight, logits) -> None:
        """A worker finished a prompt: install the block-table row,
        activate the slot, and (for fresh requests) sample the first
        token from the streamed logits — bitwise the logits the inline
        prefill would have produced."""
        r, slot = fl.request, fl.slot
        del self._disagg[r.rid]
        if r.rid in self._redispatch:
            self._redispatch.remove(r.rid)
        self.prefill_calls += 1
        self.prefill_batches.append(1)
        if r.blocks:
            rows = np.full((int(self.cache["block_tbl"].shape[1]),), -1,
                           np.int32)
            rows[:len(r.blocks)] = r.blocks
            self.cache["block_tbl"] = \
                self.cache["block_tbl"].at[slot].set(jnp.asarray(rows))
        if not r.out_tokens:
            tok = self._sample(jnp.asarray(np.asarray(logits)),
                               r.temperature, self._next_key())
            r.out_tokens.append(int(tok))
            r.t_first = time.time()
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = r.t_first
                self.finished.append(r)
                self._release_slot(slot, r)
                return
        # a resumed re-prefill keeps its retained tokens: the sample
        # these logits would re-derive is already on the host
        self.slot_len[slot] = len(r.feed_tokens)
        r.slot = int(slot)
        self.active[slot] = r
        if self._prefix is not None and r.blocks:
            hashes = chain_hashes(r.feed_tokens, self.block_len)
            r.prefix_hashes = list(hashes)
            self._prefix.insert(hashes, r.blocks[:len(hashes)], fl.group)
            self._prefix.misses += 1

    def _abort_flight(self, fl: _DisaggFlight) -> None:
        """Take a flight out of service: journal dropped, blocks
        released, slot returned.  The fleet-side cancel makes any late
        chunks from a still-running worker drop on the floor."""
        r = fl.request
        self._disagg.pop(r.rid, None)
        if r.rid in self._redispatch:
            self._redispatch.remove(r.rid)
        if self._fleet is not None:
            self._fleet.cancel(r.rid)
        if r.blocks:
            self._release_blocks(r.blocks)
            r.blocks = []
        self.free_slots.append(fl.slot)
        self.slot_len[fl.slot] = 0
        fl.acked = 0

    def _on_worker_error(self, rid: int, err: str) -> None:
        """A worker's prefill *raised* for this request (poison input,
        not a process death): re-dispatching would loop forever, so the
        flight aborts and the request re-queues marked inline-only."""
        fl = self._disagg.get(rid)
        if fl is None:
            return
        r = fl.request
        self._abort_flight(fl)
        self._inline_poison.add(rid)
        self.pending.insert(0, r)

    def _shed_expired_flights(self) -> None:
        """Deadline shedding composes with disagg: a request whose
        deadline passes mid-prefill sheds exactly like a pending one —
        blocks released, same ``Request.error`` surface."""
        if not self._disagg:
            return
        if not any(fl.request.deadline is not None
                   for fl in self._disagg.values()):
            return
        now = time.time()
        for fl in list(self._disagg.values()):
            r = fl.request
            if r.deadline is not None and now > r.deadline:
                self._abort_flight(fl)
                self._shed(r, f"deadline missed during disagg prefill "
                              f"(tick {self.tick})")

    def _enter_degraded(self) -> None:
        """Every fleet slot retired past its restart budget: flip to
        in-process prefill under a typed ``DegradedMode`` and re-queue
        the orphaned flights at the front of the pending queue, oldest
        first.  Token-identical under greedy sampling — the inline
        re-prefill rebuilds exactly the KV the workers would have
        streamed."""
        if self.degraded is not None:
            return
        self.degraded = dataclasses.replace(self._fleet.degraded,
                                            at_tick=self.tick)
        for rid in sorted(self._disagg.keys(), reverse=True):
            fl = self._disagg[rid]
            self._abort_flight(fl)
            self.pending.insert(0, fl.request)
        self._redispatch = []
        self._fleet.shutdown()

    def _poll_disagg(self) -> None:
        """Drain fleet events: scatter arrived chunks, complete
        finished prefills, queue re-dispatches for rids a worker death
        orphaned, degrade when the whole fleet has retired — then retry
        queued re-dispatches (resuming at the last acked block
        boundary, never past the final block so the worker always has
        at least one tail token to derive the logits from)."""
        if self._fleet is None or self.degraded is not None:
            return
        for ev in self._fleet.poll():
            kind = ev[0]
            if kind == "chunk":
                fl = self._disagg.get(ev[1])
                if fl is not None:
                    self._on_chunk(fl, ev[2], ev[3], ev[4])
            elif kind == "done":
                fl = self._disagg.get(ev[1])
                if fl is not None:
                    self._complete_prefill(fl, ev[2])
            elif kind == "dead":
                if ev[1] in self._disagg \
                        and ev[1] not in self._redispatch:
                    self._redispatch.append(ev[1])
            elif kind == "error":
                self._on_worker_error(ev[1], ev[2])
        if self._fleet.degraded is not None:
            self._enter_degraded()
            return
        still: List[int] = []
        for rid in self._redispatch:
            fl = self._disagg.get(rid)
            if fl is None:
                continue
            start = min(fl.acked, fl.nb_feed - 1) if fl.request.blocks \
                else 0
            if not self._dispatch_prefill(fl.request, fl.slot,
                                          start_block=start, flight=fl):
                still.append(rid)
        self._redispatch = still

    def _admit(self) -> None:
        """Bucketed batched admission: all pending prompts sharing the
        head-of-line's bucket — feed length, plus skipped-prefix length
        when prefix reuse matches resident blocks — that fit a (slot,
        sub-pool) pair are prefilled in ONE jitted call (tail-only when
        a prefix is aliased).  A request whose whole feed-but-last is
        resident skips prefill entirely and goes straight to decode.
        A request takes its admission blocks from the sub-pool of the
        data shard hosting its slot (2-D pool sharding; one global pool
        when ``pool_groups == 1``).  When no pair can cover the head
        request, admission waits for a finisher — head-of-line
        blocking, so exhaustion delays rather than starves (and
        ``run_until_idle`` raises on true deadlock).

        In disagg mode admission routes through
        :meth:`_admit_disagg` instead (dispatch to the worker fleet;
        rides still inline).
        """
        if self._fleet is not None and self.prefill_mode == "disagg":
            self._admit_disagg()
            return
        self._promo_map.clear()        # promoted-id map is per admission
        while self.pending and self.free_slots:
            head = self.pending[0]
            info0 = self._match_info(head)
            avail = list(self.free_slots)
            free_by_group = {g: self._alloc.free_in(g)
                             for g in range(self.pool_groups)}
            s0 = self._place(head, avail, free_by_group, info0)
            if s0 is None:
                if not (self.kv_tiering and self._cached):
                    return             # pool exhausted: wait for frees
                # tier rung: spill cold cached blocks to host until some
                # sub-pool can cover the head, then retry the placement
                # once (the match memo is stale after a rekey)
                need0 = self._admission_blocks(head)
                for g in range(self.pool_groups):
                    short = need0 - self._alloc.free_in(g)
                    if short > 0:
                        self._spill_cold(g, short)
                info0 = self._match_info(head)
                avail = list(self.free_slots)
                free_by_group = {g: self._alloc.free_in(g)
                                 for g in range(self.pool_groups)}
                s0 = self._place(head, avail, free_by_group, info0)
                if s0 is None:
                    return             # truly exhausted: wait for frees
            m0 = self._match_for(head, info0, self._slot_group(s0))
            if self._can_ride(head, m0):
                self.pending.pop(0)
                self.free_slots.remove(s0)
                self._admit_ride(head, s0, info0)
                continue
            key0 = self._bucket_key(head, m0)
            group: List[Request] = [head]
            slots: List[int] = [s0]
            matches: List[List[int]] = [m0]
            infos: List[Optional[Dict[str, Any]]] = [info0]
            rest: List[Request] = []
            for r in self.pending[1:]:
                s = None
                info = None
                if len(r.feed_tokens) == len(head.feed_tokens):
                    info = self._match_info(r)
                    s = self._place_bucket(r, info, key0, avail,
                                           free_by_group)
                if s is None:
                    rest.append(r)
                else:
                    group.append(r)
                    slots.append(s)
                    matches.append(
                        self._match_for(r, info, self._slot_group(s)))
                    infos.append(info)
            self.pending = rest
            for s in slots:
                self.free_slots.remove(s)
            if self.kv_tiering:
                # hit-after-spill: matched lists may name host-tier (or
                # already-promoted) blocks — resolve them to HBM ids
                # before any gather or alias touches the device pool
                matches = [self._promote_matched(m, self._slot_group(s))
                           for m, s in zip(matches, slots)]
            self._admit_group(group, slots, matches, infos, key0)

    def _admit_ride(self, r: Request, slot: int,
                    info: Dict[str, Any]) -> None:
        """Zero-prefill admission: alias the matched blocks (refcount
        bump), grant the fresh ones the budget calls for, install the
        table row, and hand the request straight to decode — its first
        tick feeds the last prompt token at position ``matched_tokens``
        and samples the first output."""
        g = self._slot_group(slot)
        matched = self._promote_matched(self._match_for(r, info, g), g)
        need = self._admission_blocks(r)
        self._alloc.retain(matched)
        fresh = self._alloc.allocate(need - len(matched), g)
        assert fresh is not None, "placement checked the free count"
        r.blocks = list(matched) + fresh
        rows = np.full((int(self.cache["block_tbl"].shape[1]),), -1,
                       np.int32)
        rows[:len(r.blocks)] = r.blocks
        self.cache["block_tbl"] = \
            self.cache["block_tbl"].at[slot].set(jnp.asarray(rows))
        r.slot = int(slot)
        m_tok = len(matched) * self.block_len
        self.slot_len[slot] = m_tok
        self.active[slot] = r
        self._register_prefix(r, info, g)
        self._prefix.hits += 1
        self._prefix.hit_tokens += m_tok
        self.prefix_rides += 1

    def _admit_group(self, group: List[Request], slots: List[int],
                     matches: Optional[List[List[int]]] = None,
                     infos: Optional[List[Optional[Dict[str, Any]]]] = None,
                     bucket=None) -> None:
        """One jitted prefill for a bucket of requests, each with a
        pre-reserved slot (its sub-pool is the one the request's blocks
        will come from).  A resumed (previously preempted) request's
        feed is prompt+generated-so-far: the prefill rebuilds its KV
        rows and its sample is discarded — the host already holds the
        token it would re-derive.

        With a nonzero skipped-prefix bucket (compute-skip archs whose
        members all matched the same number of resident blocks), the
        matched rows are *gathered from the pool* and only the tail
        runs through :func:`repro.models.lm.prefill_tail`; the matched
        blocks are aliased, not rewritten.

        The batch dim is padded to the next power of two (dummy rows
        repeat the first prompt and are discarded), so each prompt
        length compiles at most ``log2(max_batch)`` prefill programs
        instead of one per arrival-group size."""
        if matches is None:
            matches = [[] for _ in group]
        if infos is None:
            infos = [None] * len(group)
        m_tok = bucket[0] if bucket else 0
        toks = np.stack([r.feed_tokens[m_tok:] for r in group])
        padded = 1
        while padded < len(group):
            padded *= 2
        padded = min(padded, self.max_batch)   # never a batch no engine fills
        if padded > len(group):
            toks = np.concatenate(
                [toks, np.repeat(toks[:1], padded - len(group), axis=0)])
        cacheg = None
        if m_tok:
            # gather the aliased prefix rows (resident pool blocks) as
            # the tail forward's K/V prefix; dummy batch rows reuse the
            # first member's blocks (discarded, and read-only anyway)
            nbm = m_tok // self.block_len
            blk = np.asarray(matches, np.int32)            # (Bs, nbm)
            if padded > len(group):
                blk = np.concatenate(
                    [blk, np.repeat(blk[:1], padded - len(group), axis=0)])
            bid = jnp.asarray(blk.reshape(-1))
            pk = self.cache["k"][:, bid]
            pv = self.cache["v"][:, bid]
            L = pk.shape[0]
            pk = pk.reshape(L, padded, m_tok, *pk.shape[3:])
            pv = pv.reshape(L, padded, m_tok, *pv.shape[3:])
            logits, tail_k, tail_v = self._prefill_tail(
                self.params, {"tokens": jnp.asarray(toks)}, pk, pv)
        else:
            logits, cacheg = self._prefill(self.params,
                                           {"tokens": jnp.asarray(toks)})
        self.prefill_calls += 1
        self.prefill_batches.append(len(group))
        keys = jax.random.split(self._next_key(), len(group))
        live: List[Request] = []
        idxs: List[int] = []
        live_slots: List[int] = []
        for i, r in enumerate(group):
            if r.out_tokens:
                # resumed after preemption: keep the retained tokens,
                # keep decoding from where the eviction cut in
                live.append(r)
                idxs.append(i)
                live_slots.append(slots[i])
                continue
            tok = self._sample(logits[i], r.temperature, keys[i])
            r.out_tokens.append(int(tok))
            r.t_first = time.time()
            if len(r.out_tokens) >= r.max_new_tokens:
                # the prefill sample already met the budget: finish now —
                # no decode tick to over-generate on, no cache copy, no
                # blocks ever allocated, and the reserved slot goes back
                r.done = True
                r.t_done = r.t_first
                self.finished.append(r)
                self.free_slots.append(slots[i])
            else:
                live.append(r)
                idxs.append(i)
                live_slots.append(slots[i])
        if not live:
            return
        plen = len(live[0].feed_tokens)
        slots = np.asarray(live_slots, np.int32)
        gidx = np.asarray(idxs, np.int32)
        live_matches = [matches[i] for i in idxs]
        if self.arch.has_attention:
            if self.kv_residency == "paged":
                if m_tok:
                    self._scatter_tail(live, slots, gidx, tail_k, tail_v,
                                       plen, m_tok, live_matches)
                else:
                    self._scatter_paged_prefill(live, slots, gidx, cacheg,
                                                plen, live_matches)
            else:
                for key in ("k", "v"):
                    self.cache[key] = self.cache[key].at[:, slots].set(
                        cacheg[key][:, gidx])
        for key in ("ssm", "conv"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, slots].set(
                    cacheg[key][:, gidx])
        for slot, r in zip(slots, live):
            r.slot = int(slot)
            self.slot_len[slot] = plen
            self.active[int(slot)] = r
        for i, r in enumerate(live):
            g = self._slot_group(int(slots[i]))
            self._register_prefix(r, infos[idxs[i]], g)
            if self._prefix is not None:
                mt = live_matches[i]
                if mt:
                    self._prefix.hits += 1
                    self._prefix.hit_tokens += len(mt) * self.block_len
                else:
                    self._prefix.misses += 1

    def _scatter_paged_prefill(self, live: List[Request], slots: np.ndarray,
                               gidx: np.ndarray, cacheg, plen: int,
                               matches: Optional[List[List[int]]] = None
                               ) -> None:
        """Move a bucket's prefilled KV rows into their pool blocks.

        Each survivor gets its admission block budget now — matched
        blocks aliased with a refcount bump, the rest freshly allocated
        from *its slot's sub-pool* (admission reserved them, so the
        draw cannot fail).  Only the *unmatched* feed columns are
        scattered (a matched block already holds exactly those rows —
        writing them again would race a sharer's CoW), in one
        gather/scatter per cache tensor; then the block-table rows are
        installed (-1 padding past the allocation).  This is the path
        hybrid (SSM-carrying) archs take on a prefix hit: full-feed
        prefill for the state, aliased capacity for the matched KV.
        """
        bl = self.block_len
        nbp = -(-plen // bl)               # blocks holding prefilled rows
        nb_cols = self.cache["block_tbl"].shape[1]
        rows = np.full((len(live), nb_cols), -1, np.int32)
        ent_req: List[int] = []            # prefill batch row per block
        ent_col: List[int] = []            # feed block column per block
        ent_blk: List[int] = []            # destination pool block
        for i, r in enumerate(live):
            matched = list(matches[i]) if matches else []
            need = self._admission_blocks(r)
            assert need >= nbp >= len(matched), (need, nbp, len(matched))
            if matched:
                self._alloc.retain(matched)
            fresh = self._alloc.allocate(need - len(matched),
                                         self._slot_group(int(slots[i])))
            assert fresh is not None, "admission reserved these blocks"
            r.blocks = matched + fresh
            rows[i, :need] = r.blocks
            for c in range(len(matched), nbp):
                ent_req.append(int(gidx[i]))
                ent_col.append(c)
                ent_blk.append(r.blocks[c])
        if ent_blk:
            S = cacheg["k"].shape[2]
            req = jnp.asarray(np.asarray(ent_req, np.int32)[:, None])
            ridx = np.asarray(ent_col, np.int32)[:, None] * bl \
                + np.arange(bl, dtype=np.int32)[None, :]
            # rows past an unaligned max_len clamp onto the last cache
            # row: garbage, but masked (pos >= cache_len) until a decode
            # append overwrites them
            ridx = jnp.asarray(np.minimum(ridx, S - 1))
            blk_ids = jnp.asarray(np.asarray(ent_blk, np.int32))
            for key in ("k", "v"):
                upd = cacheg[key][:, req, ridx]        # (L, E, bl, K, hd)
                self.cache[key] = self.cache[key].at[:, blk_ids].set(upd)
        self.cache["block_tbl"] = \
            self.cache["block_tbl"].at[slots].set(jnp.asarray(rows))

    def _scatter_tail(self, live: List[Request], slots: np.ndarray,
                      gidx: np.ndarray, tail_k, tail_v, plen: int,
                      m_tok: int, matches: List[List[int]]) -> None:
        """Install aliased-prefix block tables and scatter the
        tail-only prefill's K/V rows into freshly granted blocks (the
        compute-skip counterpart of :meth:`_scatter_paged_prefill`:
        the first ``m_tok`` rows were never recomputed — their blocks
        are aliased as-is)."""
        bl = self.block_len
        nbm = m_tok // bl
        nbp = -(-plen // bl)
        nb_cols = self.cache["block_tbl"].shape[1]
        rows = np.full((len(live), nb_cols), -1, np.int32)
        tail_blocks: List[int] = []
        for i, r in enumerate(live):
            matched = list(matches[i])
            assert len(matched) == nbm, (len(matched), nbm)
            need = self._admission_blocks(r)
            assert need >= nbp > nbm, (need, nbp, nbm)
            self._alloc.retain(matched)
            fresh = self._alloc.allocate(need - nbm,
                                         self._slot_group(int(slots[i])))
            assert fresh is not None, "admission reserved these blocks"
            r.blocks = matched + fresh
            rows[i, :need] = r.blocks
            tail_blocks.extend(r.blocks[nbm:nbp])
        ntb = nbp - nbm
        blk_ids = jnp.asarray(np.asarray(tail_blocks, np.int32))
        T = plen - m_tok
        for key, src in (("k", tail_k), ("v", tail_v)):
            upd = src[:, gidx]                         # (L, Bs, T, K, hd)
            L = upd.shape[0]
            if T < ntb * bl:
                upd = jnp.pad(upd, ((0, 0), (0, 0), (0, ntb * bl - T),
                                    (0, 0), (0, 0)))
            upd = upd.reshape(L, len(live) * ntb, bl, *upd.shape[3:])
            self.cache[key] = self.cache[key].at[:, blk_ids].set(upd)
        self.cache["block_tbl"] = \
            self.cache["block_tbl"].at[slots].set(jnp.asarray(rows))

    # ---------------- grow-on-demand grants + degradation ladder ------
    def _needs_block(self, r: Request) -> bool:
        """True when this tick's append row falls past the blocks the
        slot currently holds (decode crossed a block boundary)."""
        return len(r.blocks) < int(self.slot_len[r.slot]) \
            // self.block_len + 1

    def _grant(self, group: int) -> Optional[int]:
        """One-block grant from a sub-pool, through the chaos hook."""
        if self.grant_fault is not None and self.grant_fault():
            self.grant_denials += 1
            return None
        blk = self._alloc.allocate_one(group)
        if blk is None:
            self.grant_denials += 1
        return blk

    def _install_block(self, r: Request, blk: int) -> None:
        r.blocks.append(blk)
        self.cache["block_tbl"] = self.cache["block_tbl"].at[
            r.slot, len(r.blocks) - 1].set(blk)

    def _ensure_grants(self) -> None:
        """Grant admission: before a decode tick, every active slot must
        hold the block its append row lands in — a missing table entry
        would silently *drop* the append (the freed-slot contract) and
        corrupt the request.  Grant failures degrade down the ladder:
        spill a cold cached block to the host tier, else migrate the
        slot to an idling sub-pool, else preempt a victim (possibly the
        needy request itself) and retry.  After this returns, every
        remaining active slot can decode."""
        if self.kv_residency != "paged" or self.kv_admission != "grant":
            return
        for r in sorted(self.active.values(), key=lambda x: x.rid):
            guard = 0
            while self.active.get(r.slot) is r and self._needs_block(r):
                guard += 1
                assert guard <= self.max_batch + 2 * self.n_blocks + 2, \
                    "grant ladder did not converge"
                blk = self._grant(self._slot_group(r.slot))
                if blk is not None:
                    self._install_block(r, blk)
                    continue
                if self._spill_cold(self._slot_group(r.slot), 1):
                    continue
                if self._try_migrate(r):
                    continue
                self._preempt_for(r)

    # ---------------- copy-on-write barrier ---------------------------
    def _ensure_writable(self) -> None:
        """Before a decode tick, no slot may append into a block with
        refcount > 1 — writers never mutate shared state.  The natural
        flow keeps appends in private blocks (only *full* feed chunks
        are ever aliased, and the matched-token cap leaves the append
        column past them), so this barrier is the structural guarantee
        — and the path the forced-divergence test drives directly.  A
        CoW needs a fresh block; under pressure it degrades like a
        grant, by preempting a victim from the slot's sub-pool
        (migration is no help here — it refuses to move shared
        blocks)."""
        if self.kv_residency != "paged" or self._prefix is None:
            return
        if self._alloc.shared_blocks == 0:
            return
        for r in sorted(self.active.values(), key=lambda x: x.rid):
            guard = 0
            while self.active.get(r.slot) is r:
                col = int(self.slot_len[r.slot]) // self.block_len
                if col >= len(r.blocks):
                    break          # the grant ladder owns missing blocks
                blk = r.blocks[col]
                if self._alloc.refcount(blk) <= 1:
                    break
                guard += 1
                assert guard <= self.max_batch + 2 * self.n_blocks + 2, \
                    "CoW ladder did not converge"
                fresh = self._grant(self._slot_group(r.slot))
                if fresh is not None:
                    self._cow_copy(r, col, fresh)
                    break
                if self._spill_cold(self._slot_group(r.slot), 1):
                    continue
                self._preempt_for(r)

    def _cow_copy(self, r: Request, col: int, fresh: int) -> None:
        """Copy ``r``'s shared append block into ``fresh`` (k/v rows +
        table entry, one jitted gather-scatter) and drop this holder's
        reference to the original — the sharers keep it resident, trie
        entry and all."""
        old = r.blocks[col]
        k, v, tbl = self._cow_kernel(
            self.cache["k"], self.cache["v"], self.cache["block_tbl"],
            np.int32(old), np.int32(fresh), np.int32(r.slot),
            np.int32(col))
        self.cache["k"], self.cache["v"] = k, v
        self.cache["block_tbl"] = tbl
        r.blocks[col] = fresh
        self._release_blocks([old])
        self.cow_copies += 1

    def _try_migrate(self, r: Request) -> bool:
        """Rung 2: move ``r`` — blocks, table row, per-slot states — to
        a donor sub-pool that idles while its home pool is hot.  The
        donor must host a free slot (the batch dim is partitioned across
        data, so changing sub-pool means changing slot) and cover the
        current holding plus the block being asked for; the idlest such
        donor wins.  Preserves the slot→sub-pool combine contract: after
        the move every block the slot holds lives in its new data
        shard's sub-pool.

        Sharing-aware: a slot holding any *shared* block stays put —
        sharers' tables point at the original ids, and moving only this
        holder's copy would strand their aliases (shared blocks are
        pinned until their refcount drops back to 1)."""
        if self.pool_groups <= 1:
            return False
        if any(self._alloc.refcount(b) > 1 for b in r.blocks):
            return False
        src = self._slot_group(r.slot)
        need_now = len(r.blocks) + 1
        best = None
        for s2 in sorted(self.free_slots):
            g2 = self._slot_group(s2)
            if g2 == src or self._alloc.free_in(g2) < need_now:
                continue
            if best is None or self._alloc.free_in(g2) \
                    > self._alloc.free_in(self._slot_group(best)):
                best = s2
        if best is None:
            return False
        s1, s2 = r.slot, best
        g2 = self._slot_group(s2)
        new_blocks = self._alloc.allocate(need_now, g2)
        assert new_blocks is not None, "donor free count was just checked"
        old = list(r.blocks)
        if old:
            old_ids = jnp.asarray(old, jnp.int32)
            new_ids = jnp.asarray(new_blocks[:len(old)], jnp.int32)
            for key in ("k", "v"):
                self.cache[key] = self.cache[key].at[:, new_ids].set(
                    self.cache[key][:, old_ids])
        for key in ("ssm", "conv"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, s2].set(
                    self.cache[key][:, s1])
        rows = np.full((int(self.cache["block_tbl"].shape[1]),), -1,
                       np.int32)
        rows[:need_now] = new_blocks
        tbl = self.cache["block_tbl"].at[s2].set(jnp.asarray(rows))
        self.cache["block_tbl"] = tbl.at[s1].set(-1)
        self._release_blocks(old)
        r.blocks = list(new_blocks)
        if self._prefix is not None and r.prefix_hashes:
            # the moved blocks hold the same content: re-key the trie
            # onto the new ids in the donor sub-pool (first writer wins,
            # so a still-resident original keeps its entry)
            self._prefix.insert(r.prefix_hashes,
                                r.blocks[:len(r.prefix_hashes)], g2)
        del self.active[s1]
        self.active[s2] = r
        r.slot = int(s2)
        self.free_slots.remove(s2)
        self.free_slots.append(s1)
        self.slot_len[s2] = self.slot_len[s1]
        self.slot_len[s1] = 0
        self.migrations += 1
        return True

    def _preempt_for(self, r: Request) -> None:
        """Rung 3: evict a victim from the needy slot's sub-pool so its
        grant can succeed (the victim may be the needy request itself,
        which also resolves the need).

        Sharing-aware: shared blocks are pinned — candidates holding
        the fewest shared blocks are preferred (evicting a sharer only
        drops a reference, freeing at most its private blocks, so
        victims whose eviction actually returns memory go first)."""
        group = self._slot_group(r.slot)
        cands = [a for a in self.active.values()
                 if self._slot_group(a.slot) == group]
        if self._prefix is not None and len(cands) > 1:
            def shared(a: Request) -> int:
                return sum(1 for b in a.blocks
                           if self._alloc.refcount(b) > 1)
            lo = min(shared(a) for a in cands)
            cands = [a for a in cands if shared(a) == lo]
        victim = self.preemption.pick_victim(cands, time.time())
        self._preempt(victim)

    def _preempt(self, r: Request) -> None:
        """Evict an active request to the host side.  With tiering on
        the victim *parks with state*: its KV blocks spill to the host
        tier (dense stripes and SSM/conv rows are copied host-side),
        so re-admission promotes them back and skips re-prefill
        entirely — token-identical resume, zero recompute.  Without
        tiering — or when the victim pins shared blocks, whose
        sharers' tables point at the old ids — blocks are released and
        re-admission is a re-prefill of prompt+generated.  Past the
        retry budget or an already-missed deadline the request is shed
        instead."""
        slot = r.slot
        del self.active[slot]
        r.slot = -1
        r.preemptions += 1
        self.preemptions += 1
        self._preempt_ticks.append(self.tick)
        shed_why = ""
        delay = 0
        if r.deadline is not None and time.time() > r.deadline:
            shed_why = ("deadline missed at preemption — a re-prefill "
                        "could not finish in time")
        else:
            pol = self._backoff.setdefault(
                r.rid, self.preemption.restart_policy())
            try:
                delay = int(pol.next_delay())
            except RuntimeError:
                shed_why = ("preemption retry budget exhausted "
                            f"({self.preemption.max_preemptions})")
        state = (self._park_state(r, slot)
                 if not shed_why and self.kv_tiering else None)
        if state is not None:
            self.free_slots.append(slot)
            self.slot_len[slot] = 0
            self.preempted.append(
                PreemptedRequest(r, self.tick + delay, state))
            return
        self._release_slot(slot, r)
        if shed_why:
            self._shed(r, shed_why)
            return
        self.preempted.append(PreemptedRequest(r, self.tick + delay))

    def _park_state(self, r: Request,
                    slot: int) -> Optional[Dict[str, Any]]:
        """Capture a victim's full per-slot state host-side so its
        resume needs no re-prefill: paged KV blocks spill to the host
        tier (ids stay on ``r.blocks``), dense stripes copy their valid
        rows, SSM/conv states copy their slot rows.  Returns None when
        the victim cannot park with state — it pins shared blocks
        (sharers' tables point at the old ids; moving them would strand
        every alias) or the host pool cannot cover its blocks even
        after evicting cold host entries — and the caller falls back to
        the legacy release+re-prefill park."""
        st: Dict[str, Any] = {"slot_len": int(self.slot_len[slot])}
        if self.kv_residency == "paged" and r.blocks:
            if self._host is None:
                return None
            if any(self._alloc.refcount(b) > 1 for b in r.blocks):
                return None
            short = len(r.blocks) - self._alloc.host_free
            if short > 0:
                self._evict_cached_host(short)
            if len(r.blocks) > self._alloc.host_free:
                return None
            # a parked victim's spilled blocks are private host copies
            # of *its* sequence — a trie match against them would alias
            # state the resume owns, so the entries go, not rekey
            if self._prefix is not None:
                self._prefix.evict(list(r.blocks))
            pairs = self._alloc.spill(list(r.blocks))
            assert pairs is not None, "host headroom was just checked"
            self._spill_rows(pairs)
            r.blocks = [h for _, h in pairs]
            st["kv_host"] = list(r.blocks)
            self.cache["block_tbl"] = \
                self.cache["block_tbl"].at[slot].set(-1)
        elif self.arch.has_attention:
            n = st["slot_len"]
            st["kv_rows"] = (np.asarray(self.cache["k"][:, slot, :n]),
                             np.asarray(self.cache["v"][:, slot, :n]))
        for key in ("ssm", "conv"):
            if key in self.cache:
                st[key] = np.asarray(self.cache[key][:, slot])
        return st

    def preempt(self, rid: int) -> None:
        """Forcibly evict an active request (chaos/test hook and ops
        escape hatch; the engine preempts autonomously on grant
        failure)."""
        for r in self.active.values():
            if r.rid == rid:
                self._preempt(r)
                return
        raise KeyError(f"request {rid} is not active")

    def _shed(self, r: Request, why: str) -> None:
        assert not r.blocks, "shed request still holds blocks"
        r.error = why
        self.shed.append(r)
        self._backoff.pop(r.rid, None)

    def _shed_expired_pending(self) -> None:
        if not any(r.deadline is not None for r in self.pending):
            return
        now = time.time()
        keep: List[Request] = []
        for r in self.pending:
            if r.deadline is not None and now > r.deadline:
                self._shed(r, f"deadline missed while pending "
                              f"(tick {self.tick})")
            else:
                keep.append(r)
        self.pending = keep

    def _readmit_preempted(self) -> None:
        """Parked evictions whose backoff expired rejoin service.
        Stateless parks (tiering off, or a shared-block victim) rejoin
        the *front* of the pending queue (oldest rid first — they
        already burned a slot's worth of work; new arrivals should not
        starve them) and re-prefill.  Parked-with-state evictions skip
        the queue entirely: :meth:`_admit_resume` promotes their host
        blocks back into a free slot's sub-pool and decode continues
        where the eviction cut in — zero prefill calls.  A resume that
        cannot fit this tick stays parked and retries next tick."""
        if not self.preempted:
            return
        ready = [p for p in self.preempted if p.not_before_tick <= self.tick]
        if not ready:
            return
        keep = [p for p in self.preempted
                if p.not_before_tick > self.tick]
        for p in sorted(ready, key=lambda p: p.request.rid, reverse=True):
            if p.parked_state is None:
                self.pending.insert(0, p.request)
                continue
            r = p.request
            if r.deadline is not None and time.time() > r.deadline:
                self._drop_parked(p)
                self._shed(r, f"deadline missed while parked "
                              f"(tick {self.tick})")
                continue
            if not self._admit_resume(p):
                keep.append(p)
        self.preempted = keep

    def _admit_resume(self, p: PreemptedRequest) -> bool:
        """Resume a parked-with-state eviction: promote its host KV
        blocks into a free slot's sub-pool (consuming the prefetch
        stage if it landed), restore dense/SSM/conv rows, and hand the
        request straight back to decode.  No prefill call — the next
        tick feeds the last generated token at the parked position, so
        the continuation is token-identical to an uninterrupted run."""
        r, st = p.request, p.parked_state
        if not self.free_slots:
            return False
        host_ids = st.get("kv_host", [])
        if host_ids:
            # the free slot whose sub-pool can cover the promote wins
            # (emptiest first); spill cold cached blocks to make room
            slot = None
            for s in sorted(self.free_slots,
                            key=lambda s: (-self._alloc.free_in(
                                self._slot_group(s)), s)):
                g = self._slot_group(s)
                short = len(host_ids) - self._alloc.free_in(g)
                if short > 0:
                    self._spill_cold(g, short)
                if self._alloc.free_in(g) >= len(host_ids):
                    slot = s
                    break
            if slot is None:
                return False
            g = self._slot_group(slot)
            staged = self._staged.pop(r.rid, None)
            pairs = self._alloc.promote(host_ids, g)
            assert pairs is not None, "free count was just checked"
            if staged is not None and staged[0] == tuple(host_ids):
                self._promote_rows(pairs, staged[1], staged[2])
            else:
                self._promote_rows(pairs)
            r.blocks = [b for _, b in pairs]
            rows = np.full((int(self.cache["block_tbl"].shape[1]),), -1,
                           np.int32)
            rows[:len(r.blocks)] = r.blocks
            self.cache["block_tbl"] = \
                self.cache["block_tbl"].at[slot].set(jnp.asarray(rows))
            if self._prefix is not None and r.prefix_hashes:
                # back on HBM, the prefix blocks are shareable again
                self._prefix.insert(r.prefix_hashes,
                                    r.blocks[:len(r.prefix_hashes)], g)
        else:
            slot = min(self.free_slots)
            if "kv_rows" in st:
                n = st["slot_len"]
                k_rows, v_rows = st["kv_rows"]
                self.cache["k"] = self.cache["k"].at[:, slot, :n].set(
                    jnp.asarray(k_rows))
                self.cache["v"] = self.cache["v"].at[:, slot, :n].set(
                    jnp.asarray(v_rows))
        for key in ("ssm", "conv"):
            if key in st:
                self.cache[key] = self.cache[key].at[:, slot].set(
                    jnp.asarray(st[key]))
        self.free_slots.remove(slot)
        self.slot_len[slot] = st["slot_len"]
        r.slot = int(slot)
        self.active[slot] = r
        return True

    def _drop_parked(self, p: PreemptedRequest) -> None:
        """Release a parked-with-state eviction's host-side holdings
        (shed, or abandoned): host block refs return to the host free
        list and any staged prefetch is discarded."""
        r = p.request
        self._staged.pop(r.rid, None)
        if p.parked_state and p.parked_state.get("kv_host"):
            freed = self._alloc.release(r.blocks)
            if self._prefix is not None and freed:
                self._prefix.evict(freed)
            r.blocks = []

    def _stage_prefetch(self) -> None:
        """Double-buffered resume prefetch: for every parked-with-state
        eviction whose backoff expires by the *next* tick, start the
        host→device transfer of its spilled KV rows now
        (``jax.device_put``) — this tick's decode dispatch overlaps the
        stream-in, and the resume finds device-resident rows waiting
        instead of paying a synchronous copy.  One-tick lookahead is
        what the plan's feasibility check sized: a block must stream in
        under ``block_len`` decode ticks.  ``kv_prefetch="off"``
        disables staging — the resume stalls on the transfer (the gap
        the benchmark's prefetch-off rows measure)."""
        if not (self.kv_prefetch and self.kv_tiering
                and self._host is not None):
            return
        for p in self.preempted:
            st = p.parked_state
            if st is None or not st.get("kv_host"):
                continue
            if p.not_before_tick > self.tick + 1:
                continue
            rid = p.request.rid
            ids = tuple(st["kv_host"])
            got = self._staged.get(rid)
            if got is not None and got[0] == ids:
                continue
            idx = np.asarray([h - self.n_blocks for h in ids], np.int64)
            self._staged[rid] = (ids,
                                 jax.device_put(self._host["k"][:, idx]),
                                 jax.device_put(self._host["v"][:, idx]))

    # ------------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample(self, logits: jax.Array, temperature: float,
                key: jax.Array) -> int:
        logits = logits[:self.arch.vocab_size].astype(jnp.float32)
        if temperature <= 0:
            return int(jnp.argmax(logits))
        return int(jax.random.categorical(key, logits / temperature))

    def _sync_pos(self) -> None:
        """Mirror per-slot lengths into the device cache (freed slots 0)."""
        pos = jnp.asarray(self.slot_len)
        if self._pos_sharding is not None:
            pos = jax.device_put(pos, self._pos_sharding)
        self.cache["pos"] = pos

    def step(self) -> int:
        """One engine tick: shed expired, re-admit parked evictions,
        admit, secure grants, decode one token for all active slots."""
        t0 = time.perf_counter()
        self.tick += 1
        if self.kv_residency == "paged" and \
                self.tick % self.preemption.shed_window_ticks == 0:
            # new low-water epoch once per rebalance window: without the
            # reset the watermark only ever ratchets down, so one
            # transient dip reads as a permanently hot sub-pool forever
            self._alloc.reset_low_water()
        self._poll_disagg()
        self._shed_expired_pending()
        self._shed_expired_flights()
        self._readmit_preempted()
        self._admit()
        self._ensure_grants()
        self._ensure_writable()
        # stage next tick's resume transfers before dispatching this
        # tick's decode: the async device_put streams in underneath it
        self._stage_prefetch()
        if not self.active:
            if self._disagg:
                # only flights in play: workers are computing off-process;
                # don't spin the tick counter at memory speed waiting
                time.sleep(0.01)
            self._observe_tick(t0)
            return 0
        # per-slot positions: every slot decodes at its own offset.  Freed
        # slots are masked to (token 0, pos 0): their decode is a bounded
        # dummy over one cache row, so stale KV / stale last-token garbage
        # never reaches a live slot's logits.
        self._sync_pos()
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for slot, r in self.active.items():
            # a ride-admitted request has no output yet: its first tick
            # feeds the last prompt token (the one admission left
            # unaliased) and samples the first output
            tokens[slot, 0] = (r.out_tokens[-1] if r.out_tokens
                               else int(r.feed_tokens[-1]))
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(tokens)})
        slot_keys = jax.random.split(self._next_key(), self.max_batch)
        finished = []
        for slot, r in list(self.active.items()):
            tok = self._sample(logits[slot], r.temperature, slot_keys[slot])
            r.out_tokens.append(int(tok))
            if r.t_first == 0.0:       # first token via decode-ride
                r.t_first = time.time()
            self.slot_len[slot] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = time.time()
                finished.append(r)
                self.finished.append(r)
                del self.active[slot]
                self._release_slot(slot, r)
                self._backoff.pop(r.rid, None)
        self._observe_tick(t0)
        return len(finished)

    def _observe_tick(self, t0: float) -> None:
        dt = time.perf_counter() - t0
        if self.tick_timer.is_straggler(dt):
            self.straggler_ticks += 1
        self.tick_timer.observe(dt)

    def _release_slot(self, slot: int, r: Request) -> None:
        """Return the slot — and, when paged, its blocks — to the pool.

        This is real reclamation: the block ids go back on their
        sub-pool's free list and the table row is cleared to -1, so the
        freed slot's decode dummy neither writes to the pool (unassigned
        appends drop) nor pins memory the next admission could use.
        """
        self.free_slots.append(slot)
        self.slot_len[slot] = 0
        if self.kv_residency == "paged" and r.blocks:
            self._release_blocks(r.blocks)
            r.blocks = []
            self.cache["block_tbl"] = \
                self.cache["block_tbl"].at[slot].set(-1)

    def run_until_idle(self, max_ticks: int = 1000) -> List[Request]:
        """Tick until no live work remains (parked evictions count as
        live — their backoff just hasn't expired).  Raises a loud
        :class:`TimeoutError` naming the stuck request ids when work
        remains after ``max_ticks``: a deadlocked admission loop must
        not be indistinguishable from success."""
        ticks = 0
        while self.pending or self.active or self.preempted or self._disagg:
            if ticks >= max_ticks:
                stuck = sorted(
                    [r.rid for r in self.pending]
                    + [r.rid for r in self.active.values()]
                    + [p.request.rid for p in self.preempted]
                    + list(self._disagg.keys()))
                raise TimeoutError(
                    f"run_until_idle: {len(stuck)} request(s) still live "
                    f"after {max_ticks} ticks (pending={len(self.pending)} "
                    f"active={len(self.active)} "
                    f"preempted={len(self.preempted)} "
                    f"disagg={len(self._disagg)}): rids {stuck}")
            self.step()
            ticks += 1
        return self.finished
