"""Batched serving engine: continuous batching over prefill + decode.

The session cache is the template's ``cache.kv`` component: allocated
once at engine start (shape from the plan), slots assigned to requests,
freed on completion — residency management, not reallocation.

Scheduling: waiting requests are admitted in same-length buckets — every
pending prompt of the head-of-line length that fits a free slot (and,
when paged, the block pool) is prefilled in ONE jitted call — then every
engine tick decodes one token for all active slots.  Positions are
**per slot** (``cache["pos"]`` is ``(B,)``): a continuous batch mixes
prompt lengths, so each slot appends KV and masks attention at its own
offset — an engine-global scalar position silently corrupts every slot
whose length differs from the batch max.  Freed slots are masked to
``(token 0, pos 0)`` so their stale KV never flows into a live decode.
Greedy or temperature sampling; sampling threads one engine PRNG key
(``seed=``), split per tick and per slot, so runs are reproducible and
slots never share a key within a tick.

KV residency is a plan decision (``kv_residency`` in the artifact):
``dense`` keeps the classic per-slot ``max_len`` stripes; ``paged``
allocates a block pool (``lm.init_paged_cache``) whose geometry the
data-organization pass chose, hands each admitted request exactly the
blocks it can ever touch, and *returns them to the pool on finish* —
real reclamation, so slot churn frees memory instead of leaving masked
rows resident.  On a data×model mesh the pool is 2-D sharded (block dim
data-major over both axes, batch slots partitioned across data —
``dist.flash_decode.pool_sharding_kind``), so the allocator works over
*per-data-shard sub-pools* (``serve.allocator.BlockAllocator``): a slot
may only hold blocks from the sub-pool of the data shard hosting it,
because a foreign block would be owned by no shard in the slot's data
row and mask out of the combine.  When no (slot, sub-pool) pair can
cover the head-of-line request, admission waits for a finisher (no
over-subscription, no mid-flight eviction).

Engines are plan-driven: :meth:`ServeEngine.from_plan` consumes the
frozen plan artifact the specialization flow produced (possibly reloaded
from the on-disk plan store in a different process) and derives the KV
cache sizing, decode implementation, and batching limits from it — no
ad-hoc kwargs needed between the compiler and the server.  With a
``mesh`` the engine state is *placed* per the plan's axis rules
(``dist.sharding.resolve_pspec``/``cache_pspecs``) and a plan that chose
the seq-sharded ``shard_map_flash`` decode drives it end-to-end — no
silent XLA fallback.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, get_arch
from repro.models import lm
from repro.models.lm import RunCfg


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    blocks: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, arch: ArchConfig, params, cfg: RunCfg,
                 max_batch: int = 8, max_len: int = 512,
                 ssm_heads: int = 0, kv_heads: int = 0, seed: int = 0,
                 kv_residency: str = "dense", kv_block_len: int = 0,
                 kv_n_blocks: int = 0):
        self.arch, self.params, self.cfg = arch, params, cfg
        self.plan = None               # set by from_plan()
        self.max_batch, self.max_len = max_batch, max_len
        # paged residency only exists for attention caches; an SSM-only
        # arch has no KV stripes to page (its states are O(1) in seq)
        self.kv_residency = ("paged" if kv_residency == "paged"
                             and arch.has_attention else "dense")
        if self.kv_residency == "paged":
            import math
            from repro.core.costmodel import kv_block_len as _default_bl
            from repro.serve.allocator import BlockAllocator
            self.block_len = kv_block_len or _default_bl(max_len)
            per_seq = -(-max_len // self.block_len)
            # never larger than this engine's slots can ever pin (a plan
            # sized for a bigger deployment must not balloon a small one);
            # a plan-shrunk (budget-capped) pool stays shrunk
            cap = max_batch * per_seq
            n = min(kv_n_blocks, cap) if kv_n_blocks else cap
            groups = 1
            if cfg.mesh is not None:
                # preserve the plan's pool divisibility through the
                # clamp: a clamp that breaks it would silently downgrade
                # the pool-sharded decode (2-D -> 1-D -> single-shard)
                # AND replicate the pool on the broken axis
                from repro.dist.flash_decode import pool_sharding_kind
                from repro.dist.sharding import mesh_sizes
                sizes = mesh_sizes(cfg.mesh)
                msize = sizes.get(cfg.model_axis, 1)
                dsize = math.prod(sizes.get(a, 1) for a in cfg.data_axes)
                aligns = []
                if dsize > 1 and max_batch % dsize == 0:
                    aligns.append(dsize * msize)
                if msize > 1:
                    aligns.append(msize)
                for align in aligns:
                    if align > 1 and n % align and \
                            (not kv_n_blocks or kv_n_blocks % align == 0):
                        n = align * (-(-n // align))
                        if kv_n_blocks:
                            n = min(kv_n_blocks, n)
                        break
                # sub-pool grouping exists for the 2-D combine's
                # ownership contract; other decode impls (xla gather)
                # read any block from anywhere, so constraining their
                # admission would refuse servable requests
                if cfg.decode_impl == "shard_map_flash" and \
                        pool_sharding_kind(cfg.mesh, n, max_batch,
                                           cfg.data_axes,
                                           cfg.model_axis) == "2d":
                    groups = dsize
            self.n_blocks = n
            self.pool_groups = groups
            self.cache = lm.init_paged_cache(
                arch, max_batch, max_len, self.block_len, self.n_blocks,
                ssm_heads=ssm_heads, kv_heads=kv_heads)
            self._alloc = BlockAllocator(self.n_blocks, groups)
        else:
            from repro.serve.allocator import BlockAllocator
            self.block_len = 0
            self.n_blocks = 0
            self.pool_groups = 1
            self.cache = lm.init_cache(arch, max_batch, max_len,
                                       ssm_heads=ssm_heads, kv_heads=kv_heads)
            self._alloc = BlockAllocator(0, 1)
        self.free_slots = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.pending: List[Request] = []
        self._rid = 0
        self.finished: List[Request] = []
        # per-slot valid lengths; mirrored into cache["pos"] every tick
        # (freed slots stay at 0 — their stale KV is masked out)
        self.slot_len = np.zeros((max_batch,), np.int32)
        self._key = jax.random.PRNGKey(seed)
        self._pos_sharding = None      # set by _place_on_mesh()
        # admission telemetry: bucketed prefill batch sizes per call
        # (bounded — long-running engines must not accumulate history)
        self.prefill_calls = 0
        self.prefill_batches: Deque[int] = deque(maxlen=1024)

        self._decode = jax.jit(
            lambda p, c, b: lm.decode_step(arch, p, c, b, cfg))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(arch, p, b, cfg, max_len=max_len))

    # ------------------------------------------------------------------
    @property
    def decode_path(self) -> str:
        """The decode implementation ticks actually run through.

        ``"shard_map_flash_paged_2d"`` when the paged pool is 2-D
        sharded (block dim over data×model, batch partitioned across
        data); ``"shard_map_flash"`` when the 1-D sharded path really
        executes; ``"flash"`` when the internal single-shard combine
        takes over — model axis of size 1, or the sharded dim not
        divisible (``max_len`` for a dense cache, ``n_blocks`` for a
        paged pool); ``"xla"`` when no mesh was provided.
        """
        impl = self.cfg.decode_impl
        if impl == "xla":
            return impl
        if self.cfg.mesh is None:
            return "xla"               # lm.decode_step's own guard
        if impl == "shard_map_flash":
            from repro.dist.flash_decode import (pool_sharding_kind,
                                                 uses_seq_sharding)
            if self.kv_residency == "paged":
                kind = pool_sharding_kind(
                    self.cfg.mesh, self.n_blocks, self.max_batch,
                    self.cfg.data_axes, self.cfg.model_axis)
                if kind == "2d":
                    return "shard_map_flash_paged_2d"
                if kind == "none":
                    return "flash"     # flash_decode's single-shard path
            elif not uses_seq_sharding(self.cfg.mesh, self.max_len,
                                       self.cfg.model_axis):
                return "flash"
        return impl

    @classmethod
    def from_plan(cls, plan, params, *, arch: Optional[ArchConfig] = None,
                  mesh=None, max_batch: Optional[int] = None,
                  max_len: Optional[int] = None, seed: int = 0
                  ) -> "ServeEngine":
        """Build an engine from the frozen plan artifact.

        The plan supplies everything the kwargs constructor asks for:
        the RunCfg (flash-attention tiles, padded head counts, decode
        implementation, pallas-vs-ref dispatch), the KV-cache sizing
        (padded kv/ssm heads), and the batching limits (the workload
        dims carried in the artifact).  ``arch`` overrides the registry
        lookup for reduced/custom configs whose name shadows a
        registered one; ``max_batch``/``max_len`` override the plan
        limits (e.g. a single-host deployment of a decode_32k plan).

        With a ``mesh`` the engine's params and KV cache are placed per
        the plan's axis rules and a ``shard_map_flash`` decode decision
        is honored end-to-end.  Without one the engine is
        single-process, so a plan that chose the seq-sharded decode
        falls back to the XLA decode path (the sharding decision needs
        a real mesh).

        Workload-dims compatibility is validated instead of silently
        sizing the cache from stale dims: a non-decode plan has no
        serving dims at all (both overrides are then required), and
        overrides *larger* than the dims the plan was specialized for
        are rejected — the pass sized the KV memory (and, for paged
        residency, the block pool) from those dims, so a bigger runtime
        cache needs a respecialized plan, not a quiet under-allocation.
        """
        from repro.core.passes.lowering import build_run_cfg
        arch = arch if arch is not None else get_arch(plan.arch)
        if plan.shape_kind != "decode":
            if max_batch is None or max_len is None:
                raise ValueError(
                    f"plan {plan.content_hash()[:12]} was specialized for "
                    f"shape_kind={plan.shape_kind!r}, not a decode workload; "
                    f"its dims (seq_len={plan.seq_len}, "
                    f"global_batch={plan.global_batch}) cannot size a "
                    "serving cache — pass max_batch= and max_len= "
                    "explicitly, or specialize a decode shape")
        else:
            if max_len is not None and plan.seq_len and max_len > plan.seq_len:
                raise ValueError(
                    f"max_len={max_len} exceeds the seq_len={plan.seq_len} "
                    f"this plan was specialized for — the pass sized the KV "
                    "memory from that dim; respecialize with the larger "
                    "decode shape instead of overriding past it")
            if max_batch is not None and plan.global_batch \
                    and max_batch > plan.global_batch:
                raise ValueError(
                    f"max_batch={max_batch} exceeds the global_batch="
                    f"{plan.global_batch} this plan was specialized for — "
                    "respecialize with the larger decode shape instead of "
                    "overriding past it")
        cfg = build_run_cfg(plan, arch, mesh)
        if mesh is None and cfg.decode_impl != "xla":
            cfg = dataclasses.replace(cfg, decode_impl="xla")
        if max_batch is None:
            max_batch = (plan.global_batch
                         if plan.shape_kind == "decode" and plan.global_batch
                         else 8)
        if max_len is None:
            max_len = plan.seq_len or 512
        eng = cls(arch, params, cfg, max_batch=max_batch, max_len=max_len,
                  ssm_heads=cfg.ssm_heads_padded, kv_heads=cfg.kv_heads_padded,
                  seed=seed,
                  kv_residency=str(plan.estimates.get("kv_residency",
                                                      "dense")),
                  kv_block_len=int(plan.estimates.get("kv_block_len", 0)),
                  kv_n_blocks=int(plan.estimates.get("kv_n_blocks", 0)))
        eng.plan = plan
        if mesh is not None:
            eng._place_on_mesh(mesh)
        return eng

    def _place_on_mesh(self, mesh) -> None:
        """Shard params + session cache per the plan's axis rules."""
        from jax.sharding import NamedSharding
        from repro.core.passes.lowering import param_pspecs
        from repro.dist.sharding import cache_pspecs, mesh_sizes

        sizes = mesh_sizes(mesh)
        # resolve against the arrays actually handed to us — their shapes
        # may differ from the IR (reduced configs, caller-side padding)
        pspecs = param_pspecs(self.plan, self.arch, sizes,
                              shapes=self.params)
        self.params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            self.params, pspecs)
        cshapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in self.cache.items()}
        cpspecs = cache_pspecs(self.plan, self.arch, cshapes, sizes)
        shardings = {k: NamedSharding(mesh, s) for k, s in cpspecs.items()}
        self.cache = {k: jax.device_put(v, shardings[k])
                      for k, v in self.cache.items()}
        self._pos_sharding = shardings["pos"]

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + max_new_tokens > self.max_len:
            # past capacity the per-slot append clamps onto the last cache
            # row and silently corrupts the tail — refuse loudly instead
            raise ValueError(
                f"request needs {len(prompt)} prompt + {max_new_tokens} new "
                f"tokens > max_len={self.max_len} cache rows; raise max_len "
                "or lower max_new_tokens")
        if self.kv_residency == "paged":
            need = self._blocks_needed(len(prompt), max_new_tokens)
            sub = self.n_blocks // max(1, self.pool_groups)
            if need > sub:
                # a request draws all its blocks from ONE data shard's
                # sub-pool; admission would wait forever for frees that
                # can never cover it — refuse loudly, not a silent hang
                raise ValueError(
                    f"request needs {need} blocks of {self.block_len} rows "
                    f"but each sub-pool holds only {sub} "
                    f"({self.n_blocks} blocks over {self.pool_groups} "
                    "sub-pool(s)); raise kv_n_blocks or lower "
                    "max_new_tokens")
        r = Request(self._rid, prompt, max_new_tokens, temperature,
                    t_submit=time.time())
        self._rid += 1
        self.pending.append(r)
        return r.rid

    def _blocks_needed(self, plen: int, max_new: int) -> int:
        """Blocks covering every cache row the request can ever touch
        (``plen`` prompt rows + one append per decode tick).  A request
        the prefill sample already satisfies (``max_new <= 1``) finishes
        before any cache write and needs none."""
        if max_new <= 1:
            return 0
        return -(-(plen + max_new) // self.block_len)

    def block_stats(self) -> Dict[str, int]:
        """Pool accounting (``free + in_use`` always equals ``total``;
        dense engines report an empty 0-block pool)."""
        return self._alloc.stats()

    def _slot_group(self, slot: int) -> int:
        """The data-shard sub-pool that hosts a slot: the batch dim is
        sharded contiguously across data, so slot ranges map 1:1 onto
        the pool's data-major sub-pools."""
        return slot * self.pool_groups // self.max_batch

    def _place(self, r: Request, avail: List[int],
               free_by_group: Dict[int, int]) -> Optional[int]:
        """Reserve the first free slot (FIFO) whose sub-pool can cover
        ``r``'s block budget; mutates both accounting structures."""
        need = (self._blocks_needed(len(r.prompt), r.max_new_tokens)
                if self.kv_residency == "paged" else 0)
        for i, s in enumerate(avail):
            if need <= free_by_group[self._slot_group(s)]:
                free_by_group[self._slot_group(s)] -= need
                return avail.pop(i)
        return None

    def _admit(self) -> None:
        """Bucketed batched admission: all pending prompts of the
        head-of-line's length that fit a (slot, sub-pool) pair are
        prefilled in ONE jitted call.  A request takes all its blocks
        from the sub-pool of the data shard hosting its slot (2-D pool
        sharding; one global pool when ``pool_groups == 1``).  When no
        pair can cover the head request, admission waits for a
        finisher — head-of-line blocking, so exhaustion delays rather
        than starves.
        """
        while self.pending and self.free_slots:
            head = self.pending[0]
            plen = len(head.prompt)
            avail = list(self.free_slots)
            free_by_group = {g: self._alloc.free_in(g)
                             for g in range(self.pool_groups)}
            s0 = self._place(head, avail, free_by_group)
            if s0 is None:
                return                 # pool exhausted: wait for frees
            group: List[Request] = [head]
            slots: List[int] = [s0]
            rest: List[Request] = []
            for r in self.pending[1:]:
                s = self._place(r, avail, free_by_group) \
                    if len(r.prompt) == plen else None
                if s is None:
                    rest.append(r)
                else:
                    group.append(r)
                    slots.append(s)
            self.pending = rest
            for s in slots:
                self.free_slots.remove(s)
            self._admit_group(group, slots)

    def _admit_group(self, group: List[Request],
                     slots: List[int]) -> None:
        """One jitted prefill for a same-length bucket of requests,
        each with a pre-reserved slot (its sub-pool is the one the
        request's blocks will come from).

        The batch dim is padded to the next power of two (dummy rows
        repeat the first prompt and are discarded), so each prompt
        length compiles at most ``log2(max_batch)`` prefill programs
        instead of one per arrival-group size."""
        toks = np.stack([r.prompt for r in group])
        padded = 1
        while padded < len(group):
            padded *= 2
        padded = min(padded, self.max_batch)   # never a batch no engine fills
        if padded > len(group):
            toks = np.concatenate(
                [toks, np.repeat(toks[:1], padded - len(group), axis=0)])
        logits, cacheg = self._prefill(self.params,
                                       {"tokens": jnp.asarray(toks)})
        self.prefill_calls += 1
        self.prefill_batches.append(len(group))
        keys = jax.random.split(self._next_key(), len(group))
        live: List[Request] = []
        idxs: List[int] = []
        live_slots: List[int] = []
        for i, r in enumerate(group):
            tok = self._sample(logits[i], r.temperature, keys[i])
            r.out_tokens.append(int(tok))
            r.t_first = time.time()
            if len(r.out_tokens) >= r.max_new_tokens:
                # the prefill sample already met the budget: finish now —
                # no decode tick to over-generate on, no cache copy, no
                # blocks ever allocated, and the reserved slot goes back
                r.done = True
                r.t_done = r.t_first
                self.finished.append(r)
                self.free_slots.append(slots[i])
            else:
                live.append(r)
                idxs.append(i)
                live_slots.append(slots[i])
        if not live:
            return
        plen = len(live[0].prompt)
        slots = np.asarray(live_slots, np.int32)
        gidx = np.asarray(idxs, np.int32)
        if self.arch.has_attention:
            if self.kv_residency == "paged":
                self._scatter_paged_prefill(live, slots, gidx, cacheg, plen)
            else:
                for key in ("k", "v"):
                    self.cache[key] = self.cache[key].at[:, slots].set(
                        cacheg[key][:, gidx])
        for key in ("ssm", "conv"):
            if key in self.cache:
                self.cache[key] = self.cache[key].at[:, slots].set(
                    cacheg[key][:, gidx])
        for slot, r in zip(slots, live):
            r.slot = int(slot)
            self.slot_len[slot] = plen
            self.active[int(slot)] = r

    def _scatter_paged_prefill(self, live: List[Request], slots: np.ndarray,
                               gidx: np.ndarray, cacheg, plen: int) -> None:
        """Move a bucket's prefilled KV rows into their pool blocks.

        Each survivor gets its full block budget now (prompt + every
        decode append) from *its slot's sub-pool* — admission reserved
        the blocks, so the draw cannot fail — the prompt rows are
        scattered block-wise into the pool in one gather/reshape per
        cache tensor, and the block table rows are installed (-1
        padding past the allocation).
        """
        bl = self.block_len
        nbp = -(-plen // bl)               # blocks holding prompt rows
        nb_cols = self.cache["block_tbl"].shape[1]
        rows = np.full((len(live), nb_cols), -1, np.int32)
        prompt_blocks: List[int] = []
        for i, r in enumerate(live):
            need = self._blocks_needed(len(r.prompt), r.max_new_tokens)
            r.blocks = self._alloc.allocate(
                need, self._slot_group(int(slots[i])))
            assert r.blocks is not None, "admission reserved these blocks"
            rows[i, :need] = r.blocks
            prompt_blocks.extend(r.blocks[:nbp])
        blk_ids = np.asarray(prompt_blocks, np.int32)
        for key in ("k", "v"):
            upd = cacheg[key][:, gidx, :nbp * bl]   # (L, Bs, <=nbp*bl, K, hd)
            L = upd.shape[0]
            if upd.shape[2] < nbp * bl:             # max_len not block-aligned
                upd = jnp.pad(upd, ((0, 0), (0, 0),
                                    (0, nbp * bl - upd.shape[2]),
                                    (0, 0), (0, 0)))
            upd = upd.reshape(L, len(live) * nbp, bl, *upd.shape[3:])
            self.cache[key] = self.cache[key].at[:, blk_ids].set(upd)
        self.cache["block_tbl"] = \
            self.cache["block_tbl"].at[slots].set(jnp.asarray(rows))

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample(self, logits: jax.Array, temperature: float,
                key: jax.Array) -> int:
        logits = logits[:self.arch.vocab_size].astype(jnp.float32)
        if temperature <= 0:
            return int(jnp.argmax(logits))
        return int(jax.random.categorical(key, logits / temperature))

    def _sync_pos(self) -> None:
        """Mirror per-slot lengths into the device cache (freed slots 0)."""
        pos = jnp.asarray(self.slot_len)
        if self._pos_sharding is not None:
            pos = jax.device_put(pos, self._pos_sharding)
        self.cache["pos"] = pos

    def step(self) -> int:
        """One engine tick: admit + decode one token for all active slots."""
        self._admit()
        if not self.active:
            return 0
        # per-slot positions: every slot decodes at its own offset.  Freed
        # slots are masked to (token 0, pos 0): their decode is a bounded
        # dummy over one cache row, so stale KV / stale last-token garbage
        # never reaches a live slot's logits.
        self._sync_pos()
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for slot, r in self.active.items():
            tokens[slot, 0] = r.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(tokens)})
        slot_keys = jax.random.split(self._next_key(), self.max_batch)
        finished = []
        for slot, r in list(self.active.items()):
            tok = self._sample(logits[slot], r.temperature, slot_keys[slot])
            r.out_tokens.append(int(tok))
            self.slot_len[slot] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = time.time()
                finished.append(r)
                self.finished.append(r)
                del self.active[slot]
                self._release_slot(slot, r)
        return len(finished)

    def _release_slot(self, slot: int, r: Request) -> None:
        """Return the slot — and, when paged, its blocks — to the pool.

        This is real reclamation: the block ids go back on their
        sub-pool's free list and the table row is cleared to -1, so the
        freed slot's decode dummy neither writes to the pool (unassigned
        appends drop) nor pins memory the next admission could use.
        """
        self.free_slots.append(slot)
        self.slot_len[slot] = 0
        if self.kv_residency == "paged" and r.blocks:
            self._alloc.release(r.blocks)
            r.blocks = []
            self.cache["block_tbl"] = \
                self.cache["block_tbl"].at[slot].set(-1)

    def run_until_idle(self, max_ticks: int = 1000) -> List[Request]:
        ticks = 0
        while (self.pending or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
