"""Batched serving engine: continuous batching over prefill + decode.

The session cache is the template's ``cache.kv`` component: allocated
once at engine start (shape from the plan), slots assigned to requests,
freed on completion — residency management, not reallocation.

Scheduling: waiting requests are prefilled (padded to the bucket length)
into free slots; every engine tick decodes one token for all active
slots.  Greedy or temperature sampling.

Engines are plan-driven: :meth:`ServeEngine.from_plan` consumes the
frozen plan artifact the specialization flow produced (possibly reloaded
from the on-disk plan store in a different process) and derives the KV
cache sizing, decode implementation, and batching limits from it — no
ad-hoc kwargs needed between the compiler and the server.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, get_arch
from repro.models import lm
from repro.models.lm import RunCfg


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, arch: ArchConfig, params, cfg: RunCfg,
                 max_batch: int = 8, max_len: int = 512,
                 ssm_heads: int = 0, kv_heads: int = 0):
        self.arch, self.params, self.cfg = arch, params, cfg
        self.plan = None               # set by from_plan()
        self.max_batch, self.max_len = max_batch, max_len
        self.cache = lm.init_cache(arch, max_batch, max_len,
                                   ssm_heads=ssm_heads, kv_heads=kv_heads)
        self.free_slots = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.pending: List[Request] = []
        self._rid = 0
        self.finished: List[Request] = []
        # slot-level position bookkeeping (cache["pos"] is per-engine tick;
        # per-slot valid lengths live here)
        self.slot_len = np.zeros((max_batch,), np.int32)

        self._decode = jax.jit(
            lambda p, c, b: lm.decode_step(arch, p, c, b, cfg))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(arch, p, b, cfg, max_len=max_len))

    # ------------------------------------------------------------------
    @classmethod
    def from_plan(cls, plan, params, *, arch: Optional[ArchConfig] = None,
                  mesh=None, max_batch: Optional[int] = None,
                  max_len: Optional[int] = None) -> "ServeEngine":
        """Build an engine from the frozen plan artifact.

        The plan supplies everything the kwargs constructor asks for:
        the RunCfg (flash-attention tiles, padded head counts, decode
        implementation, pallas-vs-ref dispatch), the KV-cache sizing
        (padded kv/ssm heads), and the batching limits (the workload
        dims carried in the artifact).  ``arch`` overrides the registry
        lookup for reduced/custom configs whose name shadows a
        registered one; ``max_batch``/``max_len`` override the plan
        limits (e.g. a single-host deployment of a decode_32k plan).

        Without a ``mesh`` the engine is single-process, so a plan that
        chose the seq-sharded ``shard_map_flash`` decode falls back to
        the XLA decode path (the sharding decision needs a real mesh).
        """
        from repro.core.passes.lowering import build_run_cfg
        arch = arch if arch is not None else get_arch(plan.arch)
        cfg = build_run_cfg(plan, arch, mesh)
        if mesh is None and cfg.decode_impl != "xla":
            cfg = dataclasses.replace(cfg, decode_impl="xla")
        if max_batch is None:
            max_batch = (plan.global_batch
                         if plan.shape_kind == "decode" and plan.global_batch
                         else 8)
        if max_len is None:
            max_len = plan.seq_len or 512
        eng = cls(arch, params, cfg, max_batch=max_batch, max_len=max_len,
                  ssm_heads=cfg.ssm_heads_padded, kv_heads=cfg.kv_heads_padded)
        eng.plan = plan
        return eng

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        r = Request(self._rid, np.asarray(prompt, np.int32),
                    max_new_tokens, temperature, t_submit=time.time())
        self._rid += 1
        self.pending.append(r)
        return r.rid

    def _admit(self) -> None:
        """Prefill pending requests into free slots (one at a time batch=1
        prefill; production would bucket same-length prompts)."""
        while self.pending and self.free_slots:
            r = self.pending.pop(0)
            slot = self.free_slots.pop(0)
            r.slot = slot
            plen = len(r.prompt)
            logits, cache1 = self._prefill(
                self.params, {"tokens": r.prompt[None, :]})
            # copy the single-sequence cache into the engine cache slot
            for key in ("k", "v", "ssm", "conv"):
                if key in self.cache:
                    upd = cache1[key]
                    pad = self.max_len - upd.shape[2] if key in ("k", "v") else 0
                    if key in ("k", "v"):
                        upd = jnp.pad(upd, ((0, 0), (0, 0), (0, pad),
                                            (0, 0), (0, 0)))[:, 0] \
                            if upd.shape[2] != self.max_len else upd[:, 0]
                        self.cache[key] = self.cache[key].at[:, slot].set(upd)
                    else:
                        self.cache[key] = self.cache[key].at[:, slot].set(
                            upd[:, 0])
            tok = self._sample(logits[0], r.temperature)
            r.out_tokens.append(int(tok))
            r.t_first = time.time()
            self.slot_len[slot] = plen
            self.active[slot] = r

    def _sample(self, logits: jax.Array, temperature: float) -> int:
        logits = logits[:self.arch.vocab_size].astype(jnp.float32)
        if temperature <= 0:
            return int(jnp.argmax(logits))
        key = jax.random.PRNGKey(int(time.time_ns()) & 0x7FFFFFFF)
        return int(jax.random.categorical(key, logits / temperature))

    def step(self) -> int:
        """One engine tick: admit + decode one token for all active slots."""
        self._admit()
        if not self.active:
            return 0
        # uniform position: engine cache pos = max slot len (slots padded)
        self.cache["pos"] = jnp.asarray(int(self.slot_len.max()), jnp.int32)
        last = np.zeros((self.max_batch, 1), np.int32)
        for slot, r in self.active.items():
            last[slot, 0] = r.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(last)})
        finished = []
        for slot, r in list(self.active.items()):
            tok = self._sample(logits[slot], r.temperature)
            r.out_tokens.append(int(tok))
            self.slot_len[slot] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = time.time()
                finished.append(r)
                self.finished.append(r)
                del self.active[slot]
                self.free_slots.append(slot)
                self.slot_len[slot] = 0
        return len(finished)

    def run_until_idle(self, max_ticks: int = 1000) -> List[Request]:
        ticks = 0
        while (self.pending or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
