"""Batched serving engine: continuous batching over prefill + decode.

The session cache is the template's ``cache.kv`` component: allocated
once at engine start (shape from the plan), slots assigned to requests,
freed on completion — residency management, not reallocation.

Scheduling: waiting requests are prefilled (padded to the bucket length)
into free slots; every engine tick decodes one token for all active
slots.  Positions are **per slot** (``cache["pos"]`` is ``(B,)``): a
continuous batch mixes prompt lengths, so each slot appends KV and masks
attention at its own offset — an engine-global scalar position silently
corrupts every slot whose length differs from the batch max.  Freed
slots are masked to ``(token 0, pos 0)`` so their stale KV never flows
into a live decode.  Greedy or temperature sampling; sampling threads
one engine PRNG key (``seed=``), split per tick and per slot, so runs
are reproducible and slots never share a key within a tick.

Engines are plan-driven: :meth:`ServeEngine.from_plan` consumes the
frozen plan artifact the specialization flow produced (possibly reloaded
from the on-disk plan store in a different process) and derives the KV
cache sizing, decode implementation, and batching limits from it — no
ad-hoc kwargs needed between the compiler and the server.  With a
``mesh`` the engine state is *placed* per the plan's axis rules
(``dist.sharding.resolve_pspec``/``cache_pspecs``) and a plan that chose
the seq-sharded ``shard_map_flash`` decode drives it end-to-end — no
silent XLA fallback.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, get_arch
from repro.models import lm
from repro.models.lm import RunCfg


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, arch: ArchConfig, params, cfg: RunCfg,
                 max_batch: int = 8, max_len: int = 512,
                 ssm_heads: int = 0, kv_heads: int = 0, seed: int = 0):
        self.arch, self.params, self.cfg = arch, params, cfg
        self.plan = None               # set by from_plan()
        self.max_batch, self.max_len = max_batch, max_len
        self.cache = lm.init_cache(arch, max_batch, max_len,
                                   ssm_heads=ssm_heads, kv_heads=kv_heads)
        self.free_slots = list(range(max_batch))
        self.active: Dict[int, Request] = {}
        self.pending: List[Request] = []
        self._rid = 0
        self.finished: List[Request] = []
        # per-slot valid lengths; mirrored into cache["pos"] every tick
        # (freed slots stay at 0 — their stale KV is masked out)
        self.slot_len = np.zeros((max_batch,), np.int32)
        self._key = jax.random.PRNGKey(seed)
        self._pos_sharding = None      # set by _place_on_mesh()

        self._decode = jax.jit(
            lambda p, c, b: lm.decode_step(arch, p, c, b, cfg))
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(arch, p, b, cfg, max_len=max_len))

    # ------------------------------------------------------------------
    @property
    def decode_path(self) -> str:
        """The decode implementation ticks actually run through.

        ``"shard_map_flash"`` only when the seq-sharded path really
        executes; ``"flash"`` when flash_decode's internal single-shard
        combine takes over (model axis of size 1, or max_len not
        divisible by it); ``"xla"`` when no mesh was provided.
        """
        impl = self.cfg.decode_impl
        if impl == "xla":
            return impl
        if self.cfg.mesh is None:
            return "xla"               # lm.decode_step's own guard
        if impl == "shard_map_flash":
            from repro.dist.flash_decode import uses_seq_sharding
            if not uses_seq_sharding(self.cfg.mesh, self.max_len,
                                     self.cfg.model_axis):
                return "flash"         # flash_decode's single-shard path
        return impl

    @classmethod
    def from_plan(cls, plan, params, *, arch: Optional[ArchConfig] = None,
                  mesh=None, max_batch: Optional[int] = None,
                  max_len: Optional[int] = None, seed: int = 0
                  ) -> "ServeEngine":
        """Build an engine from the frozen plan artifact.

        The plan supplies everything the kwargs constructor asks for:
        the RunCfg (flash-attention tiles, padded head counts, decode
        implementation, pallas-vs-ref dispatch), the KV-cache sizing
        (padded kv/ssm heads), and the batching limits (the workload
        dims carried in the artifact).  ``arch`` overrides the registry
        lookup for reduced/custom configs whose name shadows a
        registered one; ``max_batch``/``max_len`` override the plan
        limits (e.g. a single-host deployment of a decode_32k plan).

        With a ``mesh`` the engine's params and KV cache are placed per
        the plan's axis rules and a ``shard_map_flash`` decode decision
        is honored end-to-end.  Without one the engine is
        single-process, so a plan that chose the seq-sharded decode
        falls back to the XLA decode path (the sharding decision needs
        a real mesh).
        """
        from repro.core.passes.lowering import build_run_cfg
        arch = arch if arch is not None else get_arch(plan.arch)
        cfg = build_run_cfg(plan, arch, mesh)
        if mesh is None and cfg.decode_impl != "xla":
            cfg = dataclasses.replace(cfg, decode_impl="xla")
        if max_batch is None:
            max_batch = (plan.global_batch
                         if plan.shape_kind == "decode" and plan.global_batch
                         else 8)
        if max_len is None:
            max_len = plan.seq_len or 512
        eng = cls(arch, params, cfg, max_batch=max_batch, max_len=max_len,
                  ssm_heads=cfg.ssm_heads_padded, kv_heads=cfg.kv_heads_padded,
                  seed=seed)
        eng.plan = plan
        if mesh is not None:
            eng._place_on_mesh(mesh)
        return eng

    def _place_on_mesh(self, mesh) -> None:
        """Shard params + session cache per the plan's axis rules."""
        from jax.sharding import NamedSharding
        from repro.core.passes.lowering import param_pspecs
        from repro.dist.sharding import cache_pspecs, mesh_sizes

        sizes = mesh_sizes(mesh)
        # resolve against the arrays actually handed to us — their shapes
        # may differ from the IR (reduced configs, caller-side padding)
        pspecs = param_pspecs(self.plan, self.arch, sizes,
                              shapes=self.params)
        self.params = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            self.params, pspecs)
        cshapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in self.cache.items()}
        cpspecs = cache_pspecs(self.plan, self.arch, cshapes, sizes)
        shardings = {k: NamedSharding(mesh, s) for k, s in cpspecs.items()}
        self.cache = {k: jax.device_put(v, shardings[k])
                      for k, v in self.cache.items()}
        self._pos_sharding = shardings["pos"]

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + max_new_tokens > self.max_len:
            # past capacity the per-slot append clamps onto the last cache
            # row and silently corrupts the tail — refuse loudly instead
            raise ValueError(
                f"request needs {len(prompt)} prompt + {max_new_tokens} new "
                f"tokens > max_len={self.max_len} cache rows; raise max_len "
                "or lower max_new_tokens")
        r = Request(self._rid, prompt, max_new_tokens, temperature,
                    t_submit=time.time())
        self._rid += 1
        self.pending.append(r)
        return r.rid

    def _admit(self) -> None:
        """Prefill pending requests into free slots (one at a time batch=1
        prefill; production would bucket same-length prompts)."""
        while self.pending and self.free_slots:
            r = self.pending.pop(0)
            slot = self.free_slots.pop(0)
            r.slot = slot
            plen = len(r.prompt)
            logits, cache1 = self._prefill(
                self.params, {"tokens": r.prompt[None, :]})
            tok = self._sample(logits[0], r.temperature, self._next_key())
            r.out_tokens.append(int(tok))
            r.t_first = time.time()
            if len(r.out_tokens) >= r.max_new_tokens:
                # the prefill sample already met the budget: finish now —
                # no decode tick to over-generate on, no cache-slot copy
                r.done = True
                r.t_done = r.t_first
                self.finished.append(r)
                self.free_slots.append(slot)
                continue
            # copy the single-sequence cache into the engine cache slot
            for key in ("k", "v", "ssm", "conv"):
                if key in self.cache:
                    upd = cache1[key]
                    pad = self.max_len - upd.shape[2] if key in ("k", "v") else 0
                    if key in ("k", "v"):
                        upd = jnp.pad(upd, ((0, 0), (0, 0), (0, pad),
                                            (0, 0), (0, 0)))[:, 0] \
                            if upd.shape[2] != self.max_len else upd[:, 0]
                        self.cache[key] = self.cache[key].at[:, slot].set(upd)
                    else:
                        self.cache[key] = self.cache[key].at[:, slot].set(
                            upd[:, 0])
            self.slot_len[slot] = plen
            self.active[slot] = r

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _sample(self, logits: jax.Array, temperature: float,
                key: jax.Array) -> int:
        logits = logits[:self.arch.vocab_size].astype(jnp.float32)
        if temperature <= 0:
            return int(jnp.argmax(logits))
        return int(jax.random.categorical(key, logits / temperature))

    def _sync_pos(self) -> None:
        """Mirror per-slot lengths into the device cache (freed slots 0)."""
        pos = jnp.asarray(self.slot_len)
        if self._pos_sharding is not None:
            pos = jax.device_put(pos, self._pos_sharding)
        self.cache["pos"] = pos

    def step(self) -> int:
        """One engine tick: admit + decode one token for all active slots."""
        self._admit()
        if not self.active:
            return 0
        # per-slot positions: every slot decodes at its own offset.  Freed
        # slots are masked to (token 0, pos 0): their decode is a bounded
        # dummy over one cache row, so stale KV / stale last-token garbage
        # never reaches a live slot's logits.
        self._sync_pos()
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for slot, r in self.active.items():
            tokens[slot, 0] = r.out_tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": jnp.asarray(tokens)})
        slot_keys = jax.random.split(self._next_key(), self.max_batch)
        finished = []
        for slot, r in list(self.active.items()):
            tok = self._sample(logits[slot], r.temperature, slot_keys[slot])
            r.out_tokens.append(int(tok))
            self.slot_len[slot] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
                r.t_done = time.time()
                finished.append(r)
                self.finished.append(r)
                del self.active[slot]
                self.free_slots.append(slot)
                self.slot_len[slot] = 0
        return len(finished)

    def run_until_idle(self, max_ticks: int = 1000) -> List[Request]:
        ticks = 0
        while (self.pending or self.active) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished
