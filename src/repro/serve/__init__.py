from repro.serve.allocator import BlockAllocator
from repro.serve.engine import Request, ServeEngine
__all__ = ["BlockAllocator", "Request", "ServeEngine"]
