from repro.serve.allocator import BlockAllocator
from repro.serve.engine import (OverloadError, PreemptedRequest,
                                PreemptionPolicy, Request, ServeEngine)
__all__ = ["BlockAllocator", "OverloadError", "PreemptedRequest",
           "PreemptionPolicy", "Request", "ServeEngine"]
