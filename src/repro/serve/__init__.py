from repro.serve.allocator import BlockAllocator
from repro.serve.disagg import (DegradedMode, PlanHandshakeError,
                                PrefillFleet)
from repro.serve.engine import (OverloadError, PreemptedRequest,
                                PreemptionPolicy, Request, ServeEngine)
__all__ = ["BlockAllocator", "DegradedMode", "OverloadError",
           "PlanHandshakeError", "PreemptedRequest", "PreemptionPolicy",
           "PrefillFleet", "Request", "ServeEngine"]
