"""Block-pool allocator for the paged KV cache — sub-pool aware,
refcounted for cross-request block sharing, tier-aware for host spill.

The serving engine's residency management for a paged plan is exactly
this object: blocks are handed out on admission (or granted one at a
time as decode crosses block boundaries) and returned on finish.
Under 2-D pool sharding (:func:`repro.dist.flash_decode
.pool_sharding_kind` == ``"2d"``) the pool splits data-major into one
*sub-pool per data shard* and a slot may only hold blocks from the
sub-pool of the data shard hosting it — a foreign block would be owned
by no shard in the slot's data row and silently mask out of the
combine.  The allocator enforces that contract structurally: every
``allocate`` draws from one group's free list, and ``release`` returns
each block to the group its id belongs to.

Prefix sharing (cross-request KV reuse) adds **per-block refcounts**:
``allocate`` hands out blocks at count 1, ``retain`` bumps the count
when another request aliases a block into its table (a prefix-cache
hit), and ``release`` only returns a block to its sub-pool's free list
when the count reaches zero.  Conservation is counted over *unique*
resident blocks — a block aliased by five requests pins one block, not
five; ``stats()["shared"]`` reports how many resident blocks currently
have more than one holder.

Multi-tier residency (the plan's ``kv_tier_split``): behind the HBM
pool sits an optional **host-DRAM spill pool** of ``host_blocks``
blocks.  Residency is explicit in the id space — HBM blocks are
``[0, n_blocks)`` (grouped into sub-pools as before), host blocks are
``[n_blocks, n_blocks + host_blocks)`` (one flat pool; host DRAM has
no combine contract to respect).  ``spill(blocks)`` moves resident
HBM blocks to host ids — the whole refcount travels with the content,
the vacated HBM id returns to its sub-pool's free list — and
``promote(blocks, group)`` is the inverse, drawing fresh HBM ids from
one sub-pool (so a promoted block lands in the requesting slot's data
shard).  Callers move the actual k/v rows; the allocator moves the
*accounting*, and hands back ``(old_id, new_id)`` pairs so block
tables and prefix-trie entries can be re-keyed.  Conservation is
counted **per tier**: the HBM identity ``free + in_use == n_blocks``
and the host identity ``host_free + host_in_use == host_blocks`` are
asserted independently on every ``stats()`` call.

Grow-on-demand support (the grant admission mode): free lists are
:class:`collections.deque` (O(1) grants at any pool size — ``pop(0)``
on a list is O(n) and showed up at production pool sizes), and each
sub-pool tracks a *low watermark* (the smallest free count it reached
in the current epoch) so the engine's rebalancer can tell a
persistently hot sub-pool from a transient dip without keeping its own
history.  Watermarks are **epoch-based**: ``reset_low_water()`` starts
a new epoch by snapping every watermark to its sub-pool's current free
count — without it the mark only ever ratchets down, so one transient
dip poisons the hot-sub-pool signal for the engine's whole lifetime
(the bug the epoch reset fixes; the engine calls it once per rebalance
cycle).

Invariants (the property suite in ``tests/test_properties.py`` fuzzes
these over random admit/grant/retain/spill/promote/finish sequences):

* conservation per tier — ``free + in_use == n_blocks`` for the HBM
  tier and ``host_free + host_in_use == host_blocks`` for the host
  tier at every point, where ``in_use`` counts unique resident blocks
  regardless of how many holders share them (``stats()`` re-asserts
  both on every call);
* no double-assignment — a block is *allocated* to at most one holder;
  additional holders arrive only through an explicit ``retain``;
* group integrity — allocations never cross a sub-pool boundary, and a
  ``promote`` lands in exactly the group it was asked for;
* refcount transfer — ``spill``/``promote`` move a block's holder
  count unchanged (shared prefix blocks are spillable; a writer must
  promote before touching them, which the engine's CoW barrier already
  forces for any shared block);
* no leaks — releasing every holder's reference restores
  ``free == n_blocks`` and ``host_free == host_blocks``;
* no grant after free — a released block sits in its free list until
  re-allocated; it is never still owned by its previous holder;
* refcount sanity — resident blocks have count >= 1, freeing past
  zero (double free) raises, and ``release([])`` is an explicit no-op
  that never touches low-water bookkeeping.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple


class BlockAllocator:
    """FIFO free-list allocator over ``groups`` equal sub-pools, plus
    an optional flat host-tier spill pool.

    Group ``g`` owns the contiguous HBM block ids ``[g * n/groups,
    (g+1) * n/groups)`` — the data-major layout the 2-D pool's
    PartitionSpec gives the block dim, so "group" == "data shard".
    ``groups=1`` is the 1-D (or unsharded) pool.  Host block ids live
    past the HBM range: ``[n_blocks, n_blocks + host_blocks)``.
    """

    def __init__(self, n_blocks: int, groups: int = 1,
                 host_blocks: int = 0):
        if groups < 1:
            raise ValueError(f"groups must be >= 1, got {groups}")
        if n_blocks < 0 or n_blocks % groups:
            raise ValueError(
                f"n_blocks={n_blocks} must be a non-negative multiple of "
                f"groups={groups} (equal sub-pools per data shard)")
        if host_blocks < 0:
            raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
        self.n_blocks = n_blocks
        self.groups = groups
        self.group_size = n_blocks // groups
        self.host_blocks = host_blocks
        self._free: List[Deque[int]] = [
            deque(range(g * self.group_size, (g + 1) * self.group_size))
            for g in range(groups)]
        self._host_free: Deque[int] = deque(
            range(n_blocks, n_blocks + host_blocks))
        self._owned: set = set()
        # per-block holder counts for resident blocks (absent == free);
        # 1 = private, >1 = aliased by multiple block tables
        self._ref: Dict[int, int] = {}
        # per-sub-pool pressure telemetry: smallest free count seen in
        # the current epoch (the rebalancer's "hot sub-pool" signal)
        # and grant/tier-transition counters
        self._low_water: List[int] = [self.group_size] * groups
        self.grants: int = 0
        self.spills: int = 0
        self.promotes: int = 0
        self.low_water_epochs: int = 0

    # ------------------------------------------------------------------
    def group_of(self, block_id: int) -> int:
        """The sub-pool an HBM block id belongs to.  Host-tier ids have
        no group (host DRAM has no combine contract) and are rejected —
        a caller asking is about to violate the slot→sub-pool mapping."""
        if not 0 <= block_id < self.n_blocks:
            raise ValueError(f"block id {block_id} outside HBM pool "
                             f"[0, {self.n_blocks})")
        return block_id // self.group_size if self.group_size else 0

    def tier_of(self, block_id: int) -> str:
        """``"hbm"`` or ``"host"`` — residency is the id range."""
        if not 0 <= block_id < self.n_blocks + self.host_blocks:
            raise ValueError(
                f"block id {block_id} outside both tiers "
                f"[0, {self.n_blocks + self.host_blocks})")
        return "hbm" if block_id < self.n_blocks else "host"

    def free_in(self, group: int = 0) -> int:
        return len(self._free[group])

    @property
    def free(self) -> int:
        """Free HBM blocks (the decode-visible tier)."""
        return sum(len(f) for f in self._free)

    @property
    def host_free(self) -> int:
        return len(self._host_free)

    def low_water(self, group: int = 0) -> int:
        """Smallest free count this sub-pool reached in the current
        epoch — 0 means it has been fully drained since the last
        ``reset_low_water()`` (a hot sub-pool)."""
        return self._low_water[group]

    def reset_low_water(self) -> None:
        """Start a new low-water epoch: snap every sub-pool's watermark
        to its *current* free count.  The mark only ever decreases
        between resets, so without an epoch boundary one transient dip
        (a burst that drained a sub-pool once, hours ago) reads as a
        permanently hot sub-pool and the rebalancer's signal goes
        stale.  The engine calls this once per rebalance cycle."""
        for g in range(self.groups):
            self._low_water[g] = len(self._free[g])
        self.low_water_epochs += 1

    def allocate(self, need: int, group: int = 0) -> Optional[List[int]]:
        """``need`` HBM blocks from one sub-pool, or None if it cannot
        cover them (callers treat None as "wait for a finisher" or
        "preempt a victim" — partial grants would deadlock two
        half-admitted requests).  Fresh blocks start at refcount 1."""
        if need < 0:
            raise ValueError(f"need must be >= 0, got {need}")
        free = self._free[group]
        if need > len(free):
            return None
        blocks = [free.popleft() for _ in range(need)]
        self._owned.update(blocks)
        for b in blocks:
            self._ref[b] = 1
        self.grants += 1
        if len(free) < self._low_water[group]:
            self._low_water[group] = len(free)
        return blocks

    def allocate_one(self, group: int = 0) -> Optional[int]:
        """One-block grant (the grow-on-demand path: a slot asks for its
        next block only when decode crosses a block boundary)."""
        got = self.allocate(1, group)
        return got[0] if got is not None else None

    def retain(self, blocks: Sequence[int]) -> None:
        """Bump the holder count of resident blocks — the prefix-cache
        hit path: another request aliases these blocks into its table.
        Retaining a block the pool does not currently hold is loud (the
        aliased content would be whatever the next tenant writes)."""
        for b in blocks:
            if b not in self._owned:
                raise ValueError(
                    f"block {b} is not currently allocated — cannot retain "
                    "a free (or never-owned) block; an alias to it would "
                    "read the next tenant's rows")
            self._ref[b] += 1

    def refcount(self, block_id: int) -> int:
        """Current holder count (0 = free).  Refcount > 1 means the
        block is shared: writers must copy it first (CoW)."""
        return self._ref.get(block_id, 0)

    @property
    def shared_blocks(self) -> int:
        """Resident blocks with more than one holder."""
        return sum(1 for c in self._ref.values() if c > 1)

    # ---------------- tier transitions --------------------------------
    def _validate_resident(self, blocks: Sequence[int], tier: str) -> None:
        seen = set()
        for b in blocks:
            if b in seen:
                raise ValueError(f"block {b} listed twice — a tier "
                                 "transition moves each block once")
            seen.add(b)
            if b not in self._owned:
                raise ValueError(
                    f"block {b} is not currently allocated — only "
                    "resident blocks change tier")
            if self.tier_of(b) != tier:
                raise ValueError(
                    f"block {b} is {self.tier_of(b)}-resident, "
                    f"expected {tier}")

    def spill(self, blocks: Sequence[int]
              ) -> Optional[List[Tuple[int, int]]]:
        """Move resident HBM blocks to the host tier.  Returns
        ``(hbm_id, host_id)`` pairs — the caller copies the k/v rows and
        re-keys tables/trie entries — or None when the host pool cannot
        cover them all (partial spills would strand a request across an
        un-promotable split).  The vacated HBM ids return to their
        sub-pools' free lists; each block's holder count travels with
        it, so shared blocks are spillable (sharers all follow the new
        id; a writer must promote first — the engine's CoW barrier
        already forbids writing any shared block in place)."""
        self._validate_resident(blocks, "hbm")
        if len(blocks) > len(self._host_free):
            return None
        pairs: List[Tuple[int, int]] = []
        for b in blocks:
            h = self._host_free.popleft()
            self._ref[h] = self._ref.pop(b)
            self._owned.discard(b)
            self._owned.add(h)
            self._free[self.group_of(b)].append(b)
            pairs.append((b, h))
        self.spills += len(pairs)
        return pairs

    def promote(self, blocks: Sequence[int], group: int = 0
                ) -> Optional[List[Tuple[int, int]]]:
        """Move resident host-tier blocks back into one HBM sub-pool
        (the slot that needs them decodes there — group integrity is
        preserved by construction).  Returns ``(host_id, hbm_id)``
        pairs, or None when the sub-pool cannot cover them all.  The
        freed host ids return to the host free list; holder counts
        travel unchanged."""
        self._validate_resident(blocks, "host")
        free = self._free[group]
        if len(blocks) > len(free):
            return None
        pairs: List[Tuple[int, int]] = []
        for h in blocks:
            b = free.popleft()
            self._ref[b] = self._ref.pop(h)
            self._owned.discard(h)
            self._owned.add(b)
            self._host_free.append(h)
            pairs.append((h, b))
        self.promotes += len(pairs)
        if len(free) < self._low_water[group]:
            self._low_water[group] = len(free)
        return pairs

    # ------------------------------------------------------------------
    def release(self, blocks: Sequence[int]) -> List[int]:
        """Drop one holder reference per listed block; a block returns
        to its tier's free list (its sub-pool's for HBM ids, the host
        pool's for host ids) only when its count reaches zero.  Returns
        the blocks actually freed (so the engine can prune prefix-trie
        entries pointing at them).  Double frees stay loud — a silent
        one would let two slots share a block they never agreed to
        share.

        An empty ``blocks`` sequence is an explicit no-op: a request
        that sheds before any grant releases nothing, and that path must
        not touch free lists or low-water bookkeeping (pinned by the
        churn fuzz)."""
        if not blocks:
            # no-op by contract; re-assert conservation so a corrupted
            # caller path fails here rather than at the next decode
            hbm_in_use = sum(1 for b in self._owned if b < self.n_blocks)
            assert self.free + hbm_in_use == self.n_blocks, (
                f"block conservation violated on empty release: "
                f"free={self.free} in_use={hbm_in_use} "
                f"total={self.n_blocks}")
            return []
        freed: List[int] = []
        for b in blocks:
            if b not in self._owned:
                raise ValueError(
                    f"block {b} is not currently allocated "
                    "(double free, or a block this pool never owned)")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._owned.discard(b)
                if b < self.n_blocks:
                    self._free[self.group_of(b)].append(b)
                else:
                    self._host_free.append(b)
                freed.append(b)
        return freed

    def stats(self) -> Dict[str, int]:
        free = self.free
        hbm_in_use = sum(1 for b in self._owned if b < self.n_blocks)
        host_in_use = len(self._owned) - hbm_in_use
        # conservation is the invariant everything else leans on; a
        # broken free list must fail here, not as a downstream decode
        # reading a double-assigned block.  Sharing does not bend it
        # (in_use counts unique resident blocks, however many holders)
        # and neither does tiering: each tier balances independently.
        assert free + hbm_in_use == self.n_blocks, (
            f"HBM block conservation violated: free={free} "
            f"in_use={hbm_in_use} total={self.n_blocks}")
        assert self.host_free + host_in_use == self.host_blocks, (
            f"host block conservation violated: free={self.host_free} "
            f"in_use={host_in_use} total={self.host_blocks}")
        assert all(c >= 1 for c in self._ref.values()), (
            "resident block with refcount < 1")
        assert set(self._ref) == self._owned, (
            "refcount map out of sync with ownership set")
        return {"total": self.n_blocks, "free": free,
                "in_use": hbm_in_use, "shared": self.shared_blocks,
                "groups": self.groups,
                "host_total": self.host_blocks,
                "host_free": self.host_free,
                "host_in_use": host_in_use}
