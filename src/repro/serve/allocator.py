"""Block-pool allocator for the paged KV cache — sub-pool aware,
refcounted for cross-request block sharing.

The serving engine's residency management for a paged plan is exactly
this object: blocks are handed out on admission (or granted one at a
time as decode crosses block boundaries) and returned on finish.
Under 2-D pool sharding (:func:`repro.dist.flash_decode
.pool_sharding_kind` == ``"2d"``) the pool splits data-major into one
*sub-pool per data shard* and a slot may only hold blocks from the
sub-pool of the data shard hosting it — a foreign block would be owned
by no shard in the slot's data row and silently mask out of the
combine.  The allocator enforces that contract structurally: every
``allocate`` draws from one group's free list, and ``release`` returns
each block to the group its id belongs to.

Prefix sharing (cross-request KV reuse) adds **per-block refcounts**:
``allocate`` hands out blocks at count 1, ``retain`` bumps the count
when another request aliases a block into its table (a prefix-cache
hit), and ``release`` only returns a block to its sub-pool's free list
when the count reaches zero.  Conservation is counted over *unique*
resident blocks — a block aliased by five requests pins one block, not
five; ``stats()["shared"]`` reports how many resident blocks currently
have more than one holder.

Grow-on-demand support (the grant admission mode): free lists are
:class:`collections.deque` (O(1) grants at any pool size — ``pop(0)``
on a list is O(n) and showed up at production pool sizes), and each
sub-pool tracks a *low watermark* (the smallest free count it ever
reached) so the engine's rebalancer can tell a persistently hot
sub-pool from a transient dip without keeping its own history.

Invariants (the property suite in ``tests/test_properties.py`` fuzzes
these over random admit/grant/retain/finish/churn sequences):

* conservation — ``free + in_use == n_blocks`` at every point, where
  ``in_use`` counts unique resident blocks regardless of how many
  holders share them (``stats()`` re-asserts this on every call);
* no double-assignment — a block is *allocated* to at most one holder;
  additional holders arrive only through an explicit ``retain``;
* group integrity — allocations never cross a sub-pool boundary;
* no leaks — releasing every holder's reference restores
  ``free == n_blocks``;
* no grant after free — a released block sits in its free list until
  re-allocated; it is never still owned by its previous holder;
* refcount sanity — resident blocks have count >= 1, freeing past
  zero (double free) raises, and ``release([])`` is an explicit no-op
  that never touches low-water bookkeeping.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Sequence


class BlockAllocator:
    """FIFO free-list allocator over ``groups`` equal sub-pools.

    Group ``g`` owns the contiguous block ids ``[g * n/groups,
    (g+1) * n/groups)`` — the data-major layout the 2-D pool's
    PartitionSpec gives the block dim, so "group" == "data shard".
    ``groups=1`` is the 1-D (or unsharded) pool.
    """

    def __init__(self, n_blocks: int, groups: int = 1):
        if groups < 1:
            raise ValueError(f"groups must be >= 1, got {groups}")
        if n_blocks < 0 or n_blocks % groups:
            raise ValueError(
                f"n_blocks={n_blocks} must be a non-negative multiple of "
                f"groups={groups} (equal sub-pools per data shard)")
        self.n_blocks = n_blocks
        self.groups = groups
        self.group_size = n_blocks // groups
        self._free: List[Deque[int]] = [
            deque(range(g * self.group_size, (g + 1) * self.group_size))
            for g in range(groups)]
        self._owned: set = set()
        # per-block holder counts for resident blocks (absent == free);
        # 1 = private, >1 = aliased by multiple block tables
        self._ref: Dict[int, int] = {}
        # per-sub-pool pressure telemetry: smallest free count ever seen
        # (the rebalancer's "hot sub-pool" signal) and grant counters
        self._low_water: List[int] = [self.group_size] * groups
        self.grants: int = 0

    # ------------------------------------------------------------------
    def group_of(self, block_id: int) -> int:
        """The sub-pool a block id belongs to."""
        if not 0 <= block_id < self.n_blocks:
            raise ValueError(f"block id {block_id} outside pool "
                             f"[0, {self.n_blocks})")
        return block_id // self.group_size if self.group_size else 0

    def free_in(self, group: int = 0) -> int:
        return len(self._free[group])

    @property
    def free(self) -> int:
        return sum(len(f) for f in self._free)

    def low_water(self, group: int = 0) -> int:
        """Smallest free count this sub-pool has ever reached — 0 means
        it has been fully drained at least once (a hot sub-pool)."""
        return self._low_water[group]

    def allocate(self, need: int, group: int = 0) -> Optional[List[int]]:
        """``need`` blocks from one sub-pool, or None if it cannot cover
        them (callers treat None as "wait for a finisher" or "preempt a
        victim" — partial grants would deadlock two half-admitted
        requests).  Fresh blocks start at refcount 1."""
        if need < 0:
            raise ValueError(f"need must be >= 0, got {need}")
        free = self._free[group]
        if need > len(free):
            return None
        blocks = [free.popleft() for _ in range(need)]
        self._owned.update(blocks)
        for b in blocks:
            self._ref[b] = 1
        self.grants += 1
        if len(free) < self._low_water[group]:
            self._low_water[group] = len(free)
        return blocks

    def allocate_one(self, group: int = 0) -> Optional[int]:
        """One-block grant (the grow-on-demand path: a slot asks for its
        next block only when decode crosses a block boundary)."""
        got = self.allocate(1, group)
        return got[0] if got is not None else None

    def retain(self, blocks: Sequence[int]) -> None:
        """Bump the holder count of resident blocks — the prefix-cache
        hit path: another request aliases these blocks into its table.
        Retaining a block the pool does not currently hold is loud (the
        aliased content would be whatever the next tenant writes)."""
        for b in blocks:
            if b not in self._owned:
                raise ValueError(
                    f"block {b} is not currently allocated — cannot retain "
                    "a free (or never-owned) block; an alias to it would "
                    "read the next tenant's rows")
            self._ref[b] += 1

    def refcount(self, block_id: int) -> int:
        """Current holder count (0 = free).  Refcount > 1 means the
        block is shared: writers must copy it first (CoW)."""
        return self._ref.get(block_id, 0)

    @property
    def shared_blocks(self) -> int:
        """Resident blocks with more than one holder."""
        return sum(1 for c in self._ref.values() if c > 1)

    def release(self, blocks: Sequence[int]) -> List[int]:
        """Drop one holder reference per listed block; a block returns
        to its sub-pool's free list only when its count reaches zero.
        Returns the blocks actually freed (so the engine can prune
        prefix-trie entries pointing at them).  Double frees stay loud —
        a silent one would let two slots share a block they never agreed
        to share.

        An empty ``blocks`` sequence is an explicit no-op: a request
        that sheds before any grant releases nothing, and that path must
        not touch free lists or low-water bookkeeping (pinned by the
        churn fuzz)."""
        if not blocks:
            # no-op by contract; re-assert conservation so a corrupted
            # caller path fails here rather than at the next decode
            assert self.free + len(self._owned) == self.n_blocks, (
                f"block conservation violated on empty release: "
                f"free={self.free} in_use={len(self._owned)} "
                f"total={self.n_blocks}")
            return []
        freed: List[int] = []
        for b in blocks:
            if b not in self._owned:
                raise ValueError(
                    f"block {b} is not currently allocated "
                    "(double free, or a block this pool never owned)")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._owned.discard(b)
                self._free[self.group_of(b)].append(b)
                freed.append(b)
        return freed

    def stats(self) -> Dict[str, int]:
        free = self.free
        in_use = len(self._owned)
        # conservation is the invariant everything else leans on; a
        # broken free list must fail here, not as a downstream decode
        # reading a double-assigned block.  Sharing does not bend it:
        # in_use counts unique resident blocks, however many holders.
        assert free + in_use == self.n_blocks, (
            f"block conservation violated: free={free} in_use={in_use} "
            f"total={self.n_blocks}")
        assert all(c >= 1 for c in self._ref.values()), (
            "resident block with refcount < 1")
        assert set(self._ref) == self._owned, (
            "refcount map out of sync with ownership set")
        return {"total": self.n_blocks, "free": free,
                "in_use": in_use, "shared": self.shared_blocks,
                "groups": self.groups}
