"""Cross-request prefix index over resident KV blocks.

Production serving traffic is dominated by shared prefix tokens —
system prompts, few-shot headers, multi-turn session history.  The
paged pool (PR 4) plus the content-addressing idiom of
:mod:`repro.core.planstore` compose into a fleet-level prefix cache:
every *full* ``block_len``-aligned chunk of a request's feed tokens is
content-hashed with a **chained** hash (each block's hash folds in its
predecessor's, exactly like ``FrozenPlan.content_hash`` folds the whole
canonical payload), so a single hash names an entire prefix path.  The
chain is what makes a flat ``hash -> block id`` dict a radix trie with
maximal path compression: walking a request's chunk hashes in order and
stopping at the first miss *is* the longest-prefix descent, because a
chain hash can only match when every earlier chunk matched too.

One trie per allocator sub-pool: under 2-D pool sharding a slot may
only hold blocks from its data shard's sub-pool, so a match in a
foreign sub-pool would alias a block the slot's combine masks out.
Admission therefore matches per sub-pool and prefers placing the
request where the longest match lives.

Lifecycle contract (the engine owns it):

* ``insert`` after a request's feed rows land in pool blocks — only
  blocks covering *complete* chunks are indexed (a partial tail block
  is still being written and has no stable content);
* ``match`` at admission returns the resident block ids covering the
  longest indexed prefix of the feed;
* ``evict`` whenever blocks actually return to the free list
  (``BlockAllocator.release`` reports them) — a freed id's next tenant
  writes unrelated rows, so a stale trie entry would alias garbage.
  While *any* holder keeps a block resident its trie entry stays live,
  which is what lets request B keep hitting a prefix request A
  registered even after A finished, as long as a sharer pins it;
* ``rekey`` when a resident block changes id *without* changing
  content — a spill to the host tier, a promote back, a migration.
  Entries are **tier-tagged** so a hit can tell a decode-ready HBM
  block from a spilled one that must be promoted first: a hit on a
  spilled prefix promotes, it does not miss.  Only an actual free
  evicts.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Sequence, Tuple

import numpy as np


def chain_hashes(tokens: Sequence[int], block_len: int) -> List[str]:
    """Chained content hashes of the full ``block_len`` chunks of
    ``tokens``: ``h[i] = sha256(h[i-1] || tokens[i*bl:(i+1)*bl])``.

    Only complete chunks are hashed — a trailing partial chunk has no
    entry (its block is still mutable).  The chain means ``h[i]``
    commits to every token before position ``(i+1)*bl``, so equal
    hashes imply equal whole prefixes, not merely equal chunks.
    """
    if block_len <= 0:
        return []
    toks = np.asarray(tokens, np.int64)
    out: List[str] = []
    h = b"kv-prefix-root"
    for i in range(len(toks) // block_len):
        chunk = toks[i * block_len:(i + 1) * block_len]
        h = hashlib.sha256(h + b"|" + chunk.tobytes()).digest()
        out.append(h.hex())
    return out


class PrefixCache:
    """Radix trie (chain-hash compressed) over resident pool blocks,
    one per allocator sub-pool."""

    def __init__(self, groups: int = 1):
        if groups < 1:
            raise ValueError(f"groups must be >= 1, got {groups}")
        self.groups = groups
        self._trie: List[Dict[str, int]] = [dict() for _ in range(groups)]
        self._by_block: Dict[int, Tuple[int, str]] = {}
        # residency tag per indexed block ("hbm" | "host"): a hit on a
        # host-tagged block is still a hit — the engine promotes it
        # back before aliasing (hit-after-spill)
        self._tier: Dict[int, str] = {}
        # telemetry: admission-time outcomes
        self.hits = 0           # requests admitted with >= 1 matched block
        self.misses = 0         # requests admitted with no match
        self.hit_tokens = 0     # total tokens whose prefill was aliased

    def __len__(self) -> int:
        return len(self._by_block)

    def has_block(self, block: int) -> bool:
        """Is this block id currently indexed by any trie entry?"""
        return block in self._by_block

    def tier_of(self, block: int) -> str:
        """Residency tag of an indexed block (KeyError if unindexed)."""
        return self._tier[block]

    def match(self, hashes: Sequence[str], group: int = 0) -> List[int]:
        """Longest-prefix descent: resident block ids for the leading
        run of chunk hashes present in ``group``'s trie."""
        t = self._trie[group]
        out: List[int] = []
        for h in hashes:
            b = t.get(h)
            if b is None:
                break
            out.append(b)
        return out

    def insert(self, hashes: Sequence[str], blocks: Sequence[int],
               group: int = 0, tier: str = "hbm") -> None:
        """Index ``blocks[i]`` as holding the prefix named ``hashes[i]``.
        First writer wins: a hash already present keeps its original
        block (the new copy is a private duplicate — correct, just not
        shared), and a block id already indexed under another hash is
        left alone (it cannot hold two different contents)."""
        t = self._trie[group]
        for h, b in zip(hashes, blocks):
            if h in t or b in self._by_block:
                continue
            t[h] = b
            self._by_block[b] = (group, h)
            self._tier[b] = tier

    def rekey(self, pairs: Sequence[Tuple[int, int]], tier: str) -> None:
        """Follow indexed blocks through a tier transition (or any
        id-preserving-content move): entry ``old`` becomes ``new``,
        tagged with the destination ``tier``.  The entry stays in its
        original group's trie — a spilled block still belongs to the
        sub-pool whose requests can promote it, and a promote re-tags
        in place.  Unindexed ``old`` ids are skipped (not every spilled
        block was ever registered)."""
        for old, new in pairs:
            gh = self._by_block.pop(old, None)
            if gh is None:
                continue
            self._tier.pop(old, None)
            g, h = gh
            self._trie[g][h] = new
            self._by_block[new] = (g, h)
            self._tier[new] = tier

    def evict(self, blocks: Sequence[int]) -> None:
        """Prune entries whose backing blocks left the pool (freed, or
        about to be rewritten by migration/CoW)."""
        for b in blocks:
            gh = self._by_block.pop(b, None)
            if gh is not None:
                self._trie[gh[0]].pop(gh[1], None)
            self._tier.pop(b, None)

    def stats(self) -> Dict[str, int]:
        return {"trie_blocks": len(self._by_block),
                "host_blocks": sum(1 for t in self._tier.values()
                                   if t == "host"),
                "hits": self.hits, "misses": self.misses,
                "hit_tokens": self.hit_tokens}
