"""AdamW, pure JAX, with the plan's "technology" knobs.

The data-organization pass may decide (under HBM pressure) to keep Adam
moments in bf16 and/or drop the fp32 master copy; in the latter case the
bf16 params are updated with *stochastic rounding* so the update bias
stays zero.  Both decisions arrive via ``plan.opt``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"     # plan.opt["moment_dtype"]
    master_weights: bool = True       # plan.opt["master_weights"]

    @classmethod
    def from_plan(cls, plan, **kw) -> "OptConfig":
        return cls(moment_dtype=plan.opt["moment_dtype"],
                   master_weights=plan.opt["master_weights"], **kw)


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to 10% of peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    denom = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / denom, 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros_like = lambda p: jnp.zeros(p.shape, mdt)
    state: Dict[str, Any] = {
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _stochastic_round_bf16(key: jax.Array, x: jax.Array) -> jax.Array:
    """fp32 -> bf16 with probability proportional to the truncated bits."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    rnd = jax.random.bits(key, bits.shape, jnp.uint32) & jnp.uint32(0xFFFF)
    rounded = (bits + rnd) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(
        jnp.bfloat16)


def apply_updates(
    params,
    grads,
    state: Dict[str, Any],
    cfg: OptConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (params', state', metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    master = state.get("master", params)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = jax.tree.leaves(master)

    new_p, new_m, new_v, new_w = [], [], [], []
    base_key = jax.random.fold_in(jax.random.PRNGKey(0x5AD3), step)
    for i, (p, g, m, v, w) in enumerate(
            zip(flat_p, flat_g, flat_m, flat_v, flat_w)):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        w32 = w.astype(jnp.float32)
        # decoupled weight decay on everything that looks like a matrix
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * w32
        w32 = w32 - lr * upd
        if cfg.master_weights:
            new_w.append(w32)
            new_p.append(w32.astype(p.dtype))
        else:
            if p.dtype == jnp.bfloat16:
                k = jax.random.fold_in(base_key, i)
                new_p.append(_stochastic_round_bf16(k, w32))
            else:
                new_p.append(w32.astype(p.dtype))
        new_m.append(m32.astype(mdt))
        new_v.append(v32.astype(mdt))

    params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    if cfg.master_weights:
        new_state["master"] = jax.tree.unflatten(treedef, new_w)
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
