from repro.optim.adamw import (
    OptConfig,
    apply_updates,
    global_norm,
    init_opt_state,
    lr_schedule,
)

__all__ = ["OptConfig", "apply_updates", "global_norm", "init_opt_state",
           "lr_schedule"]
