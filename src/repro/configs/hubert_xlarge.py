"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only transformer, same backbone as wav2vec2 [arXiv:2106.07447].
The convolutional audio frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings (batch, frames, d_model).  Training objective
is masked-frame prediction over the 504 cluster codebook.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    modality="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope_theta=0.0,          # learned/conv positions in the original; stubbed
    norm_eps=1e-5,
    source="arXiv:2106.07447; unverified",
)
