"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676].

Hymba runs attention heads and SSM heads *in parallel* inside each block
and mixes their (normalized) outputs.  Three layers use global (full)
attention; the rest use sliding-window attention (window 1024) — which is
what makes the ``long_500k`` decode cell sub-quadratic-feasible.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    window=1024,
    global_every=16,         # layers 0, 16, 31 -> global (see models/lm.py)
    ssm_state=16,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_n_groups=1,
    rope_theta=1e4,
    norm_eps=1e-6,
    source="arXiv:2411.13676; hf",
)
