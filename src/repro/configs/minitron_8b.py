"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron [arXiv:2407.14679]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    gated_mlp=False,         # nemotron/minitron use squared-ReLU, 2-matrix FFN
    rope_theta=1e4,
    norm_eps=1e-5,
    source="arXiv:2407.14679; hf",
)
