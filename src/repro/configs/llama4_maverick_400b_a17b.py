"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, vocab=202048, MoE 128 experts top-1, early fusion.

Maverick interleaves MoE every other layer and adds one shared expert
(hf:meta-llama/Llama-4-*; unverified).  Dense layers use d_ff=16384
(2x expert dim) per the released config.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,              # dense-layer FFN width
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    moe_interleave=2,        # MoE every other layer
    n_shared_experts=1,
    rope_theta=5e5,
    norm_eps=1e-5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (scaled); unverified",
)
