"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free, vocab=50280,
ssm_state=128.  SSD (state-space duality) [arXiv:2405.21060].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_n_groups=1,
    norm_eps=1e-5,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)
