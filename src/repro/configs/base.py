"""Architecture + shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; shapes are
:class:`ShapeConfig`.  ``registry()`` exposes them to the CLI
(``--arch <id> --shape <id>``).  Reduced configs for CPU smoke tests come
from :meth:`ArchConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder
    modality: str = "text"       # text | audio | vlm
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention details
    causal: bool = True
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    window: int = 0              # 0 = full attention; >0 = sliding window
    global_every: int = 0        # hymba: every k-th layer is global attn
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    gated_mlp: bool = True       # SwiGLU (3 mats) vs squared-ReLU (2 mats)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0            # expert hidden dim (0 -> d_ff)
    moe_interleave: int = 1      # MoE every k-th layer (llama4: 2)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_n_groups: int = 1

    dtype: str = "bfloat16"
    source: str = ""             # provenance tag from the assignment

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch handle 500k-token context (decode) sanely?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window > 0

    # --- parameter counts (analytical; cross-checked in tests) ---------
    def param_count(self) -> int:
        d, L = self.d_model, self.n_layers
        n = 0
        # embeddings (+ untied head)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.has_attention:
            q = d * self.n_heads * self.hd
            kv = 2 * d * self.n_kv_heads * self.hd
            o = self.n_heads * self.hd * d
            per_layer += q + kv + o
        if self.has_ssm:
            di, g, s = self.d_inner, self.ssm_n_groups, self.ssm_state
            in_proj = d * (2 * di + 2 * g * s + self.ssm_heads)
            conv = (di + 2 * g * s) * self.ssm_conv
            out = di * d
            per_layer += in_proj + conv + out + 2 * self.ssm_heads  # A,D
        n += per_layer * L
        # FFN: dense layers + MoE layers
        if self.is_moe:
            moe_ff = self.moe_d_ff or self.d_ff
            n_moe_layers = L // self.moe_interleave
            n_dense_layers = L - n_moe_layers
            n += n_moe_layers * self.n_experts * 3 * d * moe_ff
            n += n_moe_layers * self.n_shared_experts * 3 * d * moe_ff
            n += n_moe_layers * d * self.n_experts          # router
            n += n_dense_layers * 3 * d * self.d_ff
        elif self.d_ff:
            # SwiGLU (gate, up, down) vs plain 2-matrix FFN (hubert, minitron)
            mult = 3 if (self.gated_mlp and self.family != "encoder") else 2
            n += L * mult * d * self.d_ff
        # norms
        n += L * 2 * d + d
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        moe_ff = self.moe_d_ff or self.d_ff
        n_moe_layers = self.n_layers // self.moe_interleave
        all_experts = n_moe_layers * self.n_experts * 3 * self.d_model * moe_ff
        active = n_moe_layers * self.experts_per_token * 3 * self.d_model * moe_ff
        return full - all_experts + active

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = lambda v, m: min(v, m) if v else v
        mrope = None
        if self.mrope_sections is not None:
            half = 32 // 2          # reduced head_dim is 32
            s = half * 3 // 8
            mrope = (half - 2 * s, s, s)
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 * max(self.moe_interleave, 1)),
            d_model=128,
            n_heads=r(self.n_heads, 4),
            n_kv_heads=r(self.n_kv_heads, 2),
            head_dim=32 if self.n_heads else 0,
            d_ff=r(self.d_ff, 256) if self.d_ff else 0,
            moe_d_ff=r(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=r(self.n_experts, 8),
            experts_per_token=r(self.experts_per_token, 2),
            ssm_state=r(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            window=min(self.window, 64) if self.window else 0,
            mrope_sections=mrope,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Which (arch × shape) cells are runnable (DESIGN.md §5)."""
    if arch.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "pure full-attention arch; 500k KV decode excluded per spec"
    return True, ""


_ARCH_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-2.7b": "mamba2_2p7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-8b": "qwen3_8b",
    "deepseek-7b": "deepseek_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "minitron-8b": "minitron_8b",
    "hymba-1.5b": "hymba_1p5b",
}


def get_arch(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


def all_archs() -> Tuple[str, ...]:
    return tuple(_ARCH_MODULES)


def all_cells() -> Tuple[Tuple[str, str, bool, str], ...]:
    """Every (arch, shape, runnable, reason) cell — 40 total."""
    out = []
    for a in all_archs():
        cfg = get_arch(a)
        for s in SHAPES:
            ok, why = applicable(cfg, SHAPES[s])
            out.append((a, s, ok, why))
    return tuple(out)
