"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE (3-section rotary: temporal/height/width), dynamic resolution
[arXiv:2409.12191].  The vision tower is a stub: ``input_specs()``
provides precomputed patch embeddings plus 3D M-RoPE position ids.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="dense",
    modality="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),   # temporal/height/width halves of hd=128
    norm_eps=1e-6,
    source="arXiv:2409.12191; hf",
)
