from repro.configs.base import (
    SHAPES,
    ArchConfig,
    ShapeConfig,
    all_archs,
    all_cells,
    applicable,
    get_arch,
    get_shape,
)

__all__ = [
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "all_archs",
    "all_cells",
    "applicable",
    "get_arch",
    "get_shape",
]
