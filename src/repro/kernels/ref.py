"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<kernel>_ref`` is the semantic ground truth; kernel tests sweep
shapes/dtypes and ``assert_allclose`` against these.  They are also the
lowering path the dry-run compiles (kernels target TPU; the CPU container
validates them in interpret mode only).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, S, K, D)
    v: jax.Array,              # (B, S, K, D)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qq = q.reshape(B, S, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qq * (D ** -0.5),
                   k.astype(jnp.float32))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= qpos >= kpos
    if window and window > 0:
        m &= (qpos - kpos) < window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,              # (B, H, D)  one token
    k: jax.Array,              # (B, S, K, D) cache
    v: jax.Array,              # (B, S, K, D)
    *,
    cache_len: jax.Array,      # (B,) or scalar
    window: int = 0,
) -> jax.Array:
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qq = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qq * (D ** -0.5),
                   k.astype(jnp.float32))
    kpos = jnp.arange(S)[None, :]
    cl = jnp.asarray(cache_len).reshape(-1, 1)
    m = kpos < cl
    if window and window > 0:
        m &= (cl - 1 - kpos) < window
    s = jnp.where(m[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def decode_append_ref(
    cache: jax.Array,          # (B, S, K, D) session cache
    new: jax.Array,            # (B, 1, K, D) this step's K or V row
    pos: jax.Array,            # (B,) or scalar per-slot append offsets
) -> jax.Array:
    """Per-slot KV-append oracle: ``cache[b, pos[b]] = new[b, 0]``.

    Ground truth for the vmapped ``dynamic_update_slice`` appends in
    ``models.lm.append_kv`` and ``dist.flash_decode`` — a continuous
    batch writes each slot at its *own* offset (mixed prompt lengths).
    """
    B, S = cache.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    hot = jax.nn.one_hot(pos, S, dtype=jnp.float32)[..., None, None]
    out = (cache.astype(jnp.float32) * (1.0 - hot)
           + new.astype(jnp.float32) * hot)
    return out.astype(cache.dtype)


def paged_gather_ref(
    pool: jax.Array,           # (N, bl, K, D) block pool (one layer)
    tbl: jax.Array,            # (B, nb) block ids per slot; -1 = unassigned
) -> jax.Array:
    """Dense view of a paged cache: slot b's row ``i`` is
    ``pool[tbl[b, i // bl], i % bl]``; unassigned blocks read as zeros.

    Ground truth for every paged consumer (XLA gather path, the Pallas
    block-table kernel, and the flash-decode paged combine) — paged
    attention must equal dense attention over this view.
    """
    N, bl = pool.shape[:2]
    safe = jnp.clip(tbl, 0, N - 1)
    g = pool[safe]                                      # (B, nb, bl, K, D)
    g = jnp.where((tbl >= 0)[..., None, None, None], g, 0)
    return g.reshape(tbl.shape[0], tbl.shape[1] * bl, *pool.shape[2:])


def paged_append_ref(
    pool: jax.Array,           # (N, bl, K, D)
    new: jax.Array,            # (B, 1, K, D)
    pos: jax.Array,            # (B,) per-slot append offsets (dense view)
    tbl: jax.Array,            # (B, nb) block table
) -> jax.Array:
    """Paged KV-append oracle: ``pool[tbl[b, pos[b]//bl], pos[b]%bl] =
    new[b, 0]``; a slot whose owning block is unassigned (-1) is a no-op
    (freed slots never write to the pool)."""
    B = new.shape[0]
    N, bl = pool.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    out = pool.astype(jnp.float32)
    for b in range(B):
        blk = tbl[b, pos[b] // bl]
        hot = (jax.nn.one_hot(blk, N, dtype=jnp.float32)[:, None]
               * jax.nn.one_hot(pos[b] % bl, bl,
                                dtype=jnp.float32)[None, :])[..., None, None]
        out = out * (1.0 - hot) + new[b, 0].astype(jnp.float32) * hot
    return out.astype(pool.dtype)


def paged_decode_attention_ref(
    q: jax.Array,              # (B, H, D) one token
    k_pool: jax.Array,         # (N, bl, K, D)
    v_pool: jax.Array,         # (N, bl, K, D)
    tbl: jax.Array,            # (B, nb)
    *,
    cache_len: jax.Array,      # (B,) or scalar
    window: int = 0,
) -> jax.Array:
    """Decode attention over the paged cache == dense attention over the
    gathered view (positions past ``cache_len`` are masked either way)."""
    return decode_attention_ref(
        q, paged_gather_ref(k_pool, tbl), paged_gather_ref(v_pool, tbl),
        cache_len=cache_len, window=window)


def ssd_scan_ref(
    x: jax.Array,              # (B, S, H, P) fp32
    dt: jax.Array,             # (B, S, H) fp32 (post-softplus)
    A: jax.Array,              # (H,) fp32 negative
    Bm: jax.Array,             # (B, S, H, N) fp32 (groups pre-broadcast)
    Cm: jax.Array,             # (B, S, H, N)
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Sequential recurrence oracle: S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    s0 = jnp.zeros((B_, H, P, N), jnp.float32) if initial_state is None \
        else initial_state

    def step(s, t):
        dec = jnp.exp(dt[:, t] * A)[..., None, None]
        s = s * dec + jnp.einsum("bh,bhn,bhp->bhpn", dt[:, t], Bm[:, t],
                                 x[:, t])
        y = jnp.einsum("bhpn,bhn->bhp", s, Cm[:, t])
        return s, y

    s, ys = jax.lax.scan(step, s0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), s


def tiled_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(
        a.dtype)
