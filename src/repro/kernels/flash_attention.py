"""Flash attention (forward) Pallas TPU kernel.

PLM mapping (paper §3 → DESIGN.md §2): the q/k/v/o tiles are the
multi-bank PLM; block sizes come from the local-partitioning pass
(``plan.partitions['flash_attention']``), chosen so the double-buffered
working set fits the VMEM budget and tile dims are MXU multiples.

Grid: (batch·kv_head, q_blocks, kv_blocks) — kv innermost so the online
softmax carry (m, l, acc) lives in VMEM scratch across kv steps.
GQA is handled by loading q as (G·block_q, D) per kv head.

Causal grid pruning: with ``causal=True`` the kv blocks strictly above
the diagonal are fully masked, so computing-then-masking them wastes
~half the grid at long S.  Pallas TPU grids are rectangular, so the
pruned path packs the lower triangle by *pairing* q rows: row ``i`` (has
``i+1`` valid kv blocks) shares a grid row with row ``n-1-i`` (has
``n-i``), giving a rectangle of ``ceil(n/2) x (n+1)`` steps instead of
``n^2`` — a ``(n+1)/2n -> 1/2`` step ratio, with bit-identical output
(the skipped blocks contribute exactly-zero terms to the online
softmax).  The packing needs square tiles, so it engages only when
``block_q == block_kv`` — the partitioning pass emits square tiles for
causal workloads; rectangular tile choices keep the full grid.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def kv_grid_steps(seq_len: int, block_q: int, block_kv: int, *,
                  causal: bool = True, prune: bool = True) -> int:
    """(q, kv) grid steps per (batch x kv_head) the kernel launches.

    The pruning acceptance math: for the packed causal grid (square
    tiles only) the ratio to the unpruned ``n^2`` grid is ``(n+1)/2n``
    (→ 1/2 for large ``n``).
    """
    if causal and prune and block_q == block_kv:
        n = seq_len // block_q
        return ((n + 1) // 2) * (n + 1)
    return (seq_len // block_q) * (seq_len // block_kv)


def _mask_scores(s, q_idx, kv_idx, block_q, block_kv, G, causal, window):
    qpos = q_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, G), 0).reshape(block_q * G)
    kpos = kv_idx * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)[0]
    mask = jnp.ones((block_q * G, block_kv), dtype=jnp.bool_)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    return jnp.where(mask, s, NEG_INF)


def _flash_kernel(
    # refs sliced by BlockSpec:
    q_ref,        # (1, block_q, G, D)
    k_ref,        # (1, block_kv, D)
    v_ref,        # (1, block_kv, D)
    o_ref,        # (1, block_q, G, D)
    m_scr, l_scr, acc_scr,      # VMEM scratch: (block_q*G,), (block_q*G,), (block_q*G, D)
    *,
    causal: bool,
    window: int,
    block_q: int,
    block_kv: int,
    scale: float,
):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)
    G = q_ref.shape[2]
    D = q_ref.shape[3]

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].reshape(block_q * G, D).astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)                      # (block_kv, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = _mask_scores(s, q_idx, kv_idx, block_q, block_kv, G, causal, window)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).reshape(
            block_q, G, D).astype(o_ref.dtype)


# ---------------------------------------------------------------------
# packed-causal grid: q rows i and n-1-i share one grid row
# ---------------------------------------------------------------------

def _packed_coords(r, c, n):
    """Grid (r, c) -> (q block i, kv block j, segment flags).

    Row pair ``r``: columns ``[0, r]`` walk q row ``r`` (kv j = c);
    columns ``[r+1, n]`` walk q row ``n-1-r`` (kv j = c - r - 1).  For
    odd ``n`` the middle row pairs with itself — its second segment is
    dead and must be skipped (``valid`` False).
    """
    seg2 = c > r
    i = jnp.where(seg2, n - 1 - r, r)
    j = jnp.where(seg2, c - r - 1, c)
    valid = jnp.logical_or(jnp.logical_not(seg2), (n - 1 - r) != r)
    seg_start = jnp.logical_or(c == 0, c == r + 1)
    seg_end = jnp.where(seg2, c == n, c == r)
    return i, j, valid, seg_start, seg_end


def _flash_kernel_packed(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *,
    window: int,
    block: int,
    n: int,
    scale: float,
):
    r = pl.program_id(1)
    c = pl.program_id(2)
    i, j, valid, seg_start, seg_end = _packed_coords(r, c, n)
    G = q_ref.shape[2]
    D = q_ref.shape[3]

    @pl.when(valid & seg_start)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(valid)
    def _compute():
        q = q_ref[0].reshape(block * G, D).astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = _mask_scores(s, i, j, block, block, G, True, window)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(valid & seg_end)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).reshape(
            block, G, D).astype(o_ref.dtype)


def _flash_causal_packed(qg, kg, vg, *, window, block, S, G, D, scale,
                         interpret):
    BK = qg.shape[0]
    n = S // block
    rows = (n + 1) // 2
    grid = (BK, rows, n + 1)

    def q_index(b, r, c):
        i, _, _, _, _ = _packed_coords(r, c, n)
        return (b, i, 0, 0)

    def kv_index(b, r, c):
        _, j, _, _, _ = _packed_coords(r, c, n)
        return (b, j, 0)

    return pl.pallas_call(
        functools.partial(
            _flash_kernel_packed, window=window, block=block, n=n,
            scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block, G, D), q_index),
            pl.BlockSpec((1, block, D), kv_index),
            pl.BlockSpec((1, block, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block, G, D), q_index),
        out_shape=jax.ShapeDtypeStruct((BK, S, G, D), qg.dtype),
        scratch_shapes=[
            pltpu.VMEM((block * G,), jnp.float32),
            pltpu.VMEM((block * G,), jnp.float32),
            pltpu.VMEM((block * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret",
                     "prune"))
def flash_attention(
    q: jax.Array,              # (B, S, H, D)
    k: jax.Array,              # (B, S, K, D)
    v: jax.Array,              # (B, S, K, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 1024,
    interpret: bool = False,
    prune: bool = True,
) -> jax.Array:
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = D ** -0.5

    # layout: fold heads into the grid; q as (B*K, S, G, D)
    qg = q.reshape(B, S, K, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B * K, S, G, D)
    kg = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vg = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)

    if causal and prune and block_q == block_kv:
        out = _flash_causal_packed(
            qg, kg, vg, window=window, block=block_q, S=S, G=G, D=D,
            scale=scale, interpret=interpret)
    else:
        grid = (B * K, S // block_q, S // block_kv)
        out = pl.pallas_call(
            functools.partial(
                _flash_kernel, causal=causal, window=window,
                block_q=block_q, block_kv=block_kv, scale=scale),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_q, G, D), lambda b, i, j: (b, i, 0, 0)),
                pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
                pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_q, G, D),
                                   lambda b, i, j: (b, i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((B * K, S, G, D), q.dtype),
            scratch_shapes=[
                pltpu.VMEM((block_q * G,), jnp.float32),
                pltpu.VMEM((block_q * G,), jnp.float32),
                pltpu.VMEM((block_q * G, D), jnp.float32),
            ],
            interpret=interpret,
        )(qg, kg, vg)
    return out.reshape(B, K, S, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, D)
