"""Plan-driven tiled matmul Pallas TPU kernel.

The direct embodiment of the local-partitioning pass: (bm, bk, bn) come
from ``plan.partitions['tiled_matmul']`` — the multi-bank PLM config —
and the kernel just uses them.  fp32 accumulator tile in VMEM; K is the
innermost (sequential) grid dim so the accumulator is reused across K
steps (the paper's "sharing physical memories": one accumulator bank
serves all K banks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_scr):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def tiled_matmul(
    a: jax.Array,              # (M, K)
    b: jax.Array,              # (K, N)
    *,
    bm: int = 512,
    bk: int = 512,
    bn: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)

    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
