"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU re-think of the SSD algorithm (DESIGN.md §2): the GPU version keys on
warp-level scans; on TPU the winning decomposition is

  * intra-chunk terms  -> MXU batched matmuls over a (chunk x chunk) tile,
  * inter-chunk terms  -> a VMEM-resident (P x N) running state carried
                          across sequential grid steps (the PLM),

with the chunk length + head blocking chosen by the local-partitioning
pass (``plan.partitions['ssd_scan']``).

Grid: (batch*heads, seq/chunk) — chunk dim sequential (state carry).
Inputs are fp32 (the SSD recurrence is exp-sensitive).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,        # (1, chunk, P)
    dt_ref,       # (1, chunk)
    a_ref,        # (1, 1)      A for this head
    b_ref,        # (1, chunk, N)
    c_ref,        # (1, chunk, N)
    y_ref,        # (1, chunk, P)
    state_scr,    # VMEM (P, N) running state
    *,
    chunk: int,
):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0]                        # (Q, P)
    dt = dt_ref[0]                      # (Q,)
    A = a_ref[0, 0]                     # scalar (negative)
    Bm = b_ref[0]                       # (Q, N)
    Cm = c_ref[0]                       # (Q, N)

    dA = dt * A                         # (Q,)
    dA_cs = jnp.cumsum(dA)              # (Q,)

    # 1. intra-chunk: L[q,k] = exp(cs[q]-cs[k]) for k<=q
    diff = dA_cs[:, None] - dA_cs[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(qi >= ki, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(scores * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # 2. contribution of the carried-in state
    state = state_scr[...]              # (P, N)
    decay_in = jnp.exp(dA_cs)[:, None]  # (Q, 1)
    y += decay_in * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # 3. update the state for the next chunk
    chunk_decay = jnp.exp(dA_cs[-1])
    decay_out = jnp.exp(dA_cs[-1] - dA_cs)[:, None]      # (Q, 1)
    new_contrib = jax.lax.dot_general(
        xdt * decay_out, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (P, N)
    state_scr[...] = state * chunk_decay + new_contrib

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,              # (B, S, H, P) fp32
    dt: jax.Array,             # (B, S, H) fp32 (post-softplus)
    A: jax.Array,              # (H,) fp32 negative
    Bm: jax.Array,             # (B, S, H, N) fp32 (groups pre-broadcast)
    Cm: jax.Array,             # (B, S, H, N) fp32
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)

    xg = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtg = dt.transpose(0, 2, 1).reshape(B * H, S)
    ag = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H, 1)
    bg = Bm.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cg = Cm.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    grid = (B * H, S // chunk)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xg, dtg, ag, bg, cg)
    return out.reshape(B, H, S, P).transpose(0, 2, 1, 3)
