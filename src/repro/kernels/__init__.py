from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.tiled_matmul import tiled_matmul

__all__ = ["ops", "ref", "decode_attention", "flash_attention", "ssd_scan",
           "tiled_matmul"]
