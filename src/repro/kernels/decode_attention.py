"""GQA flash-decode Pallas TPU kernel: one query token vs the session cache.

The session cache streams through VMEM in ``block_kv``-row banks (the
``cache.kv`` template component configured by the local-partitioning
pass); the online-softmax carry stays in VMEM scratch.  Decode is
memory-bound — the kernel's job is to stream the cache exactly once at
full HBM bandwidth with no score materialization.

Grid: (batch, kv_head, cache_blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,      # SMEM (1,) int32: valid cache length
    q_ref,        # (1, 1, G, D)
    k_ref,        # (1, block_kv, 1, D)
    v_ref,        # (1, block_kv, 1, D)
    o_ref,        # (1, 1, G, D)
    m_scr, l_scr, acc_scr,
    *,
    block_kv: int,
    window: int,
    scale: float,
):
    j = pl.program_id(2)
    G, D = q_ref.shape[2], q_ref.shape[3]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (block_kv, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bkv)
    kpos = j * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)[0]
    mask = kpos < cache_len
    if window > 0:
        mask &= (cache_len - 1 - kpos) < window
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _paged_decode_kernel(
    tbl_ref,      # SMEM (B, nb) int32 block table (scalar prefetch)
    len_ref,      # SMEM (B,) int32 per-slot valid lengths (scalar prefetch)
    q_ref,        # (1, 1, G, D)
    k_ref,        # (1, block_len, 1, D) — the slot's j-th block
    v_ref,        # (1, block_len, 1, D)
    o_ref,        # (1, 1, G, D)
    m_scr, l_scr, acc_scr,
    *,
    block_len: int,
    n_kv: int,
    window: int,
    scale: float,
):
    b = pl.program_id(0) // n_kv       # grid dim 0 is batch*kv_head
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    cache_len = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (block_len, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # dense-view positions: block j covers rows [j*bl, (j+1)*bl); an
    # unassigned (-1) table entry was clamped to block 0 by the index
    # map, but its whole range sits past cache_len, so the mask kills it
    kpos = j * block_len + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_len), 1)[0]
    mask = kpos < cache_len
    if window > 0:
        mask &= (cache_len - 1 - kpos) < window
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(
    q: jax.Array,              # (B, H, D)
    k_pool: jax.Array,         # (N, block_len, K, D) block pool
    v_pool: jax.Array,         # (N, block_len, K, D)
    block_tbl: jax.Array,      # (B, nb) int32 block ids (-1 = unassigned)
    *,
    cache_len: jax.Array,      # (B,) or scalar int32 valid lengths
    window: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode over a paged cache: the block table streams the
    slot's blocks through VMEM via scalar-prefetch indexed DMA.

    The grid walks (batch*kv_head, 1, table_cols); each step's k/v
    BlockSpec index map reads ``block_tbl[b, j]`` (prefetched to SMEM
    before the kernel runs) to pick the pool block to DMA — the gather
    never materializes a dense per-slot cache in HBM.  Semantics match
    :func:`repro.kernels.ref.paged_decode_attention_ref`.
    """
    B, H, D = q.shape
    N, block_len, K = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    nb = block_tbl.shape[1]
    G = H // K
    scale = D ** -0.5

    qg = q.reshape(B, 1, K, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B * K, 1, G, D)
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
    tbl = jnp.asarray(block_tbl, jnp.int32)

    def kv_index(bk, i, j, tbl_ref, len_ref):
        # the pool is shared: the table row picks the block for this
        # slot (bk // K), the grid step's kv head indexes dim 2 directly
        return (jnp.maximum(tbl_ref[bk // K, j], 0), 0, bk % K, 0)

    grid = (B * K, 1, nb)
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, block_len=block_len,
                          n_kv=K, window=window, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, i, j, *_: (b, 0, 0, 0)),
                pl.BlockSpec((1, block_len, 1, D), kv_index),
                pl.BlockSpec((1, block_len, 1, D), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, i, j, *_: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B * K, 1, G, D), q.dtype),
        interpret=interpret,
    )(tbl, clen, qg, k_pool, v_pool)
    return out.reshape(B, K, G, D).reshape(B, H, D)


@functools.partial(
    jax.jit, static_argnames=("window", "block_kv", "interpret"))
def decode_attention(
    q: jax.Array,              # (B, H, D)
    k: jax.Array,              # (B, S, K, D)
    v: jax.Array,              # (B, S, K, D)
    *,
    cache_len: jax.Array,      # scalar int32 (shared valid length)
    window: int = 0,
    block_kv: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, (S, block_kv)
    scale = D ** -0.5

    qg = q.reshape(B, 1, K, G, D).transpose(0, 2, 1, 3, 4) \
        .reshape(B * K, 1, G, D)
    kg = k.transpose(0, 2, 1, 3).reshape(B * K, S, 1, D)
    vg = v.transpose(0, 2, 1, 3).reshape(B * K, S, 1, D)
    clen = jnp.asarray(cache_len, jnp.int32).reshape(1)

    grid = (B * K, 1, S // block_kv)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_kv=block_kv, window=window,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, i, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, i, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, i, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, i, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, 1, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(clen, qg, kg, vg)
    return out.reshape(B, K, G, D).reshape(B, H, D)
