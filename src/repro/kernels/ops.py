"""Jit'd kernel wrappers: the single entry point model code / serving use.

Each op dispatches between the Pallas kernel (TPU, or interpret mode for
CPU validation) and the pure-jnp oracle in :mod:`repro.kernels.ref`,
driven by the plan's ``use_pallas`` ("auto" = kernel iff a TPU backend is
present) and configured by the plan's BlockPlans — kernel code never
picks its own tiles (paper §4: the template is parameterized by the
compiler, the datapath just runs).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.plan import BlockPlan, MemoryPlan
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas
from repro.kernels.tiled_matmul import tiled_matmul as _mm_pallas


def _use_pallas(mode: str = "auto") -> bool:
    if mode == "on":
        return True
    if mode == "off":
        return False
    return jax.default_backend() == "tpu"


def _blocks(plan: Optional[MemoryPlan], kernel: str) -> Optional[BlockPlan]:
    if plan is None:
        return None
    return plan.partitions.get(kernel)


def flash_attention(q, k, v, *, causal=True, window=0,
                    plan: Optional[MemoryPlan] = None, mode="auto",
                    interpret=False):
    bp = _blocks(plan, "flash_attention")
    if _use_pallas(mode) or interpret:
        return _flash_pallas(
            q, k, v, causal=causal, window=window,
            block_q=bp.blocks["block_q"] if bp else 512,
            block_kv=bp.blocks["block_kv"] if bp else 1024,
            interpret=interpret or jax.default_backend() != "tpu")
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def decode_attention(q, k, v, *, cache_len, window=0,
                     plan: Optional[MemoryPlan] = None, mode="auto",
                     interpret=False):
    bp = _blocks(plan, "decode_attention")
    if _use_pallas(mode) or interpret:
        return _decode_pallas(
            q, k, v, cache_len=cache_len, window=window,
            block_kv=bp.blocks["block_kv"] if bp else 2048,
            interpret=interpret or jax.default_backend() != "tpu")
    return ref.decode_attention_ref(q, k, v, cache_len=cache_len,
                                    window=window)


def ssd_scan(x, dt, A, Bm, Cm, *, plan: Optional[MemoryPlan] = None,
             mode="auto", interpret=False):
    bp = _blocks(plan, "ssd_scan")
    if _use_pallas(mode) or interpret:
        y = _ssd_pallas(
            x, dt, A, Bm, Cm,
            chunk=bp.blocks["chunk"] if bp else 256,
            interpret=interpret or jax.default_backend() != "tpu")
        return y
    y, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    return y


def matmul(a, b, *, plan: Optional[MemoryPlan] = None, mode="auto",
           interpret=False):
    bp = _blocks(plan, "tiled_matmul")
    if _use_pallas(mode) or interpret:
        return _mm_pallas(
            a, b,
            bm=bp.blocks["bm"] if bp else 512,
            bk=bp.blocks["bk"] if bp else 512,
            bn=bp.blocks["bn"] if bp else 512,
            interpret=interpret or jax.default_backend() != "tpu")
    return ref.tiled_matmul_ref(a, b)
