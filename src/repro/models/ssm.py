"""Mamba2 (SSD — state-space duality) layer, chunked, pure JAX.

Follows the minimal SSD formulation of arXiv:2405.21060 §6: the sequence
is processed in chunks; intra-chunk terms are batched matmuls (MXU food),
inter-chunk terms are a short recurrence over chunk states carried by
``lax.scan``.  The chunk length comes from the local-partitioning pass
(``plan.partitions['ssd_scan']``) — the same tile that configures the
Pallas kernel in :mod:`repro.kernels.ssd_scan`.

Decode is the O(1) recurrent update: ``S ← exp(dt·A)·S + dt·B⊗x``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm


class SSMParams(NamedTuple):
    in_proj: jax.Array       # (d, 2*di + 2*g*n + h)  -> z, xBC, dt
    conv_w: jax.Array        # (k, di + 2*g*n) depthwise causal conv
    conv_b: jax.Array        # (di + 2*g*n,)
    A_log: jax.Array         # (h,) fp32: A = -exp(A_log)
    D: jax.Array             # (h,) fp32 skip
    dt_bias: jax.Array       # (h,) fp32
    norm: jax.Array          # (di,) gated RMSNorm scale
    out_proj: jax.Array      # (di, d)


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    state: int
    n_groups: int
    conv_k: int


def segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,            # (B, S, H, P) fp32
    dt: jax.Array,           # (B, S, H) fp32 (post-softplus)
    A: jax.Array,            # (H,) fp32 negative
    Bm: jax.Array,           # (B, S, G, N) fp32
    Cm: jax.Array,           # (B, S, G, N) fp32
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B_, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt=0 padding is exact: decay=exp(0)=1, zero input contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_pad = S + pad
    nc = S_pad // chunk

    # chunked views
    xc = x.reshape(B_, nc, chunk, H, Pd)
    dtc = dt.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, G, N)
    Cc = Cm.reshape(B_, nc, chunk, G, N)
    # broadcast groups over heads: index map h -> g
    Bh = jnp.repeat(Bc, rep, axis=3)         # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A                              # (B,nc,Q,H)
    dA = jnp.moveaxis(dA, -1, 2)              # (B,nc,H,Q)
    dA_cs = jnp.cumsum(dA, axis=-1)           # (B,nc,H,Q)

    # 1. intra-chunk (quadratic in chunk -> MXU)
    L = jnp.exp(segsum(dA))                   # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]                 # (B,nc,Q,H,P)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)        # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores * L, xdt)

    # 2. per-chunk end states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)          # (B,nc,H,Q)
    states = jnp.einsum("bckhn,bchk,bckhp->bchpn", Bh, decay_states, xdt)

    # 3. inter-chunk recurrence (the only sequential part)
    chunk_decay = jnp.exp(dA_cs[..., -1])     # (B,nc,H)
    s0 = (jnp.zeros((B_, H, Pd, N), x.dtype) if initial_state is None
          else initial_state)

    def step(carry, inp):
        st, dec = inp                          # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev                       # emit the *incoming* state

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    # 4. contribution of the carried-in state to each position
    state_decay = jnp.exp(dA_cs)               # (B,nc,H,Q)
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(B_, S_pad, H, Pd)
    return y[:, :S], final


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (k, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled taps: k is tiny (4)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return y + b


def ssm_forward(
    x: jax.Array,            # (B, S, d) residual stream, bf16
    p: SSMParams,
    dims: SSMDims,
    chunk: int = 256,
) -> jax.Array:
    """Full-sequence (train/prefill) mamba2 mixer."""
    B, S, d = x.shape
    di, H, Pd, N, G = (dims.d_inner, dims.n_heads, dims.head_dim,
                       dims.state, dims.n_groups)
    zxbcdt = x @ p.in_proj                                   # (B,S,2di+2gn+h)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xbc = causal_conv(xbc, p.conv_w, p.conv_b)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, H, Pd)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)  # (B,S,H)
    A = -jnp.exp(p.A_log)
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=chunk)
    y = y + xs * p.D[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p.norm)
    return (y @ p.out_proj).astype(x.dtype)


def ssm_decode_step(
    x: jax.Array,            # (B, 1, d)
    p: SSMParams,
    dims: SSMDims,
    ssm_state: jax.Array,    # (B, H, P, N) fp32
    conv_state: jax.Array,   # (B, k, di + 2*g*n)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) recurrent decode. Returns (y, ssm_state', conv_state')."""
    B, _, d = x.shape
    di, H, Pd, N, G = (dims.d_inner, dims.n_heads, dims.head_dim,
                       dims.state, dims.n_groups)
    zxbcdt = (x[:, 0] @ p.in_proj)                            # (B, ...)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    # roll the conv window
    conv_state = jnp.concatenate([conv_state[:, 1:], xbc[:, None]], axis=1)
    xbc = jnp.einsum("bkc,kc->bc", conv_state, p.conv_w) + p.conv_b
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, H, Pd)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)      # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)  # (B,H)
    A = -jnp.exp(p.A_log)
    decay = jnp.exp(dt * A)[..., None, None]                  # (B,H,1,1)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm, xs)
    ssm_state = ssm_state * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, Cm)
    y = y + xs * p.D[None, :, None]
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p.norm)
    return (y @ p.out_proj).astype(x.dtype)[:, None], ssm_state, conv_state
