"""Model assembly: all ten architectures behind one functional interface.

Structure
---------
Parameters are nested dicts of arrays, *stacked over scan groups*:
``lax.scan`` over layers keeps the HLO size O(1) in depth (an 80-layer
72B model lowers in seconds).  Architectures with interleaved layer kinds
(llama4: dense/MoE alternation) scan over super-blocks of
``moe_interleave`` layers; hymba passes per-layer window sizes as scan
inputs so global/sliding layers share one body.

Entry points (all pure):
  * ``init_params(arch, key, ...)``
  * ``param_specs(arch, ...)``        — ShapeDtypeStructs + logical axes
  * ``train_loss(arch, params, batch, cfg)``
  * ``prefill(arch, params, batch, cfg)``  -> (logits_last, cache)
  * ``decode_step(arch, params, cache, batch, cfg)`` -> (logits, cache)

The model is *mostly unaware* of the memory plan (paper §4): it consumes
only a tiny ``RunCfg`` of lowering-relevant knobs that the plan's
lowering pass fills in (block sizes, remat policy, moe path, padded
vocab).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.describe import global_layer_mask
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnParams
from repro.models.common import (
    cross_entropy_loss,
    rms_norm,
    sinusoidal_positions,
    truncated_normal_init,
)
from repro.models.moe import MoEParams
from repro.models.ssm import SSMDims, SSMParams


@dataclasses.dataclass(frozen=True)
class RunCfg:
    """Plan-derived lowering knobs (filled by core.passes.lowering)."""

    vocab_padded: int = 0          # 0 -> arch.vocab_size
    heads_padded: int = 0          # 0 -> arch.n_heads (layout pass pads to TP)
    kv_heads_padded: int = 0       # 0 -> arch.n_kv_heads
    ssm_heads_padded: int = 0      # 0 -> arch.ssm_heads
    kv_heads_sharded: bool = True  # False -> constrain k/v replicated on TP
    shard_heads: bool = True       # False (fsdp_dp): no head constraints
    block_q: int = 512             # attention query tile
    ssd_chunk: int = 256           # SSD chunk length
    remat: str = "none"            # none | dots | full
    moe_impl: str = "gshard_einsum"  # or shard_map_alltoall | dense_einsum
    decode_impl: str = "xla"       # or shard_map_flash (seq-sharded cache)
    combine_topology: Optional[str] = None  # flat|ring|bidir; None -> predicate
    mesh: Optional[jax.sharding.Mesh] = None   # needed by shard_map path
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    batch_spec: Any = None         # resolved batch-dim mesh assignment
    aux_loss_weight: float = 0.01


_U = jax.sharding.PartitionSpec.UNCONSTRAINED


def _hint(x, cfg: "RunCfg", *spec):
    """with_sharding_constraint helper.

    spec entries: mesh-axis name (shard), "rep" (force replicated), or
    None (leave unconstrained).  No-op without a mesh (smoke tests).
    """
    if cfg.mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    full = tuple(None if s == "rep" else (_U if s is None else s)
                 for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(cfg.mesh, P(*full)))


# =====================================================================
# Parameter specs
# =====================================================================

class LeafSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: str
    axes: Tuple[Optional[str], ...]
    scale: float = 0.02


def _attn_specs(arch: ArchConfig, Lg: int, d: int,
                heads_padded: int = 0,
                kv_heads_padded: int = 0) -> Dict[str, LeafSpec]:
    hd = arch.hd
    H = heads_padded or arch.n_heads
    K = kv_heads_padded or arch.n_kv_heads
    specs = {
        "wq": LeafSpec((Lg, d, H * hd), "bfloat16",
                       ("layers", "embed", "heads")),
        "wk": LeafSpec((Lg, d, K * hd), "bfloat16",
                       ("layers", "embed", "kv_heads")),
        "wv": LeafSpec((Lg, d, K * hd), "bfloat16",
                       ("layers", "embed", "kv_heads")),
        "wo": LeafSpec((Lg, H * hd, d), "bfloat16",
                       ("layers", "heads", "embed"),
                       scale=0.02 / math.sqrt(2 * arch.n_layers)),
    }
    if arch.qk_norm:
        specs["q_norm"] = LeafSpec((Lg, hd), "float32", ("layers", None), 0.0)
        specs["k_norm"] = LeafSpec((Lg, hd), "float32", ("layers", None), 0.0)
    return specs


def _mlp_specs(arch: ArchConfig, Lg: int, d: int, ff: int) -> Dict[str, LeafSpec]:
    gated = arch.gated_mlp and arch.family != "encoder"
    n_in = 2 if gated else 1
    return {
        "wi": LeafSpec((Lg, d, n_in * ff), "bfloat16", ("layers", "embed", "ff")),
        "wo": LeafSpec((Lg, ff, d), "bfloat16", ("layers", "ff", "embed"),
                       scale=0.02 / math.sqrt(2 * arch.n_layers)),
    }


def _moe_specs(arch: ArchConfig, Lg: int, d: int) -> Dict[str, LeafSpec]:
    ff = arch.moe_d_ff or arch.d_ff
    E = arch.n_experts
    specs = {
        "router": LeafSpec((Lg, d, E), "float32", ("layers", "embed", None)),
        "wi": LeafSpec((Lg, E, d, 2 * ff), "bfloat16",
                       ("layers", "experts", "embed", "ff")),
        "wo": LeafSpec((Lg, E, ff, d), "bfloat16",
                       ("layers", "experts", "ff", "embed"),
                       scale=0.02 / math.sqrt(2 * arch.n_layers)),
    }
    if arch.n_shared_experts:
        sf = ff * arch.n_shared_experts
        specs["shared_wi"] = LeafSpec((Lg, d, 2 * sf), "bfloat16",
                                      ("layers", "embed", "ff"))
        specs["shared_wo"] = LeafSpec((Lg, sf, d), "bfloat16",
                                      ("layers", "ff", "embed"),
                                      scale=0.02 / math.sqrt(2 * arch.n_layers))
    return specs


def _ssm_specs(arch: ArchConfig, Lg: int, d: int,
               ssm_heads_padded: int = 0) -> Dict[str, LeafSpec]:
    H = ssm_heads_padded or arch.ssm_heads
    di = H * arch.ssm_head_dim
    G, N = arch.ssm_n_groups, arch.ssm_state
    cdim = di + 2 * G * N
    return {
        "in_proj": LeafSpec((Lg, d, 2 * di + 2 * G * N + H), "bfloat16",
                            ("layers", "embed", "ssm_inner")),
        "conv_w": LeafSpec((Lg, arch.ssm_conv, cdim), "bfloat16",
                           ("layers", None, "ssm_inner")),
        "conv_b": LeafSpec((Lg, cdim), "bfloat16", ("layers", "ssm_inner"), 0.0),
        "A_log": LeafSpec((Lg, H), "float32", ("layers", "ssm_heads"), 0.0),
        "D": LeafSpec((Lg, H), "float32", ("layers", "ssm_heads"), 0.0),
        "dt_bias": LeafSpec((Lg, H), "float32", ("layers", "ssm_heads"), 0.0),
        "norm": LeafSpec((Lg, di), "float32", ("layers", "ssm_inner"), 0.0),
        "out_proj": LeafSpec((Lg, di, d), "bfloat16",
                             ("layers", "ssm_inner", "embed"),
                             scale=0.02 / math.sqrt(2 * arch.n_layers)),
    }


def leaf_specs(arch: ArchConfig, vocab_padded: int = 0,
               heads_padded: int = 0,
               ssm_heads_padded: int = 0,
               kv_heads_padded: int = 0) -> Dict[str, Any]:
    """The full parameter-spec pytree for an architecture."""
    d = arch.d_model
    V = vocab_padded or arch.vocab_size
    L = arch.n_layers
    g = arch.moe_interleave if arch.is_moe and arch.moe_interleave > 1 else 1
    Lg = L // g

    specs: Dict[str, Any] = {
        "embed": LeafSpec((V, d), "bfloat16", ("vocab", "embed"), 0.02),
        "final_norm": LeafSpec((d,), "float32", ("embed",), 0.0),
    }
    if not arch.tie_embeddings:
        specs["lm_head"] = LeafSpec((d, V), "bfloat16", ("embed", "vocab"))

    blocks: Dict[str, Any] = {}

    def mixer_specs(Lh: int) -> Dict[str, Any]:
        out: Dict[str, Any] = {"pre_norm": LeafSpec((Lh, d), "float32",
                                                    ("layers", "embed"), 0.0)}
        if arch.has_attention:
            out["attn"] = _attn_specs(arch, Lh, d, heads_padded,
                                      kv_heads_padded)
        if arch.has_ssm:
            out["ssm"] = _ssm_specs(arch, Lh, d, ssm_heads_padded)
        return out

    if arch.family == "ssm":
        blocks.update(mixer_specs(Lg))
    elif arch.is_moe and g > 1:
        # llama4: [dense, moe] super-block
        blocks["dense"] = {**mixer_specs(Lg),
                           "mlp_norm": LeafSpec((Lg, d), "float32",
                                                ("layers", "embed"), 0.0),
                           "mlp": _mlp_specs(arch, Lg, d, arch.d_ff)}
        blocks["moe"] = {**mixer_specs(Lg),
                         "mlp_norm": LeafSpec((Lg, d), "float32",
                                              ("layers", "embed"), 0.0),
                         "moe": _moe_specs(arch, Lg, d)}
    elif arch.is_moe:
        blocks.update(mixer_specs(Lg))
        blocks["mlp_norm"] = LeafSpec((Lg, d), "float32", ("layers", "embed"), 0.0)
        blocks["moe"] = _moe_specs(arch, Lg, d)
    else:
        blocks.update(mixer_specs(Lg))
        blocks["mlp_norm"] = LeafSpec((Lg, d), "float32", ("layers", "embed"), 0.0)
        blocks["mlp"] = _mlp_specs(arch, Lg, d, arch.d_ff)

    specs["blocks"] = blocks
    return specs


def param_shapes(arch: ArchConfig, vocab_padded: int = 0,
                 heads_padded: int = 0, ssm_heads_padded: int = 0,
                 kv_heads_padded: int = 0):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        leaf_specs(arch, vocab_padded, heads_padded, ssm_heads_padded,
                   kv_heads_padded),
        is_leaf=lambda x: isinstance(x, LeafSpec))


def param_axes(arch: ArchConfig, vocab_padded: int = 0,
               heads_padded: int = 0, ssm_heads_padded: int = 0,
               kv_heads_padded: int = 0):
    return jax.tree.map(
        lambda s: s.axes,
        leaf_specs(arch, vocab_padded, heads_padded, ssm_heads_padded,
                   kv_heads_padded),
        is_leaf=lambda x: isinstance(x, LeafSpec))


def init_params(arch: ArchConfig, key: jax.Array, vocab_padded: int = 0,
                heads_padded: int = 0, ssm_heads_padded: int = 0,
                kv_heads_padded: int = 0):
    specs = leaf_specs(arch, vocab_padded, heads_padded, ssm_heads_padded,
                       kv_heads_padded)
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, LeafSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.scale == 0.0:
            out.append(jnp.zeros(s.shape, jnp.dtype(s.dtype)))
        else:
            out.append(truncated_normal_init(k, s.shape, jnp.dtype(s.dtype),
                                             s.scale))
    params = jax.tree.unflatten(treedef, out)
    # dead (layout-pass padded) q/kv heads: zero padded wq/wk/wv cols,
    # wo rows — they contribute nothing at init
    if arch.has_attention and heads_padded and heads_padded != arch.n_heads:
        cut = arch.n_heads * arch.hd
        for grp in _mixer_groups(params):
            if "attn" in grp:
                grp["attn"]["wq"] = grp["attn"]["wq"].at[..., cut:].set(0)
                grp["attn"]["wo"] = grp["attn"]["wo"].at[:, cut:, :].set(0)
    if arch.has_attention and kv_heads_padded and             kv_heads_padded != arch.n_kv_heads:
        cut = arch.n_kv_heads * arch.hd
        for grp in _mixer_groups(params):
            if "attn" in grp:
                grp["attn"]["wk"] = grp["attn"]["wk"].at[..., cut:].set(0)
                grp["attn"]["wv"] = grp["attn"]["wv"].at[..., cut:].set(0)
    # SSM: A_log ~ log(uniform[1,16]), dt_bias ~ inv_softplus(uniform)
    def fix_ssm(p):
        if arch.has_ssm:
            for grp in _mixer_groups(p):
                if "ssm" in grp:
                    Lh, H = grp["ssm"]["A_log"].shape
                    a = jnp.log(jnp.linspace(1.0, 16.0, H))[None, :]
                    grp["ssm"]["A_log"] = jnp.broadcast_to(a, (Lh, H)).astype(
                        jnp.float32)
                    grp["ssm"]["D"] = jnp.ones((Lh, H), jnp.float32)
                    grp["ssm"]["dt_bias"] = jnp.full((Lh, H), -2.0, jnp.float32)
        return p
    return fix_ssm(params)


def _mixer_groups(params):
    b = params["blocks"]
    if "dense" in b and isinstance(b["dense"], dict):
        return [b["dense"], b["moe"]]
    return [b]


# =====================================================================
# Forward pass
# =====================================================================

def _embed_in(arch, params, batch, cfg):
    """Returns (x (B,S,d) bf16, positions, mask_positions (B,S))."""
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.bfloat16)
        B, S = x.shape[:2]
        if arch.modality == "audio":
            x = x + sinusoidal_positions(S, arch.d_model)[None].astype(x.dtype)
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    if "positions" in batch:
        positions = batch["positions"]              # (3,B,S) mrope or (B,S)
        mask_pos = positions[0] if positions.ndim == 3 else positions
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if arch.mrope_sections is not None:
            positions = jnp.broadcast_to(positions, (3, B, S))
            mask_pos = positions[0]
        else:
            mask_pos = positions
    return x, positions, mask_pos


def _logits(arch, params, x, cfg):
    # stays bf16 (and vocab-sharded); CE/sampling upcast inside fused
    # reductions so the fp32 full-vocab tensor never hits HBM
    w = params["embed"].T if arch.tie_embeddings else params["lm_head"]
    return x @ w


def _ssm_dims(arch: ArchConfig, sp: SSMParams = None) -> SSMDims:
    if sp is not None:  # padding-aware: heads from A_log, di from out_proj
        H = sp.A_log.shape[-1]
        di = sp.out_proj.shape[-2]
        return SSMDims(arch.d_model, di, H, di // H, arch.ssm_state,
                       arch.ssm_n_groups, arch.ssm_conv)
    return SSMDims(arch.d_model, arch.d_inner, arch.ssm_heads,
                   arch.ssm_head_dim, arch.ssm_state, arch.ssm_n_groups,
                   arch.ssm_conv)


def _mixer_fwd(arch, cfg, grp, x, positions, mask_pos, window):
    """Pre-norm mixer: attention and/or SSM paths (parallel for hybrid)."""
    h = rms_norm(x, grp["pre_norm"], arch.norm_eps)
    out = 0.0
    n_paths = int(arch.has_attention) + int(arch.has_ssm)
    if arch.has_attention:
        ap = AttnParams(grp["attn"]["wq"], grp["attn"]["wk"],
                        grp["attn"]["wv"], grp["attn"]["wo"],
                        grp["attn"].get("q_norm"), grp["attn"].get("k_norm"))
        Hq = ap.wq.shape[-1] // arch.hd        # layout pass may pad heads
        q, k, v = attn_mod.project_qkv(
            h, ap, Hq, ap.wk.shape[-1] // arch.hd, arch.hd, positions,
            arch.rope_theta, arch.mrope_sections, arch.norm_eps)
        if cfg.shard_heads:
            q = _hint(q, cfg, None, None, cfg.model_axis, None)
            kv_spec = cfg.model_axis if cfg.kv_heads_sharded else "rep"
            k = _hint(k, cfg, None, None, kv_spec, None)
            v = _hint(v, cfg, None, None, kv_spec, None)
        ctx = attn_mod.attention_chunked(
            q, k, v, causal=arch.causal, window=window,
            block_q=cfg.block_q, positions=mask_pos)
        out = out + ctx.reshape(*ctx.shape[:2], -1) @ ap.wo
    if arch.has_ssm:
        sp = SSMParams(**grp["ssm"])
        out = out + ssm_mod.ssm_forward(h, sp, _ssm_dims(arch, sp),
                                        chunk=cfg.ssd_chunk)
    return x + out / n_paths


def _ffn_fwd(arch, cfg, grp, x):
    """Pre-norm FFN: dense MLP or MoE. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in grp:
        h = rms_norm(x, grp["mlp_norm"], arch.norm_eps)
        wi, wo = grp["mlp"]["wi"], grp["mlp"]["wo"]
        gated = arch.gated_mlp and arch.family != "encoder"
        z = h @ wi
        if gated:
            g, u = jnp.split(z, 2, axis=-1)
            z = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        elif arch.family == "encoder":
            z = jax.nn.gelu(z.astype(jnp.float32)).astype(x.dtype)
        else:  # squared relu (minitron/nemotron)
            z = jnp.square(jax.nn.relu(z.astype(jnp.float32))).astype(x.dtype)
        x = x + z @ wo
    elif "moe" in grp:
        h = rms_norm(x, grp["mlp_norm"], arch.norm_eps)
        mp = MoEParams(grp["moe"]["router"], grp["moe"]["wi"], grp["moe"]["wo"],
                       grp["moe"].get("shared_wi"), grp["moe"].get("shared_wo"))
        if cfg.moe_impl == "shard_map_alltoall" and cfg.mesh is not None:
            y, aux = moe_mod.moe_shard_map(
                h, mp, top_k=arch.experts_per_token,
                capacity_factor=arch.capacity_factor, mesh=cfg.mesh,
                data_axes=cfg.data_axes, model_axis=cfg.model_axis)
        elif cfg.moe_impl == "dense_einsum":
            y, aux = moe_mod.moe_dense_einsum(
                h, mp, top_k=arch.experts_per_token)
        else:
            y, aux = moe_mod.moe_gshard_einsum(
                h, mp, top_k=arch.experts_per_token,
                capacity_factor=arch.capacity_factor)
        x = x + y
    return x, aux


def _block_fwd(arch, cfg, grp, x, positions, mask_pos, window):
    x = _mixer_fwd(arch, cfg, grp, x, positions, mask_pos, window)
    if arch.family == "ssm":
        return x, jnp.zeros((), jnp.float32)
    return _ffn_fwd(arch, cfg, grp, x)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy in ("dots", "dots_saveable"):
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(fn, prevent_cse=False)


def _window_schedule(arch: ArchConfig) -> jnp.ndarray:
    """(L,) per-layer attention window (0 = unlimited/global)."""
    mask = global_layer_mask(arch)
    return jnp.asarray(
        [0 if g else arch.window for g in mask], dtype=jnp.int32)


def forward(arch: ArchConfig, params, batch, cfg: RunCfg):
    """Full-sequence forward -> (hidden (B,S,d), aux_loss)."""
    x, positions, mask_pos = _embed_in(arch, params, batch, cfg)
    # the embedding gather cannot carry both the batch sharding (indices)
    # and the table's feature sharding; pin the residual stream's batch dim
    # so GSPMD never replicates the activations (fsdp_dp strategy)
    if cfg.batch_spec is not None:
        x = _hint(x, cfg, cfg.batch_spec, None, "rep")
    g = arch.moe_interleave if arch.is_moe and arch.moe_interleave > 1 else 1
    Lg = arch.n_layers // g
    windows = _window_schedule(arch) if arch.has_attention else None

    def body(carry, xs):
        x, aux = carry
        if g > 1:
            grp_params, w = xs
            x = _mixer_fwd(arch, cfg, grp_params["dense"], x, positions,
                           mask_pos, w[0] if windows is not None else 0)
            x, a1 = _ffn_fwd(arch, cfg, grp_params["dense"], x)
            x = _mixer_fwd(arch, cfg, grp_params["moe"], x, positions,
                           mask_pos, w[1] if windows is not None else 0)
            x, a2 = _ffn_fwd(arch, cfg, grp_params["moe"], x)
            return (x, aux + a1 + a2), None
        grp_params, w = xs
        x, a = _block_fwd(arch, cfg, grp_params, x, positions, mask_pos,
                          w if windows is not None else 0)
        if cfg.batch_spec is not None:
            x = _hint(x, cfg, cfg.batch_spec, None, "rep")
        return (x, aux + a), None

    body = _remat(body, cfg.remat)
    if g > 1:
        w_xs = windows.reshape(Lg, g) if windows is not None \
            else jnp.zeros((Lg, g), jnp.int32)
        xs = (params["blocks"], w_xs)
    else:
        w_xs = windows if windows is not None else jnp.zeros((Lg,), jnp.int32)
        xs = (params["blocks"], w_xs)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    x = rms_norm(x, params["final_norm"], arch.norm_eps)
    return x, aux


def train_loss(arch: ArchConfig, params, batch, cfg: RunCfg):
    """Scalar loss for one batch. batch: tokens/embeds, targets, [mask]."""
    x, aux = forward(arch, params, batch, cfg)
    logits = _logits(arch, params, x, cfg)
    loss, n = cross_entropy_loss(
        logits, batch["targets"], batch.get("mask"),
        vocab_size=arch.vocab_size)
    metrics = {"ce_loss": loss, "aux_loss": aux, "tokens": n}
    return loss + cfg.aux_loss_weight * aux, metrics


# =====================================================================
# Serving: prefill + decode
# =====================================================================

def init_cache(arch: ArchConfig, batch_size: int, max_len: int,
               dtype=jnp.bfloat16, ssm_heads: int = 0,
               kv_heads: int = 0) -> Dict[str, Any]:
    """Session state ("cache.kv" + SSM states in the template).

    ``pos`` is a per-slot ``(B,)`` vector: continuous batching mixes
    prompt lengths, so each batch entry appends and masks at its own
    offset (an engine-global scalar silently corrupts every slot whose
    length differs from the max).
    """
    L = arch.n_layers
    Hs = ssm_heads or arch.ssm_heads
    cache: Dict[str, Any] = {"pos": jnp.zeros((batch_size,), jnp.int32)}
    if arch.has_attention:
        K, hd = kv_heads or arch.n_kv_heads, arch.hd
        cache["k"] = jnp.zeros((L, batch_size, max_len, K, hd), dtype)
        cache["v"] = jnp.zeros((L, batch_size, max_len, K, hd), dtype)
    if arch.has_ssm:
        cache["ssm"] = jnp.zeros(
            (L, batch_size, Hs, arch.ssm_head_dim, arch.ssm_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (L, batch_size, arch.ssm_conv,
             Hs * arch.ssm_head_dim + 2 * arch.ssm_n_groups * arch.ssm_state),
            jnp.bfloat16)
    return cache


def init_paged_cache(arch: ArchConfig, batch_size: int, max_len: int,
                     block_len: int, n_blocks: int,
                     dtype=jnp.bfloat16, ssm_heads: int = 0,
                     kv_heads: int = 0) -> Dict[str, Any]:
    """Paged session state: the KV stripes become a block pool.

    ``k``/``v`` are ``(L, n_blocks, block_len, K, hd)`` pools shared by
    all slots — one block id addresses the same block in every layer —
    and ``block_tbl`` is the per-slot ``(B, ceil(max_len/block_len))``
    table mapping sequence positions to pool blocks (-1 = unassigned).
    SSM/conv states stay dense per-slot (they are O(1) in seq).  The
    geometry (block_len, n_blocks) is a plan decision
    (``DataOrganizationPass`` via ``costmodel.kv_block_geometry``);
    under 2-D pool sharding the block dim is additionally split
    data-major into per-data-shard sub-pools, and the allocator filling
    ``block_tbl`` must keep each slot's blocks inside the sub-pool of
    the data shard hosting it (``serve.allocator.BlockAllocator``) —
    the batch-partitioned ``flash_decode_paged`` combine masks out any
    block its data row does not own.
    """
    L = arch.n_layers
    Hs = ssm_heads or arch.ssm_heads
    nb = -(-max_len // block_len)
    cache: Dict[str, Any] = {"pos": jnp.zeros((batch_size,), jnp.int32)}
    if arch.has_attention:
        K, hd = kv_heads or arch.n_kv_heads, arch.hd
        cache["k"] = jnp.zeros((L, n_blocks, block_len, K, hd), dtype)
        cache["v"] = jnp.zeros((L, n_blocks, block_len, K, hd), dtype)
        cache["block_tbl"] = jnp.full((batch_size, nb), -1, jnp.int32)
    if arch.has_ssm:
        cache["ssm"] = jnp.zeros(
            (L, batch_size, Hs, arch.ssm_head_dim, arch.ssm_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (L, batch_size, arch.ssm_conv,
             Hs * arch.ssm_head_dim + 2 * arch.ssm_n_groups * arch.ssm_state),
            jnp.bfloat16)
    return cache


def init_host_pool(arch: ArchConfig, n_host_blocks: int, block_len: int,
                   dtype=jnp.bfloat16, kv_heads: int = 0
                   ) -> Dict[str, np.ndarray]:
    """Host-DRAM spill tier behind the paged pool (``kv_tier_split``).

    Same per-block row layout as the device pools — ``k``/``v`` are
    ``(L, host_blocks, block_len, K, hd)`` — but held as **numpy**
    arrays: host memory by construction, never part of a jit graph, so
    a spilled block costs HBM nothing.  Blocks migrate between the two
    pools with :func:`gather_blocks` / :func:`scatter_blocks` (one
    batched gather or scatter per transfer; the host->device leg is the
    ``jax.device_put`` the engine's prefetch stages a tick early).
    The dtype matches the device pool exactly (bf16 via ml_dtypes), so
    a spill/promote round trip is bit-identical — the token-identity
    tests lean on that.
    """
    K, hd = kv_heads or arch.n_kv_heads, arch.hd
    L = arch.n_layers
    shape = (L, n_host_blocks, block_len, K, hd)
    return {"k": np.zeros(shape, dtype=np.dtype(dtype)),
            "v": np.zeros(shape, dtype=np.dtype(dtype))}


def gather_blocks(pool: jax.Array, ids: jax.Array) -> jax.Array:
    """Pull whole blocks out of a ``(L, n_blocks, block_len, K, hd)``
    pool as ``(L, len(ids), block_len, K, hd)`` rows — one batched
    gather, the device half of a block migration (spill reads, promote
    scatter-writes).  Jit-friendly: the engine wraps it once."""
    return pool[:, ids]


def scatter_blocks(pool: jax.Array, ids: jax.Array,
                   rows: jax.Array) -> jax.Array:
    """Write whole blocks back into a pool — the inverse of
    :func:`gather_blocks`, one batched scatter."""
    return pool.at[:, ids].set(rows)


def append_kv_paged(pool: jax.Array, new: jax.Array, pos: jax.Array,
                    tbl: jax.Array, start=0) -> jax.Array:
    """Paged KV append: write ``new[b]`` into slot b's owning block.

    ``pool`` is ``(n_blocks, block_len, K, hd)``, ``new`` ``(B, 1, K,
    hd)``, ``pos`` ``(B,)`` dense-view offsets, ``tbl`` ``(B, nb)``.
    Slots whose owning table entry is unassigned (-1) are dropped — a
    freed slot's dummy decode never touches the pool.  ``start`` is the
    caller's first global block id when ``pool`` is one shard of a
    sharded pool (``dist.flash_decode.flash_decode_paged`` — under 2-D
    pool sharding the shard's offset linearizes its (data..., model)
    mesh coordinates data-major): blocks owned elsewhere are dropped
    too.  Oracle: :func:`repro.kernels.ref.paged_append_ref`.
    """
    N, bl = pool.shape[0], pool.shape[1]
    blk = jnp.take_along_axis(tbl, (pos // bl)[:, None], axis=1)[:, 0] - start
    # scatter mode="drop" still *wraps* negative indices, so route
    # unassigned/off-shard entries to an always-out-of-range sentinel
    blk = jnp.where(blk < 0, N, blk)
    return pool.at[blk, pos % bl].set(new[:, 0].astype(pool.dtype),
                                      mode="drop")


def _flatten_groups(arch, params):
    """Stacked per-layer params (group-interleaved archs -> per-layer)."""
    g = arch.moe_interleave if arch.is_moe and arch.moe_interleave > 1 else 1
    return params["blocks"], g


def append_kv(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Per-slot KV append: write ``new[b]`` at seq offset ``pos[b]``.

    ``cache`` is ``(B, S, K, hd)``, ``new`` ``(B, 1, K, hd)``, ``pos``
    ``(B,)`` — each batch entry lands at its own offset (continuous
    batching; oracle: :func:`repro.kernels.ref.decode_append_ref`).
    """
    def one(c, n, p):
        return jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), p, axis=0)
    return jax.vmap(one)(cache, new, pos)


def decode_step(arch: ArchConfig, params, cache, batch, cfg: RunCfg):
    """One-token decode across all layers. Returns (logits, new_cache)."""
    x, positions, _ = _embed_in(arch, params, batch, cfg)   # (B,1,d)
    B = x.shape[0]
    pos = jnp.asarray(cache["pos"], jnp.int32)
    if pos.ndim == 0:                   # legacy scalar: uniform offsets
        pos = jnp.full((B,), pos, jnp.int32)
    if "positions" not in batch:
        positions = pos[:, None]                            # (B, 1)
        if arch.mrope_sections is not None:
            positions = jnp.broadcast_to(positions, (3, B, 1))
    windows = _window_schedule(arch) if arch.has_attention else \
        jnp.zeros((arch.n_layers,), jnp.int32)
    blocks, g = _flatten_groups(arch, params)
    block_tbl = cache.get("block_tbl")        # paged residency marker

    def layer(x, grp, w, kc, vc, sc, cc):
        """One layer of decode; returns (x, new kc/vc/sc/cc)."""
        h = rms_norm(x, grp["pre_norm"], arch.norm_eps)
        out = 0.0
        n_paths = int(arch.has_attention) + int(arch.has_ssm)
        if arch.has_attention:
            ap = AttnParams(grp["attn"]["wq"], grp["attn"]["wk"],
                            grp["attn"]["wv"], grp["attn"]["wo"],
                            grp["attn"].get("q_norm"), grp["attn"].get("k_norm"))
            Hq = ap.wq.shape[-1] // arch.hd
            q, k, v = attn_mod.project_qkv(
                h, ap, Hq, ap.wk.shape[-1] // arch.hd, arch.hd, positions,
                arch.rope_theta, arch.mrope_sections, arch.norm_eps)
            if cfg.decode_impl == "shard_map_flash" and cfg.mesh is not None:
                if block_tbl is not None:
                    from repro.dist.flash_decode import flash_decode_paged
                    ctx, kc, vc = flash_decode_paged(
                        q, k, v, kc, vc, block_tbl, pos, w, mesh=cfg.mesh,
                        data_axes=cfg.data_axes, model_axis=cfg.model_axis,
                        combine=cfg.combine_topology)
                else:
                    from repro.dist.flash_decode import flash_decode
                    ctx, kc, vc = flash_decode(
                        q, k, v, kc, vc, pos, w, mesh=cfg.mesh,
                        data_axes=cfg.data_axes, model_axis=cfg.model_axis,
                        combine=cfg.combine_topology)
            else:
                if not cfg.shard_heads:
                    pass
                elif cfg.kv_heads_sharded:
                    q = _hint(q, cfg, None, None, cfg.model_axis, None)
                    k = _hint(k, cfg, None, None, cfg.model_axis, None)
                    v = _hint(v, cfg, None, None, cfg.model_axis, None)
                else:
                    # match the head_dim-sharded cache: QK^T contracts the
                    # sharded dim -> psum of the score tensor, and the
                    # cache append stays local
                    q = _hint(q, cfg, None, None, "rep", cfg.model_axis)
                    k = _hint(k, cfg, None, None, "rep", cfg.model_axis)
                    v = _hint(v, cfg, None, None, "rep", cfg.model_axis)
                if block_tbl is not None:
                    kc = append_kv_paged(kc, k, pos, block_tbl)
                    vc = append_kv_paged(vc, v, pos, block_tbl)
                    ctx = attn_mod.attention_decode_paged(
                        q, kc, vc, block_tbl, cache_len=pos + 1, window=w)
                else:
                    kc = append_kv(kc, k, pos)
                    vc = append_kv(vc, v, pos)
                    ctx = attn_mod.attention_decode(q, kc, vc,
                                                    cache_len=pos + 1,
                                                    window=w)
            out = out + ctx.reshape(B, 1, -1) @ ap.wo
        if arch.has_ssm:
            sp = SSMParams(**grp["ssm"])
            y, sc, cc = ssm_mod.ssm_decode_step(h, sp, _ssm_dims(arch, sp),
                                                sc, cc)
            out = out + y
        x = x + out / n_paths
        if arch.family != "ssm" and ("mlp" in grp or "moe" in grp):
            x, _ = _ffn_fwd(arch, cfg, grp, x)
        return x, kc, vc, sc, cc

    # scan over layers with the FULL stacked cache in the carry: each
    # iteration slices its layer and updates it in place (dynamic-update-
    # slice on the unsharded layer dim), so the cache buffer is aliased
    # end-to-end (with donation) instead of double-buffered through ys.
    L = arch.n_layers
    Lg = L // g
    kc_full = cache.get("k")
    vc_full = cache.get("v")
    sc_full = cache.get("ssm")
    cc_full = cache.get("conv")
    win = windows
    if g > 1:
        kc_full = kc_full.reshape(Lg, g, *kc_full.shape[1:]) \
            if kc_full is not None else None
        vc_full = vc_full.reshape(Lg, g, *vc_full.shape[1:]) \
            if vc_full is not None else None
        win = windows.reshape(Lg, g)
        if sc_full is not None:
            sc_full = sc_full.reshape(Lg, g, *sc_full.shape[1:])
            cc_full = cc_full.reshape(Lg, g, *cc_full.shape[1:])
    zeros = lambda: jnp.zeros((Lg, 1), jnp.float32)
    kc_full = kc_full if kc_full is not None else zeros()
    vc_full = vc_full if vc_full is not None else zeros()
    sc_full = sc_full if sc_full is not None else zeros()
    cc_full = cc_full if cc_full is not None else zeros()

    def at(full, i):
        return jax.lax.dynamic_index_in_dim(full, i, axis=0, keepdims=False)

    def put(full, i, val):
        return jax.lax.dynamic_update_index_in_dim(full, val, i, axis=0)

    def body(carry, xs):
        x, i, kf, vf, sf, cf = carry
        grp, w = xs
        kc, vc, sc, cc = at(kf, i), at(vf, i), at(sf, i), at(cf, i)
        if g > 1:
            x, kc0, vc0, sc0, cc0 = layer(x, grp["dense"], w[0],
                                          kc[0], vc[0], sc[0], cc[0])
            x, kc1, vc1, sc1, cc1 = layer(x, grp["moe"], w[1],
                                          kc[1], vc[1], sc[1], cc[1])
            kc = jnp.stack([kc0, kc1]) if arch.has_attention else kc
            vc = jnp.stack([vc0, vc1]) if arch.has_attention else vc
            sc = jnp.stack([sc0, sc1]) if arch.has_ssm else sc
            cc = jnp.stack([cc0, cc1]) if arch.has_ssm else cc
        else:
            x, kc, vc, sc, cc = layer(x, grp, w, kc, vc, sc, cc)
        return (x, i + 1, put(kf, i, kc), put(vf, i, vc),
                put(sf, i, sc), put(cf, i, cc)), None

    init = (x, jnp.zeros((), jnp.int32), kc_full, vc_full, sc_full, cc_full)
    (x, _, new_k, new_v, new_s, new_c), _ = jax.lax.scan(
        body, init, (blocks, win))
    x = rms_norm(x, params["final_norm"], arch.norm_eps)
    logits = _logits(arch, params, x, cfg)
    new_cache = dict(cache)
    new_cache["pos"] = pos + 1
    if arch.has_attention:
        new_cache["k"] = new_k.reshape(L, *new_k.shape[2:]) if g > 1 else new_k
        new_cache["v"] = new_v.reshape(L, *new_v.shape[2:]) if g > 1 else new_v
    if arch.has_ssm:
        new_cache["ssm"] = new_s.reshape(L, *new_s.shape[2:]) if g > 1 else new_s
        new_cache["conv"] = new_c.reshape(L, *new_c.shape[2:]) if g > 1 else new_c
    return logits[:, 0], new_cache


def prefill(arch: ArchConfig, params, batch, cfg: RunCfg, max_len: int = 0):
    """Process a prompt, build the session cache, return last-token logits.

    Implemented as the full-sequence forward plus cache extraction — the
    K/V for every layer are recomputed from the per-layer projections in
    a second scan that shares the same block params (cheap relative to
    the FFN work, and keeps `forward` cache-free for training).
    For SSM archs the final state comes from running the SSD scan.
    """
    x, positions, mask_pos = _embed_in(arch, params, batch, cfg)
    B, S = x.shape[:2]
    max_len = max_len or S
    windows = _window_schedule(arch) if arch.has_attention else None
    g = arch.moe_interleave if arch.is_moe and arch.moe_interleave > 1 else 1
    Lg = arch.n_layers // g

    cache = init_cache(arch, B, max_len)

    def layer(x, grp, w):
        h = rms_norm(x, grp["pre_norm"], arch.norm_eps)
        out = 0.0
        n_paths = int(arch.has_attention) + int(arch.has_ssm)
        kv = (jnp.zeros((B, 0, 1, 1), jnp.bfloat16),) * 2
        states = ()
        if arch.has_attention:
            ap = AttnParams(grp["attn"]["wq"], grp["attn"]["wk"],
                            grp["attn"]["wv"], grp["attn"]["wo"],
                            grp["attn"].get("q_norm"), grp["attn"].get("k_norm"))
            Hq = ap.wq.shape[-1] // arch.hd
            q, k, v = attn_mod.project_qkv(
                h, ap, Hq, ap.wk.shape[-1] // arch.hd, arch.hd, positions,
                arch.rope_theta, arch.mrope_sections, arch.norm_eps)
            if cfg.shard_heads:
                q = _hint(q, cfg, None, None, cfg.model_axis, None)
                kv_spec = cfg.model_axis if cfg.kv_heads_sharded else "rep"
                k = _hint(k, cfg, None, None, kv_spec, None)
                v = _hint(v, cfg, None, None, kv_spec, None)
            ctx = attn_mod.attention_chunked(
                q, k, v, causal=arch.causal, window=w,
                block_q=cfg.block_q, positions=mask_pos)
            out = out + ctx.reshape(B, S, -1) @ ap.wo
            kv = (k, v)
        if arch.has_ssm:
            sp = SSMParams(**grp["ssm"])
            y, fin_s, fin_c = _ssm_prefill(h, sp, arch, cfg)
            out = out + y
            states = (fin_s, fin_c)
        x = x + out / n_paths
        aux = jnp.zeros((), jnp.float32)
        if arch.family != "ssm" and ("mlp" in grp or "moe" in grp):
            x, aux = _ffn_fwd(arch, cfg, grp, x)
        return x, kv, states

    def body(carry, xs):
        x = carry
        grp, w = xs
        outs = []
        if g > 1:
            x, kv0, st0 = layer(x, grp["dense"], w[0])
            x, kv1, st1 = layer(x, grp["moe"], w[1])
            ys = _stack_cache(arch, (kv0, kv1), (st0, st1), max_len, S)
        else:
            x, kv, st = layer(x, grp, w)
            ys = _stack_cache(arch, (kv,), (st,), max_len, S)
        return x, ys

    if g > 1:
        w_xs = (windows.reshape(Lg, g) if windows is not None
                else jnp.zeros((Lg, g), jnp.int32))
    else:
        w_xs = (windows if windows is not None
                else jnp.zeros((Lg,), jnp.int32))
    x, ys = jax.lax.scan(body, x, (params["blocks"], w_xs))
    x = rms_norm(x, params["final_norm"], arch.norm_eps)
    logits = _logits(arch, params, x[:, -1:], cfg)

    # unpack stacked cache entries
    L = arch.n_layers
    idx = 0
    if arch.has_attention:
        cache["k"] = ys[idx].reshape(L, B, max_len, -1, arch.hd)
        cache["v"] = ys[idx + 1].reshape(L, B, max_len, -1, arch.hd)
        idx += 2
    if arch.has_ssm:
        cache["ssm"] = ys[idx].reshape(L, *ys[idx].shape[-4:])
        cache["conv"] = ys[idx + 1].reshape(L, *ys[idx + 1].shape[-3:])
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits[:, 0], cache


def prefill_tail(arch: ArchConfig, params, batch, cfg: RunCfg,
                 prefix_k: jax.Array, prefix_v: jax.Array):
    """Prefill only the unmatched *tail* of a prompt whose leading rows
    already sit in resident pool blocks (cross-request prefix reuse).

    ``batch["tokens"]`` is ``(B, T)`` tail tokens; ``prefix_k`` /
    ``prefix_v`` are ``(L, B, M, K, hd)`` — the matched prefix rows
    gathered from the pool (already post-RoPE at absolute positions
    ``[0, M)``, exactly as the donor's prefill wrote them).  The tail is
    embedded at absolute positions ``M + [0, T)`` and each layer attends
    over prefix-plus-tail keys through :func:`repro.models.attention
    .attention_tail`, whose op structure matches the full-prefill
    attention bit-for-bit on the tail positions — the token-identity
    contract aliased admission leans on.

    Returns ``(last-token logits (B, V), tail_k, tail_v)`` with tail
    K/V stacked ``(L, B, T, K, hd)`` for the caller to scatter into its
    freshly allocated blocks.  Attention-only architectures: an SSM
    path's state at position M depends on every earlier token, so a
    hybrid cannot skip the prefix compute (the engine runs those
    through the full prefill and aliases blocks without skipping).
    """
    if arch.has_ssm:
        raise ValueError(
            f"prefill_tail cannot skip prefix compute for {arch.name}: "
            "SSM state at the split point depends on the whole prefix")
    tokens = batch["tokens"]
    B, T = tokens.shape
    M = prefix_k.shape[2]
    x = jnp.take(params["embed"], tokens, axis=0)
    q_pos = jnp.broadcast_to(M + jnp.arange(T, dtype=jnp.int32), (B, T))
    k_pos = jnp.broadcast_to(jnp.arange(M + T, dtype=jnp.int32), (B, M + T))
    positions = q_pos
    if arch.mrope_sections is not None:
        positions = jnp.broadcast_to(positions, (3, B, T))
    windows = _window_schedule(arch)
    g = arch.moe_interleave if arch.is_moe and arch.moe_interleave > 1 else 1
    Lg = arch.n_layers // g

    def layer(x, grp, w, pk, pv):
        h = rms_norm(x, grp["pre_norm"], arch.norm_eps)
        ap = AttnParams(grp["attn"]["wq"], grp["attn"]["wk"],
                        grp["attn"]["wv"], grp["attn"]["wo"],
                        grp["attn"].get("q_norm"), grp["attn"].get("k_norm"))
        Hq = ap.wq.shape[-1] // arch.hd
        q, k, v = attn_mod.project_qkv(
            h, ap, Hq, ap.wk.shape[-1] // arch.hd, arch.hd, positions,
            arch.rope_theta, arch.mrope_sections, arch.norm_eps)
        if cfg.shard_heads:
            q = _hint(q, cfg, None, None, cfg.model_axis, None)
            kv_spec = cfg.model_axis if cfg.kv_heads_sharded else "rep"
            k = _hint(k, cfg, None, None, kv_spec, None)
            v = _hint(v, cfg, None, None, kv_spec, None)
        k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        ctx = attn_mod.attention_tail(
            q, k_full, v_full, q_positions=q_pos, k_positions=k_pos,
            causal=arch.causal, window=w, block_q=cfg.block_q)
        x = x + ctx.reshape(B, T, -1) @ ap.wo
        if "mlp" in grp or "moe" in grp:
            x, _ = _ffn_fwd(arch, cfg, grp, x)
        return x, k, v

    pk_xs = prefix_k.reshape(Lg, g, *prefix_k.shape[1:]) if g > 1 \
        else prefix_k
    pv_xs = prefix_v.reshape(Lg, g, *prefix_v.shape[1:]) if g > 1 \
        else prefix_v

    def body(x, xs):
        grp, w, pk, pv = xs
        if g > 1:
            x, k0, v0 = layer(x, grp["dense"], w[0], pk[0], pv[0])
            x, k1, v1 = layer(x, grp["moe"], w[1], pk[1], pv[1])
            return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
        x, k, v = layer(x, grp, w, pk, pv)
        return x, (k, v)

    w_xs = windows.reshape(Lg, g) if g > 1 else windows
    x, (ks, vs) = jax.lax.scan(
        body, x, (params["blocks"], w_xs, pk_xs, pv_xs))
    x = rms_norm(x, params["final_norm"], arch.norm_eps)
    logits = _logits(arch, params, x[:, -1:], cfg)
    L = arch.n_layers
    tail_k = ks.reshape(L, B, T, -1, arch.hd)
    tail_v = vs.reshape(L, B, T, -1, arch.hd)
    return logits[:, 0], tail_k, tail_v


def prefill_chunked(arch: ArchConfig, params, tokens, chunk: int,
                    cfg: RunCfg, kv_heads: int = 0,
                    prefix_k=None, prefix_v=None,
                    on_chunk=None, tail_fn=None):
    """Block-native chunked prefill of ONE prompt: no dense ``(B, plen)``
    intermediate ever exists.

    ``tokens`` is ``(T,)`` — the part of the feed *after* any prefix
    already in hand; ``prefix_k``/``prefix_v`` are ``(L, M, K, hd)``
    rows covering the first M tokens (``None`` for a fresh prompt).
    The tail is processed in ``chunk``-sized slices, each one a
    :func:`prefill_tail` call chained on the KV accumulated so far —
    every slice comes out pool-block-shaped, ready to scatter straight
    into paged blocks.  Because ``attention_tail`` mirrors the
    full-prefill ``attention_chunked`` op-for-op, the chained chunks
    reproduce the dense prefill's hidden states exactly: same KV rows,
    same last-token logits (pinned by ``test_disagg``).

    ``on_chunk(block_idx, k_c, v_c)`` fires after each slice with
    ``(L, t, K, hd)`` rows (``block_idx`` counts from the start of the
    *feed*, prefix included) — the disagg worker streams these to the
    decode engine and heartbeats between them.  ``tail_fn`` lets a
    long-lived caller supply a pre-jitted ``prefill_tail`` closure so
    the per-shape compile cache survives across prompts.

    Returns ``(last-token logits (V,), ks, vs)`` with the per-chunk row
    lists.  Attention-only archs (same restriction as ``prefill_tail``).
    """
    if arch.has_ssm:
        raise ValueError(
            f"prefill_chunked needs pure-attention KV for {arch.name}: "
            "SSM state is sequential across the whole prompt")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    tokens = jnp.asarray(tokens, jnp.int32)
    (T,) = tokens.shape
    if T < 1:
        raise ValueError("prefill_chunked needs at least one tail token")
    L, K = arch.n_layers, (kv_heads or arch.n_kv_heads)
    if prefix_k is None:
        pk = jnp.zeros((L, 1, 0, K, arch.hd), jnp.bfloat16)
        pv = pk
    else:
        pk = jnp.asarray(prefix_k)[:, None]    # (L, 1, M, K, hd)
        pv = jnp.asarray(prefix_v)[:, None]
    M = pk.shape[2]
    if M % chunk:
        raise ValueError(f"prefix length {M} not block-aligned to {chunk}")
    if tail_fn is None:
        tail_fn = lambda p, b, k, v: prefill_tail(arch, p, b, cfg, k, v)
    ks, vs = [], []
    logits = None
    for i in range(0, T, chunk):
        tok = tokens[None, i:i + chunk]                      # (1, t)
        logits, tk, tv = tail_fn(params, {"tokens": tok}, pk, pv)
        ks.append(tk[:, 0])                                  # (L, t, K, hd)
        vs.append(tv[:, 0])
        if on_chunk is not None:
            on_chunk((M + i) // chunk, ks[-1], vs[-1])
        pk = jnp.concatenate([pk, tk], axis=2)
        pv = jnp.concatenate([pv, tv], axis=2)
    return logits[0], ks, vs


def _ssm_prefill(h, sp, arch, cfg):
    """SSD forward that also returns the final (ssm, conv) states."""
    dims = _ssm_dims(arch, sp)
    B, S, d = h.shape
    di, G, N = dims.d_inner, dims.n_groups, dims.state
    zxbcdt = h @ sp.in_proj
    z, xbc_raw, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xbc = ssm_mod.causal_conv(xbc_raw, sp.conv_w, sp.conv_b)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, dims.n_heads, dims.head_dim)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + sp.dt_bias)
    A = -jnp.exp(sp.A_log)
    y, final = ssm_mod.ssd_chunked(xs, dtv, A, Bm, Cm, chunk=cfg.ssd_chunk)
    y = y + xs * sp.D[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), sp.norm)
    y = (y @ sp.out_proj).astype(h.dtype)
    k = dims.conv_k
    conv_fin = jnp.pad(xbc_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, -k:, :] \
        .astype(jnp.bfloat16)
    return y, final, conv_fin


def _stack_cache(arch, kvs, states, max_len, S):
    """Build the per-scan-step cache ys tuple (padded to max_len)."""
    out = []
    if arch.has_attention:
        ks = jnp.stack([jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))
                        for k, _ in kvs])
        vs = jnp.stack([jnp.pad(v, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))
                        for _, v in kvs])
        if len(kvs) == 1:
            ks, vs = ks[0], vs[0]
        out += [ks, vs]
    if arch.has_ssm:
        ss = jnp.stack([s[0] for s in states])
        cs = jnp.stack([s[1] for s in states])
        if len(states) == 1:
            ss, cs = ss[0], cs[0]
        out += [ss, cs]
    return tuple(out)
