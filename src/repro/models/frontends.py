"""Modality frontends (STUBS per the assignment) + input specs.

``[audio]``/``[vlm]`` architectures specify the transformer backbone
only; the conv feature extractor (hubert) and vision tower (qwen2-vl)
are stubs that provide *precomputed* frame/patch embeddings.  This
module is the single source of truth for what each (arch × shape) step
function consumes:

* ``input_specs(arch, shape)``   — ShapeDtypeStructs (dry-run, no alloc)
* ``synthetic_batch(arch, shape, key)`` — real arrays (smoke tests, CPU)

Logical input axes (for the sharding rules): batch -> data(+pod),
seq -> None, act_embed -> None.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def input_axes(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Tuple]:
    """Logical axes per input (same vocabulary as the Memory IR)."""
    ax: Dict[str, Tuple] = {}
    if shape.kind == "decode":
        ax["tokens"] = ("batch", None)
    elif arch.modality in ("audio", "vlm"):
        ax["embeds"] = ("batch", "seq", None)
        if arch.mrope_sections is not None:
            ax["positions"] = (None, "batch", "seq")
        if shape.kind == "train":
            ax["targets"] = ("batch", "seq")
        if arch.modality == "audio" and shape.kind == "train":
            ax["mask"] = ("batch", "seq")
    else:
        ax["tokens"] = ("batch", "seq")
        if shape.kind == "train":
            ax["targets"] = ("batch", "seq")
    return ax


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    if shape.kind == "decode":
        out["tokens"] = sd((B, 1), jnp.int32)
        return out
    if arch.modality in ("audio", "vlm"):
        out["embeds"] = sd((B, S, arch.d_model), jnp.bfloat16)
        if arch.mrope_sections is not None:
            out["positions"] = sd((3, B, S), jnp.int32)
        if shape.kind == "train":
            out["targets"] = sd((B, S), jnp.int32)
        if arch.modality == "audio" and shape.kind == "train":
            out["mask"] = sd((B, S), jnp.float32)
    else:
        out["tokens"] = sd((B, S), jnp.int32)
        if shape.kind == "train":
            out["targets"] = sd((B, S), jnp.int32)
    return out


def synthetic_batch(arch: ArchConfig, shape: ShapeConfig,
                    key: jax.Array) -> Dict[str, Any]:
    """Concrete random batch matching ``input_specs`` (smoke tests)."""
    specs = input_specs(arch, shape)
    out: Dict[str, Any] = {}
    for name, spec in specs.items():
        key, k = jax.random.split(key)
        if name in ("tokens", "targets"):
            out[name] = jax.random.randint(k, spec.shape, 0, arch.vocab_size,
                                           dtype=jnp.int32)
        elif name == "positions":
            pos = jnp.broadcast_to(
                jnp.arange(spec.shape[-1], dtype=jnp.int32), spec.shape)
            out[name] = pos
        elif name == "mask":
            out[name] = (jax.random.uniform(k, spec.shape) < 0.5).astype(
                jnp.float32)
        else:  # embeds
            out[name] = jax.random.normal(k, spec.shape, jnp.float32).astype(
                spec.dtype)
    return out
