"""Mixture-of-Experts layer: capacity-based (GShard-style) routing.

Two execution paths, chosen by the communication pass:

* ``gshard_einsum`` — dispatch/combine one-hot einsums under plain pjit;
  XLA inserts the token↔expert all-to-alls from the shardings.  This is
  the baseline (paper-faithful "the compiler sees the IR and places the
  transfers").
* ``shard_map_alltoall`` — explicit ``jax.lax.all_to_all`` over the
  ``model`` axis inside ``shard_map``: the hand-scheduled collective
  pattern used in the beyond-paper perf iterations.

Both produce identical math (tested for equivalence); tokens over
capacity are dropped (capacity_factor 1.25 by default) and the router
adds the standard load-balancing auxiliary loss.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat as _compat  # noqa: F401  (jax.shard_map alias)


class MoEParams(NamedTuple):
    router: jax.Array          # (d, E) fp32
    wi: jax.Array              # (E, d, 2*ff)  gate||up
    wo: jax.Array              # (E, ff, d)
    shared_wi: Optional[jax.Array] = None   # (d, 2*ff*n_shared)
    shared_wo: Optional[jax.Array] = None   # (ff*n_shared, d)


def _capacity(tokens_per_group: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(tokens_per_group * top_k * capacity_factor / n_experts)
    return max(4, -(-c // 4) * 4)          # multiple of 4, at least 4


def route(
    x: jax.Array,                # (G, T, d)  G groups of T tokens
    router_w: jax.Array,         # (d, E)
    top_k: int,
    capacity: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing with per-group capacity.

    Returns (dispatch (G,T,E,C) bf16, combine (G,T,E,C) f32, aux_loss).
    """
    G, T, d = x.shape
    E = router_w.shape[-1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (G,T,E)

    # standard load-balance aux loss (Switch): E * mean(f_e * p_e)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=1)
    frac_probs = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    remaining = probs
    dispatch = jnp.zeros((G, T, E, capacity), dtype=x.dtype)
    combine = jnp.zeros((G, T, E, capacity), dtype=jnp.float32)
    # fill counts per expert as we take top-k slots sequentially
    fill = jnp.zeros((G, E), dtype=jnp.int32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                 # (G,T)
        gate = jnp.take_along_axis(remaining, idx[..., None], -1)[..., 0]
        remaining = remaining * (1.0 - jax.nn.one_hot(idx, E, dtype=jnp.float32))
        oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)          # (G,T,E)
        pos = fill[:, None, :] + jnp.cumsum(oh, axis=1) - oh  # slot per token
        fill = fill + jnp.sum(oh, axis=1)
        within = (pos < capacity) & (oh > 0)                  # (G,T,E)
        slot = jnp.where(within, pos, 0)
        one_hot_slot = jax.nn.one_hot(slot, capacity, dtype=jnp.float32) \
            * within[..., None]
        dispatch = dispatch + one_hot_slot.astype(x.dtype)
        combine = combine + one_hot_slot * gate[..., None, None]
    return dispatch, combine, aux


def moe_dense_einsum(
    x: jax.Array,                # (B, S, d)
    p: MoEParams,
    *,
    top_k: int,
    capacity_factor: float = 0.0,   # unused; signature parity
) -> Tuple[jax.Array, jax.Array]:
    """Dense-execution MoE: run EVERY expert on every token, combine with
    the top-k router weights.

    For small-expert/high-top-k configs (granite: 8-of-32, ff=512) the
    GShard dispatch/combine one-hot matmuls cost MORE FLOPs than simply
    computing all experts — and this path has no capacity drops, no
    (T,E,C) tensors, and no all-to-all.  The communication pass picks it
    when 6·E·ff <= 6·k·ff + 4·k·cf·(E·C/T)·... (see _moe_impl decision).
    """
    B, S, d = x.shape
    E = p.router.shape[-1]
    logits = x.astype(jnp.float32) @ p.router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B,S,E)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32),
                           axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # top-k gate weights, zero elsewhere
    thresh = jax.lax.top_k(probs, top_k)[0][..., -1:]
    gates = jnp.where(probs >= thresh, probs, 0.0)           # (B,S,E)

    h = jnp.einsum("bsd,edf->bsef", x, p.wi)                 # (B,S,E,2ff)
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jnp.einsum("bsef,efd->bsed", h, p.wo)              # (B,S,E,d)
    y = jnp.einsum("bsed,bse->bsd", out.astype(jnp.float32), gates)
    y = y.astype(x.dtype)
    if p.shared_wi is not None:
        hs = x @ p.shared_wi
        gs, us = jnp.split(hs, 2, axis=-1)
        y = y + (jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype)
                 * us) @ p.shared_wo
    return y, aux


def moe_gshard_einsum(
    x: jax.Array,                # (B, S, d)
    p: MoEParams,
    *,
    top_k: int,
    capacity_factor: float,
) -> Tuple[jax.Array, jax.Array]:
    """Einsum dispatch path (pjit shards: B->data, E->model)."""
    B, S, d = x.shape
    E = p.router.shape[-1]
    C = _capacity(S, E, top_k, capacity_factor)
    dispatch, combine, aux = route(x, p.router, top_k, C)     # (B,S,E,C)
    # token -> expert slots (XLA: all-to-all from B-shard to E-shard)
    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    h = jnp.einsum("ebcd,edf->ebcf", expert_in, p.wi)
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    expert_out = jnp.einsum("ebcf,efd->ebcd", h, p.wo)
    y = jnp.einsum("ebcd,bsec->bsd", expert_out.astype(jnp.float32), combine)
    y = y.astype(x.dtype)
    if p.shared_wi is not None:
        hs = x @ p.shared_wi
        gs, us = jnp.split(hs, 2, axis=-1)
        y = y + (jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us) @ p.shared_wo
    return y, aux


def moe_shard_map(
    x: jax.Array,                # (B, S, d) — sharded (data, model, None)
    p: MoEParams,
    *,
    top_k: int,
    capacity_factor: float,
    mesh: jax.sharding.Mesh,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Explicit expert-parallel path: tokens sharded over (data×model),
    experts sharded over ``model``; two ragged-free all_to_alls move
    capacity slots between the layouts.  Beyond-paper optimization: the
    dispatch tensor never exists at global size and the collective is a
    single fused all-to-all instead of XLA's inferred pair.
    """
    E = p.router.shape[-1]
    tp = mesh.shape[model_axis]
    E_local = E // tp
    B, S, _ = x.shape
    # decode steps have S=1: keep tokens replicated over the model axis then
    seq_spec = model_axis if S % max(tp, 1) == 0 and S >= tp else None
    all_axes = tuple(data_axes) + (model_axis,)

    def local(x_l, router, wi, wo, *shared):
        # x_l: (B_l, S_l, d) — tokens on this chip
        Bl, Sl, d = x_l.shape
        toks = x_l.reshape(1, Bl * Sl, d)
        C = _capacity(Bl * Sl, E, top_k, capacity_factor)
        dispatch, combine, aux = route(toks, router, top_k, C)
        # (1,T,E,C) -> local contribution to every expert's slots
        send = jnp.einsum("gtec,gtd->ecd", dispatch, toks)      # (E,C,d)
        send = send.reshape(tp, E_local, C, d)
        # exchange: each peer receives its experts' slots from everyone
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)   # (tp,E_l,C,d)
        expert_in = recv.transpose(1, 0, 2, 3).reshape(E_local, tp * C, d)
        h = jnp.einsum("ecd,edf->ecf", expert_in, wi)
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x_l.dtype) * up
        out = jnp.einsum("ecf,efd->ecd", h, wo)                 # (E_l,tp*C,d)
        out = out.reshape(E_local, tp, C, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(out, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)   # (tp,E_l,C,d)
        back = back.reshape(E, C, d)
        y = jnp.einsum("ecd,gtec->gtd", back.astype(jnp.float32),
                       combine)[0].reshape(Bl, Sl, d).astype(x_l.dtype)
        if shared:
            shared_wi, shared_wo = shared
            hs = x_l @ shared_wi
            gs, us = jnp.split(hs, 2, axis=-1)
            y = y + (jax.nn.silu(gs.astype(jnp.float32)).astype(x_l.dtype)
                     * us) @ shared_wo
        return y, jax.lax.pmean(aux, all_axes)

    in_specs = [
        P(data_axes, seq_spec, None),          # x: tokens over data(×model)
        P(None, None),                         # router replicated
        P(model_axis, None, None),             # wi: experts over model
        P(model_axis, None, None),             # wo
    ]
    args = [x, p.router, p.wi, p.wo]
    if p.shared_wi is not None:
        in_specs += [P(None, None), P(None, None)]
        args += [p.shared_wi, p.shared_wo]
    out_specs = (P(data_axes, seq_spec, None), P())
    fn = jax.shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
        check_vma=False)
    y, aux = fn(*args)
    return y, aux
