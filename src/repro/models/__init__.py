from repro.models.lm import (
    RunCfg,
    decode_step,
    forward,
    init_cache,
    init_params,
    leaf_specs,
    param_axes,
    param_shapes,
    prefill,
    train_loss,
)
from repro.models.frontends import input_axes, input_specs, synthetic_batch

__all__ = [
    "RunCfg", "decode_step", "forward", "init_cache", "init_params",
    "leaf_specs", "param_axes", "param_shapes", "prefill", "train_loss",
    "input_axes", "input_specs", "synthetic_batch",
]
