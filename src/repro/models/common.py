"""Shared model components: norms, rotary embeddings, initializers.

All computations follow the numerics decided by the layout pass: bf16
streams, fp32 for norms/softmax/rotary tables.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies, fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,                 # (..., seq, heads, head_dim)
    positions: jax.Array,         # (..., seq) int32
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """Rotary embedding; supports qwen2-vl M-RoPE via 3 position streams.

    With M-RoPE, ``positions`` has shape (3, ..., seq): temporal / height /
    width ids.  The hd/2 frequency slots are split into the configured
    sections, each rotated by its own position stream.
    """
    if theta <= 0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    inv = rope_frequencies(hd, theta)                       # (half,)
    if mrope_sections is not None:
        sec = np.cumsum((0,) + tuple(mrope_sections))
        assert sec[-1] == half, (mrope_sections, half)
        # pick which of the 3 position streams drives each frequency slot
        sel = np.zeros((half,), dtype=np.int32)
        for i in range(3):
            sel[sec[i]:sec[i + 1]] = i
        pos = positions.astype(jnp.float32)                 # (3, ..., seq)
        pos = jnp.moveaxis(pos, 0, -1)                      # (..., seq, 3)
        angles = pos[..., sel] * inv                        # (..., seq, half)
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv
    # broadcast over heads: x is (..., seq, heads, hd)
    angles = angles[..., None, :]                           # (..., seq, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Fixed sinusoidal position table (hubert frontend stub)."""
    pos = np.arange(seq)[:, None]
    i = np.arange(dim)[None, :]
    angle = pos / np.power(10000.0, 2 * (i // 2) / dim)
    table = np.where(i % 2 == 0, np.sin(angle), np.cos(angle))
    return jnp.asarray(table, dtype=jnp.float32)


def truncated_normal_init(key: jax.Array, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


def cross_entropy_loss(
    logits: jax.Array,            # (..., vocab) any float dtype
    targets: jax.Array,           # (...,) int32
    mask: Optional[jax.Array] = None,
    z_loss: float = 1e-4,
    vocab_size: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Masked mean CE with z-loss; handles padded vocab via vocab_size.

    Sharding-friendly formulation: no gather over the (model-sharded)
    vocab dim — the target log-prob comes from a fused one-hot reduction
    and the padded-vocab mask is a fused iota compare, so the full-vocab
    logits are never re-laid-out or gathered (they would be 40 GiB/device
    for a 150k vocab at 16x4096 tokens).
    """
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < v:
        # padded slots -> -inf via fused iota-compare (never materialized)
        slot = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
        logits = jnp.where(slot < vocab_size, logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
              == targets[..., None])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        mask = mask.astype(jnp.float32)
        n = jnp.maximum(mask.sum(), 1.0)
        return (nll * mask).sum() / n, n
    n = jnp.asarray(nll.size, jnp.float32)
    return nll.mean(), n
