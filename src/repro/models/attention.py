"""GQA attention: training/prefill (chunked) and decode (cache read).

The chunked formulation scans MXU-aligned query blocks whose size comes
from the local-partitioning pass (``plan.partitions['flash_attention']``):
the same tile decision configures both this XLA-level path and the Pallas
kernel in :mod:`repro.kernels.flash_attention` — the paper's "the
datapath uses whatever the compiler configured" separation.

Peak live memory per block is ``block_q × seq`` scores instead of
``seq × seq``, which is what lets the 32k-prefill cells fit HBM.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, rms_norm

NEG_INF = -1e30


class AttnParams(NamedTuple):
    """Separate q/k/v projections: section boundaries of a fused QKV
    matmul rarely align with TP shard boundaries (e.g. (H+2K)·hd = 6144
    over 16 shards puts the q/k split mid-shard), and GSPMD then patches
    the `split` with collective-permute halos.  Split projections shard
    cleanly (layout-pass decision `qkv: split`)."""

    wq: jax.Array              # (d, H * hd)
    wk: jax.Array              # (d, K * hd)
    wv: jax.Array              # (d, K * hd)
    wo: jax.Array              # (H * hd, d)
    q_norm: Optional[jax.Array] = None   # (hd,) qwen3 qk-norm scales
    k_norm: Optional[jax.Array] = None


def _mask(
    q_pos: jax.Array,          # (..., Sq)
    k_pos: jax.Array,          # (..., Sk)
    causal: bool,
    window,                    # 0/None = unlimited; scalar or traced value
) -> jax.Array:
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                 dtype=bool)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = m & (diff >= 0)
    if window is not None:
        w = jnp.asarray(window)
        m = m & jnp.where(w > 0, diff < w, True)
    return m


def gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,H,hd) × k (B,Sk,K,hd) -> scores (B,K,G,Sq,Sk), G=H/K."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    q = q.reshape(B, Sq, K, H // K, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32)


def gqa_context(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,K,G,Sq,Sk) × v (B,Sk,K,hd) -> (B,Sq,H,hd)."""
    B, K, G, Sq, Sk = p.shape
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return ctx.reshape(B, Sq, K * G, v.shape[-1])


def attention_chunked(
    q: jax.Array,              # (B, S, H, hd) — post-RoPE
    k: jax.Array,              # (B, S, K, hd)
    v: jax.Array,              # (B, S, K, hd)
    *,
    causal: bool,
    window=0,
    block_q: int = 512,
    positions: Optional[jax.Array] = None,   # (B, S)
) -> jax.Array:
    """Scan over query blocks; each block sees the full K/V stream.

    The per-block closure is rematerialized (``jax.checkpoint``) so the
    backward pass never holds more than one block's score matrix — the
    XLA equivalent of flash attention's O(S) memory.
    """
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    bq = min(block_q, S)
    n_blocks = -(-S // bq)
    pad = n_blocks * bq - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qpos_full = positions
    if pad:
        qpos_full = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=-1)

    q_blocks = q.reshape(B, n_blocks, bq, H, hd).transpose(1, 0, 2, 3, 4)
    qpos_blocks = qpos_full.reshape(B, n_blocks, bq).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one_block(qb, qpb):
        s = gqa_scores(qb * scale, k)                     # (B,K,G,bq,S)
        m = _mask(qpb, positions, causal, window)          # (B,bq,S)
        m = m & (qpb >= 0)[..., :, None]
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return gqa_context(p, v).astype(q.dtype)          # (B,bq,H,hd)

    out = jax.lax.map(lambda xs: one_block(*xs), (q_blocks, qpos_blocks))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n_blocks * bq, H, hd)
    return out[:, :S]


def attention_tail(
    q: jax.Array,              # (B, T, H, hd) — post-RoPE tail queries
    k: jax.Array,              # (B, M+T, K, hd) prefix ++ tail keys
    v: jax.Array,              # (B, M+T, K, hd)
    *,
    q_positions: jax.Array,    # (B, T) absolute positions of the tail
    k_positions: jax.Array,    # (B, M+T) absolute positions of all keys
    causal: bool,
    window=0,
    block_q: int = 512,
) -> jax.Array:
    """Chunked attention for a *tail* of queries over a longer key
    stream (prefix-cache prefill: the leading M keys come from resident
    pool blocks whose compute is being skipped).

    Deliberately mirrors :func:`attention_chunked` op-for-op — same
    ``gqa_scores`` einsum, same fp32 full-row softmax, same
    ``gqa_context`` contraction over the full key axis — so the tail
    positions' outputs are bitwise what a full-sequence prefill would
    have produced for them (token-identity across aliased vs private
    runs leans on this).
    """
    B, T, H, hd = q.shape
    scale = hd ** -0.5
    bq = min(block_q, T)
    n_blocks = -(-T // bq)
    pad = n_blocks * bq - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qpos_full = q_positions
    if pad:
        qpos_full = jnp.pad(q_positions, ((0, 0), (0, pad)),
                            constant_values=-1)
    q_blocks = q.reshape(B, n_blocks, bq, H, hd).transpose(1, 0, 2, 3, 4)
    qpos_blocks = qpos_full.reshape(B, n_blocks, bq).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one_block(qb, qpb):
        s = gqa_scores(qb * scale, k)                     # (B,K,G,bq,M+T)
        m = _mask(qpb, k_positions, causal, window)       # (B,bq,M+T)
        m = m & (qpb >= 0)[..., :, None]
        s = jnp.where(m[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return gqa_context(p, v).astype(q.dtype)          # (B,bq,H,hd)

    out = jax.lax.map(lambda xs: one_block(*xs), (q_blocks, qpos_blocks))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, n_blocks * bq, H, hd)
    return out[:, :T]


def attention_decode(
    q: jax.Array,              # (B, 1, H, hd) — post-RoPE
    k_cache: jax.Array,        # (B, S, K, hd)
    v_cache: jax.Array,        # (B, S, K, hd)
    *,
    cache_len: jax.Array,      # scalar or (B,): number of valid positions
    window=0,
) -> jax.Array:
    """One-token decode against the session cache (fp32 softmax)."""
    B, S, K, hd = k_cache.shape
    scale = hd ** -0.5
    s = gqa_scores(q * scale, k_cache)                    # (B,K,G,1,S)
    kpos = jnp.arange(S, dtype=jnp.int32)
    qpos = (jnp.asarray(cache_len) - 1).reshape(-1, 1)    # (B or 1, 1)
    valid = kpos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    m = valid
    if window is not None:
        w = jnp.asarray(window)
        m = m & jnp.where(w > 0, (qpos - kpos[None, :]) < w, True)
    s = jnp.where(m[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return gqa_context(p, v_cache).astype(q.dtype)        # (B,1,H,hd)


def attention_decode_paged(
    q: jax.Array,              # (B, 1, H, hd) — post-RoPE
    k_pool: jax.Array,         # (N, bl, K, hd) block pool (one layer)
    v_pool: jax.Array,         # (N, bl, K, hd)
    block_tbl: jax.Array,      # (B, nb) block ids; -1 = unassigned
    *,
    cache_len: jax.Array,      # scalar or (B,): number of valid positions
    window=0,
) -> jax.Array:
    """One-token decode against a paged cache (XLA gather path).

    Gathers each slot's blocks into a dense per-slot view and reuses
    :func:`attention_decode`; positions past ``cache_len`` are masked, so
    stale pool rows (from a block's previous tenant) and the clamped
    block-0 read of unassigned entries never reach the softmax.  Oracle:
    :func:`repro.kernels.ref.paged_decode_attention_ref`.
    """
    N, bl = k_pool.shape[0], k_pool.shape[1]
    B, nb = block_tbl.shape
    safe = jnp.clip(block_tbl, 0, N - 1)
    k = k_pool[safe].reshape(B, nb * bl, *k_pool.shape[2:])
    v = v_pool[safe].reshape(B, nb * bl, *v_pool.shape[2:])
    return attention_decode(q, k, v, cache_len=cache_len, window=window)


def project_qkv(
    x: jax.Array,
    p: AttnParams,
    n_heads: int,
    n_kv: int,
    hd: int,
    positions: jax.Array,
    theta: float,
    mrope_sections=None,
    qk_norm_eps: float = 1e-6,
):
    q = (x @ p.wq).reshape(*x.shape[:-1], n_heads, hd)
    k = (x @ p.wk).reshape(*x.shape[:-1], n_kv, hd)
    v = (x @ p.wv).reshape(*x.shape[:-1], n_kv, hd)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, qk_norm_eps)
        k = rms_norm(k, p.k_norm, qk_norm_eps)
    q = apply_rope(q, positions, theta, mrope_sections)
    k = apply_rope(k, positions, theta, mrope_sections)
    return q, k, v
