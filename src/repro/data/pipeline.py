"""Host data pipeline with prefetch (the template's ``prefetch.host``).

Production shape: a background thread keeps ``prefetch_depth`` batches
ahead (depth set by the communication pass), each host producing only its
shard of the global batch.  The source here is a deterministic synthetic
token stream (seeded per (host, step) so restarts reproduce bit-exactly —
required for checkpoint/restart tests); a real deployment swaps
``SyntheticSource`` for a storage-backed source with the same interface.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


class SyntheticSource:
    """Deterministic per-(host, step) synthetic batches."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 host_id: int = 0, n_hosts: int = 1, seed: int = 0):
        self.arch, self.shape = arch, shape
        self.host_id, self.n_hosts = host_id, n_hosts
        self.seed = seed
        assert shape.global_batch % n_hosts == 0
        self.host_batch = shape.global_batch // n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        B, S = self.host_batch, self.shape.seq_len
        arch = self.arch
        out: Dict[str, np.ndarray] = {}
        if arch.modality in ("audio", "vlm") and self.shape.kind != "decode":
            out["embeds"] = rng.standard_normal(
                (B, S, arch.d_model), dtype=np.float32)
            if arch.mrope_sections is not None:
                pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
                out["positions"] = np.broadcast_to(pos, (3, B, S)).copy()
            if self.shape.kind == "train":
                out["targets"] = rng.integers(
                    0, arch.vocab_size, (B, S), dtype=np.int32)
            if arch.modality == "audio" and self.shape.kind == "train":
                out["mask"] = (rng.random((B, S)) < 0.5).astype(np.float32)
        else:
            S_eff = 1 if self.shape.kind == "decode" else S
            out["tokens"] = rng.integers(
                0, arch.vocab_size, (B, S_eff), dtype=np.int32)
            if self.shape.kind == "train":
                out["targets"] = rng.integers(
                    0, arch.vocab_size, (B, S), dtype=np.int32)
        return out


class PrefetchPipeline:
    """Background-thread prefetcher; depth comes from the memory plan."""

    def __init__(self, source: SyntheticSource, prefetch_depth: int = 2,
                 start_step: int = 0, device_put=None):
        self.source = source
        self.depth = max(prefetch_depth, 1)
        self._q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._step = start_step
        self._stop = threading.Event()
        self._put = device_put or (lambda x: x)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            try:
                self._q.put((step, self._put(batch)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
