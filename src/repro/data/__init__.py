from repro.data.pipeline import PrefetchPipeline, SyntheticSource
__all__ = ["PrefetchPipeline", "SyntheticSource"]
