"""Serve a small model with batched requests (continuous batching).

The engine is built from the frozen plan artifact the specialization
flow produced — the same artifact a deployment would reload from the
content-addressed plan store next to the model checkpoint.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs import ShapeConfig, get_arch
from repro.core import specialize
from repro.models import init_params
from repro.serve import ServeEngine


def main() -> None:
    arch = get_arch("qwen3-8b").reduced()
    plan = specialize(arch, ShapeConfig("serve_demo", "decode", 128, 4),
                      mesh_axes=("data", "model"), mesh_shape=(1, 1))
    print(f"plan {plan.content_hash()[:12]} "
          f"(decode_impl={plan.estimates.get('decode_impl', 'xla')})")
    params = init_params(arch, jax.random.PRNGKey(0), *plan.padded_sizes())
    engine = ServeEngine.from_plan(plan, params, arch=arch)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(10):
        plen = int(rng.integers(8, 64))
        engine.submit(rng.integers(0, arch.vocab_size, (plen,)),
                      max_new_tokens=12,
                      temperature=0.0 if i % 2 == 0 else 0.8)
    done = engine.run_until_idle()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s")
    ttfts = [(r.t_first - r.t_submit) * 1e3 for r in done]
    print(f"ttft p50={np.percentile(ttfts, 50):.0f}ms "
          f"p95={np.percentile(ttfts, 95):.0f}ms")
    for r in done[:4]:
        print(f"  rid={r.rid:2d} prompt={len(r.prompt):3d} tok "
              f"-> {r.out_tokens}")


if __name__ == "__main__":
    main()
