"""Quickstart: the paper's flow in 30 lines.

1. Pick an architecture + workload shape.
2. Run the multi-level specialization flow -> MemoryPlan (the specialized
   memory-template instance, with the full decision log).
3. Lower ("HLS") the plan to an executable train step and run it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import ShapeConfig, get_arch
from repro.core.pipeline import specialize
from repro.launch.mesh import make_host_mesh
from repro.models import synthetic_batch
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

# 1. workload: a reduced qwen3 so it runs on CPU in seconds
arch = get_arch("qwen3-8b").reduced()
shape = ShapeConfig("quickstart", "train", seq_len=128, global_batch=4)
mesh = make_host_mesh()

# 2. the paper's contribution: specialize the memory template
plan = specialize(arch, shape, mesh_axes=tuple(mesh.axis_names),
                  mesh_shape=tuple(mesh.devices.shape))
print("=== specialized memory plan (decision log) ===")
for pass_name, subject, decision, reason in plan.log:
    print(f"  [{pass_name}] {subject}: {decision}\n      -> {reason}")

print("\n=== template components after specialization ===")
for name, comp in sorted(plan.template_summary["components"].items()):
    state = "ON " if comp["enabled"] else "OFF"
    print(f"  {state} {name:18s} {comp['params']}")

# 3. lower + train a few steps
trainer = Trainer(plan, mesh, TrainerConfig(n_steps=10, ckpt_every=0,
                                            log_every=2),
                  opt_cfg=OptConfig(total_steps=10),
                  arch=arch, shape=shape)
state, metrics = trainer.fit()
print(f"\nfinal loss: {float(metrics['loss']):.4f}")
