"""Inspect how the flow specializes the SAME template differently per
workload — the paper's central claim, made visible.

Compares the MemoryPlan for four contrasting workloads and prints what
each pass decided and why.

Run:  PYTHONPATH=src python examples/specialize_report.py
"""

from repro.core.pipeline import specialize

CASES = [
    ("qwen3-8b", "train_4k", ("data", "model"), (16, 16)),
    ("llama4-maverick-400b-a17b", "train_4k", ("pod", "data", "model"),
     (2, 16, 16)),
    ("qwen2-vl-72b", "decode_32k", ("data", "model"), (16, 16)),
    ("mamba2-2.7b", "long_500k", ("data", "model"), (16, 16)),
]


def main() -> None:
    for arch, shape, axes, mesh in CASES:
        plan = specialize(arch, shape, mesh_axes=axes, mesh_shape=mesh)
        print(f"\n{'='*72}\n{arch} @ {shape} on {'x'.join(map(str, mesh))}")
        print(f"{'='*72}")
        for pass_name, subject, decision, reason in plan.log:
            print(f"  [{pass_name:18s}] {subject:16s} -> {decision}")
            print(f"       {reason}")
        on = [n for n, c in plan.template_summary["components"].items()
              if c["enabled"]]
        off = [n for n, c in plan.template_summary["components"].items()
               if not c["enabled"]]
        print(f"  components kept:    {', '.join(on)}")
        print(f"  components removed: {', '.join(off) or '(none)'}")


if __name__ == "__main__":
    main()
