"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Uses the REAL pipeline end to end: specialization flow -> lowered train
step (microbatching/remat/donation per plan) -> prefetching data pipeline
-> async checkpoints -> restart replay.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import ShapeConfig, get_arch
from repro.core.pipeline import specialize
from repro.launch.mesh import make_host_mesh
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    # ~100M params: 8 layers x d512 x ff2048, 32k vocab (qwen3 family)
    arch = dataclasses.replace(
        get_arch("qwen3-8b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32768)
    print(f"params: {arch.param_count()/1e6:.1f}M")
    shape = ShapeConfig("tiny", "train", seq_len=256, global_batch=8)
    mesh = make_host_mesh()

    plan = specialize(arch, shape, mesh_axes=tuple(mesh.axis_names),
                      mesh_shape=tuple(mesh.devices.shape))
    trainer = Trainer(
        plan, mesh,
        TrainerConfig(n_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, log_every=20),
        opt_cfg=OptConfig(peak_lr=1e-3, warmup_steps=50,
                          total_steps=args.steps),
        arch=arch, shape=shape)
    t0 = time.time()
    state, metrics = trainer.fit()
    dt = time.time() - t0
    tokens = args.steps * shape.tokens
    print(f"\n{args.steps} steps, {tokens/1e6:.1f}M tokens in {dt:.0f}s "
          f"({tokens/dt/1e3:.1f}k tok/s) — final loss "
          f"{float(metrics['loss']):.4f} "
          f"(first {trainer.history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
