"""Plan-artifact round-trip smoke: specialize -> persist -> fresh-process
reload -> plan-driven serve engine ticks one token.

Guards the plan schema against silent drift: if a field stops surviving
the disk round-trip (hash mismatch) or the serve engine can no longer be
built from a reloaded artifact, this fails in CI.

Run:  PYTHONPATH=src python scripts/plan_roundtrip_smoke.py
"""

from __future__ import annotations

import os
import subprocess
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def phase1(plan_dir: str) -> str:
    """Compile + persist the plan; print its content hash."""
    from repro.configs import ShapeConfig, get_arch
    from repro.core import specialize
    arch = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("smoke_dec", "decode", 48, 2)
    plan = specialize(arch, shape, mesh_axes=("data", "model"),
                      mesh_shape=(1, 1), plan_dir=plan_dir)
    return plan.content_hash()


def phase2(plan_dir: str, expect_hash: str) -> None:
    """Fresh process: reload by hash, build the engine, decode a token."""
    import numpy as np
    from repro.configs import get_arch
    from repro.core import get_store
    from repro.models import init_params
    from repro.serve import ServeEngine
    import jax

    store = get_store(plan_dir)
    plan = store.load(expect_hash)
    assert plan is not None, f"plan {expect_hash} not reloadable"
    assert plan.content_hash() == expect_hash, "hash drift across processes"

    arch = get_arch(plan.arch).reduced()
    params = init_params(arch, jax.random.PRNGKey(0), *plan.padded_sizes())
    eng = ServeEngine.from_plan(plan, params, arch=arch)
    assert eng.max_len == 48 and eng.max_batch == 2, \
        (eng.max_len, eng.max_batch)    # batching limits came from the plan
    eng.submit(np.arange(8, dtype=np.int32) % arch.vocab_size,
               max_new_tokens=1)
    done = eng.run_until_idle(max_ticks=4)
    assert done and len(done[0].out_tokens) >= 1, "engine produced no token"
    print(f"plan round-trip smoke OK: {expect_hash[:12]} "
          f"-> {len(done)} request(s), token {done[0].out_tokens[0]}")


def main() -> None:
    if len(sys.argv) >= 3 and sys.argv[1] == "--phase2":
        phase2(os.environ["REPRO_PLAN_DIR"], sys.argv[2])
        return
    plan_dir = tempfile.mkdtemp(prefix="repro_plan_smoke_")
    h = phase1(plan_dir)
    env = {**os.environ, "REPRO_PLAN_DIR": plan_dir,
           "PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src")}
    subprocess.run([sys.executable, __file__, "--phase2", h],
                   check=True, env=env, timeout=300)


if __name__ == "__main__":
    main()
