"""Serve smoke for ci.sh: from_plan → staggered submits → run_until_idle.

Exercises the full plan-driven serving path in one process: specialize a
decode plan whose GQA kv_heads cannot shard the model axis (so the
data-organization pass spills the cache's seq dim and picks
``shard_map_flash``), build the engine with ``from_plan(mesh=...)``,
submit a staggered mix of prompt lengths (more requests than slots, so
slots are freed and reused mid-flight), and assert every request
finishes with the requested token count — and that the engine really
decodes through the plan's implementation (no silent XLA fallback).
"""

import dataclasses
import sys

import jax
import numpy as np

from repro.configs import ShapeConfig, get_arch
from repro.core.pipeline import specialize
from repro.models import lm
from repro.serve.engine import ServeEngine


def main() -> int:
    # kv_heads=1 on a (model=2) plan mesh -> seq spill -> shard_map_flash
    arch = dataclasses.replace(get_arch("qwen3-8b").reduced(), n_kv_heads=1)
    shape = ShapeConfig("serve_smoke", "decode", 32, 2)
    plan = specialize(arch, shape, mesh_axes=("data", "model"),
                      mesh_shape=(1, 2))
    impl = plan.estimates.get("decode_impl", "xla")
    assert impl == "shard_map_flash", f"plan chose {impl!r}"

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    params = lm.init_params(arch, jax.random.PRNGKey(0),
                            *plan.padded_sizes())
    eng = ServeEngine.from_plan(plan, params, arch=arch, mesh=mesh)
    # no silent XLA fallback: ticks go through the flash combine — the
    # real seq-sharded shard_map on a >1-wide model axis, its in-process
    # single-shard path on one device
    want = "shard_map_flash" if n_dev > 1 else "flash"
    assert eng.decode_path == want, (eng.decode_path, want)

    rng = np.random.default_rng(0)
    want = []
    for plen, mnt in ((5, 6), (11, 4), (8, 5), (14, 3)):   # staggered
        eng.submit(rng.integers(0, arch.vocab_size, (plen,)).astype(np.int32),
                   max_new_tokens=mnt)
        want.append(mnt)
    done = eng.run_until_idle(max_ticks=64)
    assert len(done) == len(want), (len(done), len(want))
    got = sorted(len(r.out_tokens) for r in done)
    assert got == sorted(want), (got, want)
    print(f"serve smoke OK: {len(done)} requests, "
          f"{sum(got)} tokens via {eng.decode_path} "
          f"(plan {plan.content_hash()[:12]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
